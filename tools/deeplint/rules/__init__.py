"""Rule registry: every deeplint rule module, keyed by ``RULE_ID``.

Each rule module exposes ``RULE_ID`` (kebab-case id used in suppressions,
baselines, and reports), ``SUMMARY`` (one line for ``--list-rules`` and
the JSON report), and ``check(project) -> Iterable[Finding]``.
"""

from __future__ import annotations

from tools.deeplint.rules import (
    device_sync,
    kernel_purity,
    layering,
    lock_discipline,
    metric_naming,
    mutation_version,
    stripped_assert,
    swallowed_exception,
)

ALL_RULES = [
    lock_discipline,
    kernel_purity,
    device_sync,
    stripped_assert,
    mutation_version,
    layering,
    metric_naming,
    swallowed_exception,
]

RULE_IDS = {mod.RULE_ID: mod for mod in ALL_RULES}
