"""The paper's primary contribution: the DeepMapping hybrid learned
structure — multi-task memorization MLP + auxiliary accuracy-assurance
table + existence bitvector + decode maps — plus MHAS architecture
search and the modification workflows.
"""

from repro.core.aux_table import AuxTable  # noqa: F401
from repro.core.bitvector import BitVector  # noqa: F401
from repro.core.encoding import KeyEncoder, ValueCodec, build_codecs  # noqa: F401
from repro.core.hybrid import DeepMappingConfig, DeepMappingStore  # noqa: F401
from repro.core.inference import EngineCache, EngineStats, InferenceEngine  # noqa: F401
from repro.core.model import MLPSpec, forward_digits, forward_onehot, init_params  # noqa: F401
from repro.core.table import Table, pack_composite_key  # noqa: F401
from repro.core.trainer import TrainConfig, train  # noqa: F401
