"""deeplint — stdlib-``ast`` static analysis for this repo's invariants.

The package is a small rule engine (:mod:`tools.deeplint.engine`) plus one
module per rule under :mod:`tools.deeplint.rules`.  Run it as::

    python -m tools.deeplint src/repro

Exit codes: 0 = clean (or fully baselined), 1 = non-baselined findings,
2 = usage / parse error.
"""

from tools.deeplint.engine import (  # noqa: F401
    Finding,
    Project,
    SourceModule,
    load_baseline,
    run,
)
from tools.deeplint.rules import ALL_RULES, RULE_IDS  # noqa: F401
