"""Shared plan executor.

Every plan runs the same pipeline regardless of store type:

    key source  ->  (shard scatter)  ->  batched inference + existence
    + aux merge ->  decode projection ->  gather

The store-specific middle is behind two protocol hooks:
``_range_keys(lo, hi)`` resolves range/scan key sources against the
store's existence index, and ``_lookup_with_stats(keys, columns,
fanout)`` answers a key batch with per-stage stats.  The sharded store
implements the scatter + thread-pool fan-out inside its hook; the
executor stays oblivious.

Plan execution defaults the sharded fan-out ON (overlapping per-shard
inference — ``Query.fanout(False)`` restores serial visits); the
legacy ``store.lookup`` shim stays serial for bit-for-bit continuity.
"""

from __future__ import annotations

import time

import numpy as np

from repro.api.plan import QueryPlan, QueryResult


def execute_plan(store, plan: QueryPlan) -> QueryResult:
    """Run ``plan`` against ``store`` -> :class:`QueryResult`."""
    t0 = time.perf_counter()

    # Stage 1: key source.
    if plan.kind == "point":
        keys = np.asarray(plan.keys, dtype=np.int64)
        route_s = 0.0
    elif plan.kind == "range":
        keys = store._range_keys(int(plan.lo), int(plan.hi))
        route_s = time.perf_counter() - t0
    else:  # scan
        keys = store._all_keys()
        route_s = time.perf_counter() - t0

    # Stages 2-5: scatter / inference / aux merge / decode (store hooks).
    # dispatch/collect pair: device work is enqueued before the host
    # half starts, so model-backed stores overlap inference of later
    # chunks with aux-merge + decode of earlier ones (and callers that
    # interleave several plans get cross-plan overlap for free).
    fanout = True if plan.fanout is None else plan.fanout
    handle = store._dispatch_lookup(keys, plan.columns, fanout=fanout)
    values, exists, stats = store._collect_lookup(handle)

    stats.kind = plan.kind
    stats.plan = (plan.source_stage(),) + stats.plan
    stats.num_keys = int(keys.shape[0])
    stats.num_rows = int(exists.sum())
    stats.route_s += route_s
    stats.total_s = time.perf_counter() - t0
    if plan.kind != "point":
        # Range/scan keys come from the existence index, so every one exists.
        assert bool(exists.all())
    return QueryResult(keys=keys, values=values, exists=exists, explain=stats)
