"""Compact multi-task MLP that memorizes key->value mappings (paper §IV-A).

Structure: a stack of *shared* fully-connected layers abstracting the
key, then per-value-column *private* stacks ending in a logits layer
(one softmax classifier per column).  Strings/categoricals are integer
codes; keys are digit-decomposed (``repro.core.encoding``).

The first dense layer from the input is stored as a rank-3 tensor
``(width, base, out)`` and evaluated as a **gather** (sum of rows
selected by digit codes) — mathematically identical to a dense matmul on
the one-hot encoding but never materializes it.  ``forward_onehot`` is
the reference path used by tests and by the Pallas kernel oracles.

Everything is pure JAX on pytrees: no flax/haiku dependency.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MLPSpec:
    """Architecture of one hybrid DeepMapping model.

    Hashable (usable as a jit static argument): dict-valued fields are
    normalized to sorted tuples of pairs at construction.
    """

    base: int
    width: int
    shared: Tuple[int, ...]
    private: Tuple[Tuple[str, Tuple[int, ...]], ...]
    out_cards: Tuple[Tuple[str, int], ...]
    dtype: str = "float32"

    def __init__(self, base, width, shared, private, out_cards, dtype="float32"):
        if isinstance(private, dict):
            private = tuple(sorted((k, tuple(v)) for k, v in private.items()))
        if isinstance(out_cards, dict):
            out_cards = tuple(sorted(out_cards.items()))
        object.__setattr__(self, "base", int(base))
        object.__setattr__(self, "width", int(width))
        object.__setattr__(self, "shared", tuple(shared))
        object.__setattr__(self, "private", tuple(private))
        object.__setattr__(self, "out_cards", tuple(out_cards))
        object.__setattr__(self, "dtype", dtype)
        if {k for k, _ in self.private} != {k for k, _ in self.out_cards}:
            raise ValueError("private/out_cards task mismatch")
        if not self.out_cards:
            raise ValueError("need at least one task")

    @property
    def tasks(self) -> Tuple[str, ...]:
        return tuple(k for k, _ in self.out_cards)

    @property
    def private_map(self) -> Dict[str, Tuple[int, ...]]:
        return dict(self.private)

    @property
    def card_map(self) -> Dict[str, int]:
        return dict(self.out_cards)

    @property
    def feature_dim(self) -> int:
        return self.base * self.width

    def num_params(self) -> int:
        total = 0
        priv, cards = self.private_map, self.card_map
        d = self.feature_dim
        for h in self.shared:
            total += d * h + h
            d = h
        trunk = d
        for t in self.tasks:
            d = trunk
            for h in priv[t]:
                total += d * h + h
                d = h
            total += d * cards[t] + cards[t]
        return total

    def size_bytes(self) -> int:
        """On-disk model size — Eq. 1's ``size(M)`` (fp32 serialized)."""
        itemsize = jnp.dtype(self.dtype).itemsize
        return self.num_params() * itemsize


def _init_dense(key, in_dim: int, out_dim: int, dtype) -> Dict[str, jnp.ndarray]:
    # He-normal: memorization nets are ReLU stacks.
    w = jax.random.normal(key, (in_dim, out_dim), dtype) * jnp.sqrt(2.0 / in_dim).astype(dtype)
    return {"w": w, "b": jnp.zeros((out_dim,), dtype)}


def init_params(spec: MLPSpec, seed: int = 0) -> Dict:
    """Initialize parameters. First layer from input is (width, base, out)."""
    dtype = jnp.dtype(spec.dtype)
    key = jax.random.PRNGKey(seed)
    n_heads = len(spec.tasks)
    keys = jax.random.split(key, 1 + len(spec.shared) + 4 * n_heads)
    ki = iter(range(len(keys)))

    def first_from_input(k, out_dim):
        p = _init_dense(k, spec.feature_dim, out_dim, dtype)
        return {"w": p["w"].reshape(spec.width, spec.base, out_dim), "b": p["b"]}

    params: Dict = {"shared": [], "heads": {}}
    d = None
    for i, h in enumerate(spec.shared):
        if i == 0:
            params["shared"].append(first_from_input(keys[next(ki)], h))
        else:
            params["shared"].append(_init_dense(keys[next(ki)], d, h, dtype))
        d = h
    trunk_dim = d  # None if no shared layers
    priv, cards = spec.private_map, spec.card_map
    for t in spec.tasks:
        head = {"hidden": [], "out": None}
        hd = trunk_dim
        for h in priv[t]:
            if hd is None:
                head["hidden"].append(first_from_input(keys[next(ki)], h))
            else:
                head["hidden"].append(_init_dense(keys[next(ki)], hd, h, dtype))
            hd = h
        if hd is None:
            head["out"] = first_from_input(keys[next(ki)], cards[t])
        else:
            head["out"] = _init_dense(keys[next(ki)], hd, cards[t], dtype)
        params["heads"][t] = head
    return params


def _apply(layer: Dict, x, digits):
    w = layer["w"]
    if w.ndim == 3:
        # Gather path: sum over digit positions of selected rows.
        # digits: (n, width) int32 ; w: (width, base, out)
        if x is not None:
            raise ValueError("rank-3 layer must be first from input")
        gathered = jax.vmap(lambda wp, dp: wp[dp], in_axes=(0, 1))(w, digits)
        return gathered.sum(axis=0) + layer["b"]  # (width, n, out) -> (n, out)
    return x @ w + layer["b"]


def forward_digits(params: Dict, digits: jnp.ndarray, spec: MLPSpec) -> Dict[str, jnp.ndarray]:
    """digits (n, width) int32 -> {task: (n, card) logits}. Gather fast path."""
    x = None
    for layer in params["shared"]:
        x = jax.nn.relu(_apply(layer, x, digits))
    out = {}
    for t in spec.tasks:
        head = params["heads"][t]
        h = x
        for layer in head["hidden"]:
            h = jax.nn.relu(_apply(layer, h, digits))
        out[t] = _apply(head["out"], h, digits)
    return out


def _apply_onehot(layer: Dict, x, onehot):
    w = layer["w"]
    if w.ndim == 3:
        if x is not None:
            raise ValueError("rank-3 layer must be first from input")
        return onehot @ w.reshape(-1, w.shape[-1]) + layer["b"]
    return x @ w + layer["b"]


def forward_onehot(params: Dict, onehot: jnp.ndarray, spec: MLPSpec) -> Dict[str, jnp.ndarray]:
    """Reference path: identical math on materialized one-hot features."""
    x = None
    for layer in params["shared"]:
        x = jax.nn.relu(_apply_onehot(layer, x, onehot))
    out = {}
    for t in spec.tasks:
        head = params["heads"][t]
        h = x
        for layer in head["hidden"]:
            h = jax.nn.relu(_apply_onehot(layer, h, onehot))
        out[t] = _apply_onehot(head["out"], h, onehot)
    return out


def predict_codes(params: Dict, digits: jnp.ndarray, spec: MLPSpec) -> jnp.ndarray:
    """argmax per task -> (n, m) int32 codes, tasks in spec.tasks order."""
    logits = forward_digits(params, digits, spec)
    return jnp.stack([jnp.argmax(logits[t], axis=-1) for t in spec.tasks], axis=1).astype(
        jnp.int32
    )


def count_params(params: Dict) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))


def model_size_bytes(params: Dict) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize for l in jax.tree.leaves(params))
