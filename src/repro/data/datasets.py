"""Synthetic datasets with controlled key-value correlation (paper §V-A1).

* ``synthetic_*_column(correlation="low")``  — values independent of the
  key (Pearson ~1e-4), like the paper's <OrderKey, OrderStatus> sample
  from TPC-H Orders.
* ``synthetic_*_column(correlation="high")`` — values are periodic
  functions of the key with a small noise fraction, like TPC-DS
  Customer_Demographics (Pearson ~0.12, "periodical patterns along the
  key-dimension").
* ``cropland_like`` — spatially-autocorrelated grid of crop categories
  (CroplandCROS §V-A1): patches generated from a coarse random field.
"""

from __future__ import annotations

import numpy as np

from repro.core.table import Table, pack_composite_key


def synthetic_single_column(
    n: int = 100_000,
    correlation: str = "low",
    cardinality: int = 3,
    noise: float = 0.01,
    seed: int = 0,
) -> Table:
    rng = np.random.default_rng(seed)
    keys = np.arange(n, dtype=np.int64)
    if correlation == "low":
        col = rng.integers(0, cardinality, size=n).astype(np.int32)
    elif correlation == "high":
        period = max(2, n // (cardinality * 64))
        col = ((keys // period) % cardinality).astype(np.int32)
        flip = rng.random(n) < noise
        col[flip] = rng.integers(0, cardinality, size=int(flip.sum()))
    else:
        raise ValueError(correlation)
    return Table(keys=keys, columns={"value": col})


def synthetic_multi_column(
    n: int = 100_000,
    correlation: str = "low",
    cardinalities=(3, 2, 7, 5),
    noise: float = 0.01,
    seed: int = 0,
) -> Table:
    rng = np.random.default_rng(seed)
    keys = np.arange(n, dtype=np.int64)
    cols = {}
    for i, c in enumerate(cardinalities):
        if correlation == "low":
            cols[f"v{i}"] = rng.integers(0, c, size=n).astype(np.int32)
        elif correlation == "high":
            period = max(2, (n // (c * 32)) * (i + 1))
            col = ((keys // period + i) % c).astype(np.int32)
            flip = rng.random(n) < noise
            col[flip] = rng.integers(0, c, size=int(flip.sum()))
            cols[f"v{i}"] = col
        else:
            raise ValueError(correlation)
    return Table(keys=keys, columns=cols)


def cropland_like(
    rows: int = 256,
    cols: int = 256,
    num_crops: int = 12,
    patch: int = 16,
    noise: float = 0.02,
    seed: int = 0,
) -> Table:
    """Image-like crop map: coarse random field upsampled into patches —
    strong spatial correlation, pixel key = packed (lat, lon)."""
    rng = np.random.default_rng(seed)
    coarse = rng.integers(0, num_crops, size=(rows // patch + 1, cols // patch + 1))
    rr, cc = np.meshgrid(np.arange(rows), np.arange(cols), indexing="ij")
    crop = coarse[rr // patch, cc // patch].astype(np.int32)
    flip = rng.random(crop.shape) < noise
    crop[flip] = rng.integers(0, num_crops, size=int(flip.sum()))
    keys = pack_composite_key([rr.ravel(), cc.ravel()])
    return Table(keys=keys, columns={"crop_type": crop.ravel()})


def pearson_keyvalue(table: Table) -> float:
    """Mean |Pearson| between key and each (coded) value column — the
    paper's correlation characterization of its synthetic data."""
    corrs = []
    k = table.keys.astype(np.float64)
    for col in table.columns.values():
        if col.dtype == object or col.dtype.kind in "SU":
            _, codes = np.unique(col, return_inverse=True)
            v = codes.astype(np.float64)
        else:
            v = col.astype(np.float64)
        if v.std() == 0 or k.std() == 0:
            corrs.append(1.0)
            continue
        corrs.append(abs(float(np.corrcoef(k, v)[0, 1])))
    return float(np.mean(corrs))
