"""Dry-run infrastructure units (the 512-device lowering itself runs in
``repro.launch.dryrun``; here we test the pieces that feed it)."""

import jax
import pytest

from repro.configs import SHAPES, get_arch, list_archs
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_host_mesh, mesh_axes
from repro.sharding.partition import param_shardings, state_shardings
from repro.train.optimizer import adamw


class TestCollectiveParsing:
    def test_shape_bytes(self):
        from repro.launch.dryrun import _shape_bytes

        assert _shape_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
        assert _shape_bytes("f32[10]") == 40
        assert _shape_bytes("(f32[8], bf16[4])") == 32 + 8
        assert _shape_bytes("pred[]") == 1

    def test_collective_regex(self):
        from repro.launch.dryrun import collective_bytes

        hlo = """
          %ag = bf16[64,128]{1,0} all-gather(bf16[4,128]{1,0} %x), dims={0}
          %ar.1 = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%sum
          %rs = f32[32]{0} reduce-scatter(f32[256]{0} %z), dimensions={0}
          %a2a = bf16[8,16]{1,0} all-to-all(bf16[8,16]{1,0} %w), dimensions={0}
          %cp = u32[4]{0} collective-permute(u32[4]{0} %v)
          %not_a_collective = f32[9]{0} add(f32[9]{0} %a, f32[9]{0} %b)
        """
        got = collective_bytes(hlo)
        assert got["all-gather"] == 64 * 128 * 2
        assert got["all-reduce"] == 2 * 256 * 4  # ring ~2x
        assert got["reduce-scatter"] == 32 * 4
        assert got["all-to-all"] == 8 * 16 * 2
        assert got["collective-permute"] == 16


class TestInputSpecs:
    @pytest.mark.parametrize("arch_id", list_archs())
    def test_specs_exist_for_assigned_shapes(self, arch_id):
        spec = get_arch(arch_id)
        for shape_id in spec.shapes:
            batch = specs_lib.input_specs(arch_id, shape_id)
            assert "tokens" in batch or "frames" in batch
            for v in jax.tree.leaves(batch):
                assert isinstance(v, jax.ShapeDtypeStruct)
            if SHAPES[shape_id]["kind"] == "decode":
                cache = specs_lib.cache_specs(arch_id, shape_id)
                assert len(jax.tree.leaves(cache)) > 0

    def test_train_shape_dims(self):
        b = specs_lib.input_specs("qwen2-7b", "train_4k")
        assert b["tokens"].shape == (256, 4096)
        b = specs_lib.input_specs("phi-3-vision-4.2b", "train_4k")
        assert b["patch_embeds"].shape[0] == 256

    def test_encdec_split(self):
        b = specs_lib.input_specs("seamless-m4t-medium", "train_4k")
        assert b["frames"].shape == (256, 2048, 1024)
        assert b["tokens"].shape == (256, 2048)

    def test_decode_cache_length(self):
        c = specs_lib.cache_specs("tinyllama-1.1b", "decode_32k")
        leaves = [x for x in jax.tree.leaves(c) if hasattr(x, "shape") and len(x.shape) == 5]
        # stacked (groups, B, T, K, hd)
        assert any(x.shape[1] == 128 and x.shape[2] == 32768 for x in leaves)

    def test_long_cache_for_ssm(self):
        c = specs_lib.cache_specs("rwkv6-7b", "long_500k")
        # constant-size state, no 500k dim anywhere
        assert all(524288 not in x.shape for x in jax.tree.leaves(c) if hasattr(x, "shape"))

    def test_no_device_allocation(self):
        """Specs must be ShapeDtypeStructs, never committed arrays."""
        opt = adamw(lr=1e-3)
        st = specs_lib.state_specs("granite-3-2b", opt)
        for leaf in jax.tree.leaves(st):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


class TestShardingRules:
    def test_param_shardings_cover_tree(self):
        cfg = get_arch("qwen2-7b").config
        mesh = make_host_mesh(1, 1)
        shapes = specs_lib.params_specs("qwen2-7b")
        sh = param_shardings(cfg, mesh, shapes)
        n_shapes = len(jax.tree.leaves(shapes))
        n_sh = len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
        assert n_shapes == n_sh

    def test_divisibility_fallback(self):
        """granite vocab 49155 is not divisible by 16 — rule must fall
        back rather than emit an invalid spec."""
        from jax.sharding import PartitionSpec

        cfg = get_arch("granite-3-2b").config
        mesh = make_host_mesh(1, 1)
        shapes = specs_lib.params_specs("granite-3-2b")
        sh = param_shardings(cfg, mesh, shapes)
        embed = sh["embed"]["table"]
        assert isinstance(embed.spec, PartitionSpec)

    def test_state_shardings_cover_optstate(self):
        cfg = get_arch("tinyllama-1.1b").config
        mesh = make_host_mesh(1, 1)
        opt = adamw(lr=1e-3)
        st = specs_lib.state_specs("tinyllama-1.1b", opt)
        sh = state_shardings(cfg, mesh, st)
        assert len(jax.tree.leaves(sh.opt.mu, is_leaf=lambda x: hasattr(x, "spec"))) == len(
            jax.tree.leaves(st.opt.mu)
        )

    def test_mesh_axes_helper(self):
        m1 = make_host_mesh(1, 1)
        fsdp, tp = mesh_axes(m1)
        assert fsdp == ("data",) and tp == "model"


class TestDryrunResults:
    """Validate the recorded compilability sweep (deliverable e)."""

    def test_all_cells_compiled(self):
        import json
        import os

        path = "results/dryrun.jsonl"
        if not os.path.exists(path):
            pytest.skip("dry-run results not generated in this environment")
        recs = {}
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                recs[(r["arch"], r["shape"], r["mesh"])] = r
        expected = 0
        for arch_id in list_archs():
            for shape_id in get_arch(arch_id).shapes:
                for mesh in ("16x16", "2x16x16"):
                    expected += 1
                    key = (arch_id, shape_id, mesh)
                    assert key in recs, f"missing dry-run cell {key}"
                    assert recs[key].get("ok"), f"cell failed: {key}"
        assert expected == 66  # 33 applicable cells x 2 meshes
