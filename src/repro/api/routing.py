"""Shared scatter/gather primitives (numpy-only, store-agnostic).

One request batch fans out to several owners — shards behind a
``ShardRouter``, members behind a ``FederatedStore`` — and results come
back in request order.  Both layers used to carry private copies of
the same two nontrivial idioms; they live here once:

* :func:`group_runs` — stable group-by of positions per owner id
  (argsort + run cuts; one contiguous group per owner, ascending id);
* :func:`gather_parts` — reassemble per-owner ``(values, exists)``
  into request order via concatenate + inverse permutation, which
  sidesteps per-column dtype preallocation (owners may disagree on
  e.g. unicode widths of decode maps).

This module must stay dependency-light (numpy only): ``cluster``
imports it through ``api``, and ``api`` must never import the store
packages back.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Tuple

import numpy as np


def group_runs(ids: np.ndarray) -> List[Tuple[int, np.ndarray]]:
    """Group request positions by owner id -> ``[(id, positions), ...]``
    (ascending id; owners with no positions are skipped; empty input
    -> empty list).  ``positions`` index the original request array."""
    ids = np.asarray(ids)
    if ids.size == 0:
        return []
    order = np.argsort(ids, kind="stable")
    sorted_ids = ids[order]
    cut = np.flatnonzero(np.diff(sorted_ids)) + 1
    starts = np.concatenate([[0], cut])
    ends = np.concatenate([cut, [sorted_ids.size]])
    return [
        (int(sorted_ids[s]), order[s:e]) for s, e in zip(starts, ends)
    ]


def gather_parts(
    n: int,
    parts: Iterable[Tuple[np.ndarray, Dict[str, np.ndarray], np.ndarray]],
) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    """Reassemble per-owner ``(positions, values, exists)`` parts into
    request order over ``n`` rows -> ``(values, exists)``."""
    parts = list(parts)
    exists = np.zeros(n, dtype=bool)
    if not parts:
        return {}, exists
    positions = np.concatenate([p for p, _, _ in parts])
    inv = np.empty(n, dtype=np.int64)
    inv[positions] = np.arange(positions.size)
    values: Dict[str, np.ndarray] = {}
    for name in parts[0][1]:
        values[name] = np.concatenate([v[name] for _, v, _ in parts])[inv]
    exists[positions] = np.concatenate([e for _, _, e in parts])
    return values, exists
