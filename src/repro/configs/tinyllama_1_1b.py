"""tinyllama-1.1b — llama2-arch small [arXiv:2401.02385].
22L d_model=2048 32H (kv=4, head 64) d_ff=5632 vocab=32000."""

from repro.configs.base import ArchSpec, register
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
)

SMOKE = ModelConfig(
    name="tinyllama-smoke",
    family="dense",
    num_layers=2,
    d_model=32,
    num_heads=4,
    num_kv_heads=2,
    head_dim=8,
    d_ff=64,
    vocab_size=128,
    dtype="float32",
    remat="none",
)

SPEC = register(
    ArchSpec(
        arch_id="tinyllama-1.1b",
        config=CONFIG,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        notes="Pure full attention -> long_500k skipped.",
    )
)
