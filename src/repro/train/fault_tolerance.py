"""Fault tolerance: checkpoint/restart train loop, straggler watchdog,
failure injection, elastic remesh.

The runner owns the invariants a 1000-node fleet needs:

* every step is DETERMINISTIC in (seed, step) — the loader is stateless,
  so a restart at step k replays exactly the batches k, k+1, ... with no
  data loss or duplication;
* checkpoints are atomic + keep-k (``repro.train.checkpoint``), written
  async off the critical path;
* a crash (injected or real) triggers restore-latest + replay;
* per-step wall times feed a straggler watchdog (median × factor rule —
  in production the callback re-shards around the slow host; here it
  records events for tests and benchmarks);
* ``elastic_restore`` re-lowers the step for a NEW mesh and device_puts
  the restored state against the new sharding tree (scale up/down).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.train import checkpoint as ckpt_lib


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    median: float


class StepWatchdog:
    """Flags steps slower than ``factor`` x running median."""

    def __init__(self, factor: float = 2.0, window: int = 50):
        self.factor = factor
        self.window = window
        self.times: List[float] = []
        self.events: List[StragglerEvent] = []

    def observe(self, step: int, dt: float) -> Optional[StragglerEvent]:
        self.times.append(dt)
        hist = self.times[-self.window :]
        med = float(np.median(hist))
        if len(hist) >= 5 and dt > self.factor * med:
            ev = StragglerEvent(step=step, step_time=dt, median=med)
            self.events.append(ev)
            return ev
        return None


@dataclasses.dataclass
class RunReport:
    steps_run: int
    restarts: int
    final_step: int
    losses: List[float]
    straggler_events: List[StragglerEvent]


def run_training(
    step_fn: Callable,
    state,
    batch_fn: Callable[[int], Dict],
    num_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 10,
    keep: int = 3,
    fail_at: Optional[Callable[[int], bool]] = None,
    max_restarts: int = 5,
    watchdog: Optional[StepWatchdog] = None,
    async_ckpt: bool = True,
) -> RunReport:
    """Fault-tolerant loop.  ``fail_at(step)`` injects a crash (tests);
    recovery = restore latest checkpoint and REPLAY from there, exactly
    as a real preemption restart would."""
    watchdog = watchdog or StepWatchdog()
    saver = ckpt_lib.AsyncCheckpointer(ckpt_dir, keep=keep) if async_ckpt else None
    like = jax.tree.map(np.asarray, state)

    losses: List[float] = []
    restarts = 0
    step = 0
    start_step, restored = ckpt_lib.restore_latest(ckpt_dir, like)
    if restored is not None:
        state = restored
        step = start_step

    while step < num_steps:
        try:
            if fail_at is not None and fail_at(step):
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch_fn(step))
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            watchdog.observe(step, dt)
            losses.append(loss)
            step += 1
            if step % ckpt_every == 0:
                if saver is not None:
                    saver.save(step, state)
                else:
                    ckpt_lib.save_checkpoint(ckpt_dir, step, state, keep=keep)
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
            if saver is not None:
                saver.wait()
            prev_step, restored = ckpt_lib.restore_latest(ckpt_dir, like)
            if restored is None:
                step = 0  # nothing durable yet: restart from scratch
            else:
                state, step = restored, prev_step
    if saver is not None:
        saver.save(step, state)
        saver.wait()
    return RunReport(
        steps_run=len(losses),
        restarts=restarts,
        final_step=step,
        losses=losses,
        straggler_events=watchdog.events,
    )


def elastic_restore(
    ckpt_dir: str,
    like,
    new_shardings,
):
    """Restore the latest checkpoint onto a DIFFERENT mesh: the sharding
    tree of the new topology re-places every leaf (scale up/down).  The
    caller re-lowers its step function for the new mesh."""
    step, state = ckpt_lib.restore_latest(ckpt_dir, like, shardings=new_shardings)
    return step, state
