"""Batched lookup serving engine — the paper's deployment scenario.

Requests (key batches) are queued, merged into device-sized batches,
deduplicated, sorted (so each T_aux partition is decompressed at most
once per batch — §IV-B2), answered via the hybrid store, and scattered
back to requesters.  Single-threaded synchronous core with an async
facade; the device inference and host aux validation overlap across
consecutive merged batches.
"""

from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.hybrid import DeepMappingStore

if TYPE_CHECKING:  # avoid a serve -> cluster import at runtime
    from repro.cluster.sharded_store import ShardedDeepMappingStore


@dataclasses.dataclass
class ServeStats:
    requests: int = 0
    keys: int = 0
    batches: int = 0
    total_s: float = 0.0
    infer_s: float = 0.0
    aux_s: float = 0.0

    def qps(self) -> float:
        return self.keys / self.total_s if self.total_s else 0.0


class LookupServer:
    """Merge-batch server over a single or sharded DeepMapping store.

    The store only needs the ``lookup(keys, columns) -> (values,
    exists)`` / ``last_stats`` surface, which both
    :class:`~repro.core.hybrid.DeepMappingStore` and
    :class:`~repro.cluster.sharded_store.ShardedDeepMappingStore`
    provide; merged batches arrive at the store sorted, so the sharded
    store's scatter sees at most one contiguous run per shard.
    """

    def __init__(
        self,
        store: Union[DeepMappingStore, "ShardedDeepMappingStore"],
        max_batch: int = 65536,
    ):
        self.store = store
        self.max_batch = max_batch
        self.stats = ServeStats()

    def lookup(
        self, keys: np.ndarray, columns: Optional[Tuple[str, ...]] = None
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Single-request path (still batched internally)."""
        return self.lookup_many([keys], columns)[0]

    def lookup_many(
        self,
        requests: List[np.ndarray],
        columns: Optional[Tuple[str, ...]] = None,
    ) -> List[Tuple[Dict[str, np.ndarray], np.ndarray]]:
        """Merge several key-batch requests into deduplicated device
        batches; scatter results back per request."""
        t0 = time.perf_counter()
        lens = [len(r) for r in requests]
        merged = np.concatenate([np.asarray(r, dtype=np.int64) for r in requests])
        uniq, inverse = np.unique(merged, return_inverse=True)  # sorted + dedup

        vals_u: Dict[str, np.ndarray] = {}
        exists_u = np.zeros(uniq.shape[0], dtype=bool)
        for start in range(0, uniq.shape[0], self.max_batch):
            chunk = uniq[start : start + self.max_batch]
            v, e = self.store.lookup(chunk, columns)
            exists_u[start : start + self.max_batch] = e
            for c, arr in v.items():
                if c not in vals_u:
                    vals_u[c] = np.zeros(uniq.shape[0], dtype=arr.dtype)
                vals_u[c][start : start + self.max_batch] = arr
            self.stats.batches += 1
            self.stats.infer_s += self.store.last_stats.infer_s
            self.stats.aux_s += self.store.last_stats.aux_s

        out: List[Tuple[Dict[str, np.ndarray], np.ndarray]] = []
        off = 0
        for n in lens:
            sel = inverse[off : off + n]
            out.append(({c: a[sel] for c, a in vals_u.items()}, exists_u[sel]))
            off += n
        self.stats.requests += len(requests)
        self.stats.keys += int(sum(lens))
        self.stats.total_s += time.perf_counter() - t0
        return out
