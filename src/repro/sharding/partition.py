"""PartitionSpec assignment for every architecture family.

Policy (DP/FSDP over the composed ``(pod, data)`` axes, TP/EP over
``model``):

* 2-D projections ``(in, out)`` -> ``(fsdp, tp)`` — FSDP shards the
  contraction dim, TP the output features; transposed output
  projections (``wo``/``down``) get ``(tp, fsdp)`` so the TP axis stays
  on the features that were just produced (Megatron pairing: no
  re-gather between the two matmuls of a block).
* 3-D expert weights ``(E, in, out)`` -> ``(tp(E), fsdp, None)`` —
  expert parallelism over the model axis, FSDP within the expert.
* embeddings ``(V, d)`` -> ``(tp, fsdp)``; stacked-scan params keep the
  leading layer/group dim replicated.
* every rule falls back along ``(divisible-tp, divisible-fsdp, replicate)``
  so odd dims (e.g. granite's 49155 vocab) never block compilation.

Activations: batch over fsdp axes; decode caches shard batch when
divisible, else SEQUENCE over fsdp (the long_500k cells — turning the
cache-bound decode into a flash-decoding-style distributed softmax).
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import mesh_axes
from repro.models.config import ModelConfig


def _axsize(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fit(dim: int, mesh, axes):
    """axes if it divides dim, else None."""
    return axes if axes is not None and dim % _axsize(mesh, axes) == 0 else None


def param_shardings(cfg: ModelConfig, mesh, params_shape_tree) -> Dict:
    """Map an eval_shape params tree to NamedShardings by path rules."""
    fsdp, tp = mesh_axes(mesh)
    fsdp = tuple(fsdp)
    tp_only = getattr(cfg, "param_sharding_mode", "fsdp_tp") == "tp_only"
    contract_axes = None if tp_only else fsdp
    embed_d = None if (tp_only or getattr(cfg, "embed_unsharded_d", False)) else fsdp

    def rule(path: str, shape: Tuple[int, ...]):
        # stacked scan params carry a leading group dim -> replicated.
        lead = ()
        if ("groups" in path or "enc_layers" in path or "dec_layers" in path) and len(shape) >= 1:
            lead, shape = (None,), tuple(shape[1:])
        r = len(shape)
        name = path.rsplit("/", 1)[-1]

        if r == 0:
            return P(*lead) if lead else P()
        if r == 1:
            return P(*lead, _fit(shape[0], mesh, tp))
        if "embed" in path and name == "table":
            return P(*lead, _fit(shape[0], mesh, tp), _fit(shape[1], mesh, embed_d))
        if r == 2:
            transposed = any(k in path for k in ("/wo", "/down", "/w_out", "/wv_b", "/wk_b"))
            if transposed:
                return P(*lead, _fit(shape[0], mesh, tp), _fit(shape[1], mesh, contract_axes))
            return P(*lead, _fit(shape[0], mesh, contract_axes), _fit(shape[1], mesh, tp))
        if r == 3:
            if any(k in path for k in ("w_gate", "w_up", "w_down")):
                # (E, in, out): EP over tp, FSDP inside the expert
                return P(*lead, _fit(shape[0], mesh, tp),
                         _fit(shape[1], mesh, contract_axes), None)
            # conv kernels / misc rank-3: shard the widest divisible dim on tp
            best = max(range(3), key=lambda i: shape[i])
            spec = [None, None, None]
            spec[best] = _fit(shape[best], mesh, tp)
            return P(*lead, *spec)
        # rank>=4: replicate (rare: none today)
        return P(*lead, *([None] * r))

    def walk(node, path=""):
        if node is None:
            return None  # empty pytree node (e.g. zero-group segment)
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(v, f"{path}/{i}") for i, v in enumerate(node)]
            return type(node)(t) if isinstance(node, tuple) else t
        return NamedSharding(mesh, rule(path, tuple(node.shape)))

    return walk(params_shape_tree)


def state_shardings(cfg: ModelConfig, mesh, state_shape_tree) -> Dict:
    """TrainState = (params, OptState(step, mu, nu)).  Moments are ALWAYS
    fully sharded over (fsdp x tp) — ZeRO — even when params run
    tp-only: the resharding cost appears once per step at the update,
    param-sized, instead of per matmul."""
    import dataclasses

    params_sh = param_shardings(cfg, mesh, state_shape_tree.params)
    moments_cfg = dataclasses.replace(
        cfg, param_sharding_mode="fsdp_tp", embed_unsharded_d=False
    )
    mu_sh = param_shardings(moments_cfg, mesh, state_shape_tree.opt.mu)
    nu_sh = param_shardings(moments_cfg, mesh, state_shape_tree.opt.nu)
    from repro.train.optimizer import OptState
    from repro.train.train_step import TrainState

    return TrainState(
        params=params_sh,
        opt=OptState(step=NamedSharding(mesh, P()), mu=mu_sh, nu=nu_sh),
    )


def batch_shardings(cfg: ModelConfig, mesh, batch_shape_tree) -> Dict:
    fsdp, tp = mesh_axes(mesh)
    fsdp = tuple(fsdp)

    def rule(path, shape):
        b = _fit(shape[0], mesh, fsdp)
        rest = [None] * (len(shape) - 1)
        if len(shape) == 3:  # (B, S, d) embeddings: d on tp when divisible
            rest[-1] = _fit(shape[-1], mesh, tp)
        return P(b, *rest)

    def walk(node, path=""):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        return NamedSharding(mesh, rule(path, tuple(node.shape)))

    return walk(batch_shape_tree)


def cache_shardings(cfg: ModelConfig, mesh, cache_shape_tree) -> Dict:
    """Decode caches: shard batch over fsdp when divisible; otherwise
    shard the SEQUENCE dim (long-context single-sequence decode).  Head
    or feature dims go on tp when divisible."""
    fsdp, tp = mesh_axes(mesh)
    fsdp = tuple(fsdp)

    seq_tp = getattr(cfg, "cache_seq_shard_tp", False)

    def rule(path: str, shape: Tuple[int, ...]):
        lead = ()
        if "groups" in path and len(shape) >= 1:
            lead, shape = (None,), tuple(shape[1:])
        r = len(shape)
        if r == 0:
            return P(*lead) if lead else P()
        spec = [None] * r
        batch_ax = _fit(shape[0], mesh, fsdp)
        spec[0] = batch_ax
        if r >= 2 and batch_ax is None and shape[1] > 1:
            spec[1] = _fit(shape[1], mesh, fsdp)  # sequence-sharded cache
        if seq_tp and r >= 3 and spec[1] is None and shape[1] > 1:
            # flash-decoding: sequence over the tensor axis; softmax
            # reductions become all-reduces (§Perf decode variant)
            spec[1] = _fit(shape[1], mesh, tp)
            return P(*lead, *spec)
        # last/feature dims on tp (prefer the head dim for rank-4 KV)
        if r == 4:
            spec[2] = _fit(shape[2], mesh, tp)
            if spec[2] is None:
                spec[3] = _fit(shape[3], mesh, tp)
        elif r >= 2:
            if spec[-1] is None and shape[-1] > 1:
                spec[-1] = _fit(shape[-1], mesh, tp)
        return P(*lead, *spec)

    def walk(node, path=""):
        if node is None:
            return None
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(v, f"{path}/{i}") for i, v in enumerate(node)]
            return type(node)(t) if isinstance(node, tuple) else t
        return NamedSharding(mesh, rule(path, tuple(node.shape)))

    return walk(cache_shape_tree)


def logits_sharding(cfg: ModelConfig, mesh, batch: int):
    fsdp, tp = mesh_axes(mesh)
    v = cfg.vocab_size
    m = cfg.vocab_pad_multiple
    if m > 0:
        v = ((v + m - 1) // m) * m
    return NamedSharding(
        mesh, P(_fit(batch, mesh, tuple(fsdp)), None, _fit(v, mesh, tp))
    )
