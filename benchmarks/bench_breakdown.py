"""Paper Fig. 6 (storage breakdown: model / T_aux / V_exist / f_decode)
and Fig. 7 (end-to-end latency breakdown: inference / existence check /
aux lookup / decode)."""

from __future__ import annotations

import argparse
from typing import Dict, List

from benchmarks import common as C
from repro.storage import MemoryPool


def run_storage(datasets=None) -> List[Dict]:
    rows = []
    for ds in datasets or C.FAST_DATASETS:
        store = C.dm_store(ds, "DM-Z")
        bd = store.size_breakdown()
        total = sum(bd.values())
        rows.append({"dataset": ds, **bd, "total": total,
                     "memorized": store.memorized_fraction()})
        C.emit(
            f"storage_breakdown/{ds}", 0.0,
            f"model={bd['model']};aux={bd['aux_table']};"
            f"vexist={bd['exist_bitvector']};decode={bd['decode_map']};"
            f"memorized={store.memorized_fraction():.3f}",
        )
    return rows


def run_latency(datasets=None, batch=10_000) -> List[Dict]:
    rows = []
    for ds in datasets or C.FAST_DATASETS:
        table = C.DATASETS[ds]()
        pool = MemoryPool(max(1 << 20, table.raw_size_bytes() // 20))
        store = C.dm_store(ds, "DM-Z", pool=pool)
        keys = C.query_keys(table, batch, seed=1)
        store.lookup(keys)  # warm the jit
        pool.clear()
        s = store.query().where_keys(keys).execute().explain
        stage_total = s.infer_s + s.exist_s + s.aux_s + s.decode_s
        rows.append({"dataset": ds, "infer_s": s.infer_s, "exist_s": s.exist_s,
                     "aux_s": s.aux_s, "decode_s": s.decode_s})
        C.emit(
            f"latency_breakdown/{ds}/B={batch}",
            stage_total * 1e6,
            f"infer={s.infer_s*1e6:.0f};exist={s.exist_s*1e6:.0f};"
            f"aux={s.aux_s*1e6:.0f};decode={s.decode_s*1e6:.0f}",
        )
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--what", default="storage", choices=["storage", "latency"])
    args = ap.parse_args()
    (run_storage if args.what == "storage" else run_latency)()


if __name__ == "__main__":
    main()
