"""Unified architecture configuration covering every assigned family."""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads

    # -- attention ---------------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # per-layer sliding window; 0 = full/global attention at that layer.
    # 'window_pattern' cycles over layers, e.g. (1024,)*5 + (0,) for
    # gemma3's 5 local : 1 global.
    window_pattern: Tuple[int, ...] = (0,)
    logit_softcap: float = 0.0

    # -- MoE ----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0    # deepseek: leading dense-FFN layers
    router_scale: float = 1.0
    capacity_factor: float = 1.25

    # -- MLA (deepseek) -------------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # -- recurrent families ----------------------------------------------------
    # block pattern cycled over depth, e.g. ("rglru","rglru","attn").
    block_pattern: Tuple[str, ...] = ("attn",)
    conv_width: int = 4            # RG-LRU temporal conv
    rglru_dim: int = 0             # recurrence width (0 -> d_model)

    # -- encoder-decoder ---------------------------------------------------------
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    dec_layers: int = 0

    # -- misc -----------------------------------------------------------------
    modality: str = "text"         # text | vision | audio (frontend stubs)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # activation checkpointing policy used by train_step: none|full|dots
    remat: str = "full"
    # Unroll the over-layers scan.  False for fast compiles (deliverable-e
    # compilability sweep); True for the roofline metrics sweep — XLA cost
    # analysis counts a while body ONCE, so scanned models under-report
    # FLOPs and in-loop collective bytes by the trip count.
    scan_unroll: bool = False

    # ---- perf-iteration knobs (EXPERIMENTS.md §Perf) -----------------------
    # Remat each flash-attention KV chunk: the pure-JAX flash scan is
    # memory-lean in forward but its BACKWARD saves per-chunk softmax
    # residuals (O(S·chunk·heads) fp32 per layer) — checkpointing the
    # chunk body recomputes them instead.
    flash_remat: bool = False
    # Pad embedding/lm-head vocab to a multiple (0 = off).  Non-divisible
    # vocabs (granite 49155, seamless 256206) otherwise fall back to
    # replicated logits on the tensor axis — padding restores the shard.
    vocab_pad_multiple: int = 0
    # Keep MoE dispatch buffers sharded (experts on 'model', capacity on
    # 'data') via explicit constraints instead of letting GSPMD replicate
    # through the sort/scatter pipeline.
    moe_shard_constraints: bool = False
    # Block-local MoE dispatch: split tokens into N blocks (= data-axis
    # size) and sort/route WITHIN each block, with the block dim pinned
    # to 'data'.  Gathers/scatters become shard-local; only the
    # (block x expert) reshard moves bytes — the all-to-all pattern a
    # hand-written shard_map MoE would produce.  0 = global dispatch.
    moe_block_dispatch: int = 0
    # Decode cells: shard the KV-cache SEQUENCE dim over the tensor axis
    # (flash-decoding-style distributed softmax) instead of heads/head_dim
    # — kills the involuntary cache replication when kv_heads < tp.
    cache_seq_shard_tp: bool = False
    # Parameter layout: "fsdp_tp" shards weight contraction dims over the
    # data axis (ZeRO-3-style; GSPMD may turn every matmul into a partial
    # product + activation-sized all-reduce); "tp_only" keeps weights
    # megatron-sharded on the tensor axis only and leaves FSDP to the
    # optimizer moments (ZeRO-1) — weight-sized collectives instead of
    # activation-sized ones when the model fits 1/tp per chip.
    param_sharding_mode: str = "fsdp_tp"
    # Keep the embedding table's d_model dim unsharded: tied embeddings
    # are used twice per step and an fsdp-sharded d forces table-sized
    # all-gathers on every logits matmul.
    embed_unsharded_d: bool = False
    # Explicitly replicate attention q/k/v/scores on the tensor axis
    # (batch stays data-sharded).  For few-head archs (gemma3: 4H/1KV on
    # a 16-way tensor axis) GSPMD otherwise thrashes through involuntary
    # full rematerializations on every (H*hd)<->(H,hd) reshape; explicit
    # replication trades a little redundant attention compute (not the
    # bottleneck) for near-zero attention collectives.
    attn_replicated: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def layer_window(self) -> Tuple[int, ...]:
        """Resolved per-layer window (len == num_layers)."""
        p = self.window_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def layer_blocks(self) -> Tuple[str, ...]:
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    def param_count_estimate(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D and
        sanity checks against the instantiated tree)."""
        d, L = self.d_model, self.num_layers
        hd = self.head_dim
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d
        blocks = self.layer_blocks
        for i in range(L if not self.is_encoder_decoder else 0):
            kind = blocks[i]
            if kind == "attn":
                if self.use_mla:
                    ql = self.q_lora_rank or d
                    total += d * ql + ql * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    total += d * (self.kv_lora_rank + self.qk_rope_dim)
                    total += self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                    total += self.num_heads * self.v_head_dim * d
                else:
                    total += d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                    total += self.num_heads * hd * d
            elif kind == "rwkv":
                total += 5 * d * d + d * d  # r,k,v,g,w projections + output
                total += 2 * 3 * d * d      # channel mix
            elif kind == "rglru":
                rd = self.rglru_dim or d
                total += 2 * d * rd + rd * d + self.conv_width * rd + 2 * rd
            # FFN
            if self.is_moe and i >= self.first_dense_layers and kind == "attn":
                e = self.num_experts
                total += d * e  # router
                total += e * 3 * d * self.moe_d_ff
                total += self.num_shared_experts * 3 * d * self.moe_d_ff
            elif kind in ("attn", "rglru"):
                total += 3 * d * self.d_ff
        if self.is_encoder_decoder:
            for _ in range(self.enc_layers):
                total += 4 * d * d + 3 * d * self.d_ff
            for _ in range(self.dec_layers):
                total += 8 * d * d + 3 * d * self.d_ff
        return total

    def active_param_count_estimate(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if not self.is_moe:
            return self.param_count_estimate()
        total = self.param_count_estimate()
        e, k = self.num_experts, self.experts_per_token
        L_moe = self.num_layers - self.first_dense_layers
        expert_params = 3 * self.d_model * self.moe_d_ff
        total -= L_moe * (e - k) * expert_params
        return total
