"""End-to-end serving driver (the paper's deployment): build a
DeepMapping store, stand up the batched LookupServer, and push mixed
batched request traffic through it — the paper-kind analogue of
"serve a small model with batched requests".

The server rides the unified query API: merged batches execute as
point plans, so projection pushdown (only the requested column's model
head runs) and — with ``--shards`` — the sharded thread-pool fan-out
apply to served traffic too.

    PYTHONPATH=src python examples/serve_lookup.py
    PYTHONPATH=src python examples/serve_lookup.py --shards 4
"""

import argparse

import numpy as np

import repro
from repro.core import DeepMappingConfig
from repro.core.trainer import TrainConfig
from repro.data import customer_demographics_like
from repro.serve import LookupServer


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--shards", type=int, default=1)
    args = ap.parse_args()

    table = customer_demographics_like(n=50_000)
    cluster = None
    if args.shards > 1:
        from repro.cluster import ClusterConfig

        cluster = ClusterConfig(num_shards=args.shards)
    store = repro.build(
        table,
        DeepMappingConfig(
            shared=(128, 64), private=(16,), residues=(2, 5, 7),
            train=TrainConfig(epochs=30, batch_size=8192),
        ),
        cluster=cluster,
        verbose=True,
    )
    server = LookupServer(store, max_batch=16384)

    rng = np.random.default_rng(0)
    # 40 concurrent requests of mixed sizes, some probing missing keys.
    requests = []
    for i in range(40):
        size = int(rng.integers(50, 2000))
        ks = rng.choice(table.keys, size=size)
        if i % 5 == 0:
            ks = np.concatenate([ks, table.max_key + rng.integers(1, 100, 10)])
        requests.append(ks)

    results = server.lookup_many(requests, columns=("cd_education_status",))
    hits = sum(int(e.sum()) for _, e in results)
    total = sum(len(r) for r in requests)
    print(f"\nserved {len(requests)} requests, {total:,} keys, {hits:,} hits")
    s = server.stats
    print(f"throughput: {s.qps():,.0f} keys/s "
          f"(infer {s.infer_s:.3f}s, exist {s.exist_s:.3f}s, "
          f"aux {s.aux_s:.3f}s, decode {s.decode_s:.3f}s, "
          f"batches {s.batches})")

    # the same traffic, expressed as one explicit plan
    res = (
        store.query()
        .select("cd_education_status")
        .where_keys(np.unique(np.concatenate(requests)))
        .execute()
    )
    print(f"plan: {' -> '.join(res.explain.plan)}")
    print(f"pushdown: heads skipped = {res.explain.heads_skipped}")

    # spot-check correctness against the source table
    req0, (vals0, e0) = requests[0], results[0]
    lut = dict(zip(table.keys.tolist(), table.columns["cd_education_status"]))
    for k, v, ex in zip(req0.tolist(), vals0["cd_education_status"], e0):
        if ex:
            assert lut[k] == v, (k, v, lut[k])
    print("correctness spot-check passed")


if __name__ == "__main__":
    main()
