"""Batched lookup serving engine — the paper's deployment scenario.

Requests (key batches) are queued, merged into device-sized batches,
deduplicated, sorted (so each T_aux partition is decompressed at most
once per batch — §IV-B2), answered via the hybrid store, and scattered
back to requesters.

Merged traffic rides the streaming operator pipeline
(:func:`repro.api.executor.stream_plan`): the merged unique-key batch
becomes ONE point plan whose morsel size is the server's ``max_batch``,
and the executor keeps a window of morsels' device work in flight
ahead of the host half — existence fallback, aux merge, decode,
scatter — so consecutive morsels overlap while device residency stays
bounded for arbitrarily large merged requests.  For baseline stores
the store hooks degenerate to plain synchronous calls (no device stage
to overlap), so the pipeline is a no-op there.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.api.executor import MORSEL_WINDOW, PlanStream, _stream_run
from repro.api.plan import QueryPlan
from repro.api.protocol import MappingStore


@dataclasses.dataclass
class ServeStats:
    """Serving-side rollup of the SAME stage accounting the executor
    produces (per-morsel ``ExplainStats`` plus the plan stream's
    route/cache evidence) — not an independently-measured field set.
    The full pipeline is covered: route (key-source/plan compile),
    infer/exist/aux/decode from the store hooks, filter (zero unless a
    predicate plan is served), gather (scatter-back to requesters).
    Everything here is also mirrored into the process metrics registry
    under ``deepmap_serve_*`` for export."""

    requests: int = 0
    keys: int = 0
    batches: int = 0
    total_s: float = 0.0
    route_s: float = 0.0
    infer_s: float = 0.0
    exist_s: float = 0.0
    aux_s: float = 0.0
    filter_s: float = 0.0
    decode_s: float = 0.0
    gather_s: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_bypass: int = 0

    def qps(self) -> float:
        return self.keys / self.total_s if self.total_s else 0.0


class LookupServer:
    """Merge-batch server over any :class:`~repro.api.protocol.MappingStore`
    (single, sharded, baseline, or federated).

    Merged batches execute through the streaming executor, so the
    server gets the unified pipeline — projection pushdown, sharded
    thread-pool fan-out, infer/aux overlap across consecutive morsels,
    per-morsel stats — for free; merged batches arrive at the store
    sorted, so the sharded store's scatter sees at most one contiguous
    run per shard.
    """

    def __init__(
        self,
        store: MappingStore,
        max_batch: int = 65536,
        on_error: str = "raise",
    ):
        self.store = store
        self.max_batch = max_batch
        #: 'raise' fails the whole merged batch on any owner failure;
        #: 'partial' serves the healthy owners' keys (unreachable keys
        #: report exists=False) — QueryPlan validates the mode.
        self.on_error = on_error
        self.stats = ServeStats()

    def lookup(
        self, keys: np.ndarray, columns: Optional[Tuple[str, ...]] = None
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Single-request path (still batched internally)."""
        return self.lookup_many([keys], columns)[0]

    def lookup_many(
        self,
        requests: List[np.ndarray],
        columns: Optional[Tuple[str, ...]] = None,
    ) -> List[Tuple[Dict[str, np.ndarray], np.ndarray]]:
        """Merge several key-batch requests into deduplicated device
        batches; scatter results back per request.  Device inference of
        morsel *i+1* overlaps the host half of morsel *i* (the
        streaming executor's window)."""
        if not requests:
            return []  # np.concatenate rejects an empty list
        t0 = time.perf_counter()
        reg = obs.registry()
        depth = reg.gauge(
            "deepmap_serve_queue_depth",
            "Requests currently being merged/answered by the server.",
        )
        depth.inc(len(requests))
        lens = [len(r) for r in requests]
        merged = np.concatenate([np.asarray(r, dtype=np.int64) for r in requests])
        uniq, inverse = np.unique(merged, return_inverse=True)  # sorted + dedup

        # One point plan over the merged uniques, morselized at the
        # server's batch size.  Columns pass straight through so
        # unknown names degrade to "ignored", like the legacy lookup
        # did; fanout=True keeps the sharded store's thread-pool
        # fan-out.  A zero-length merge still streams one empty morsel,
        # so callers get typed empty columns (same contract as the
        # stores' own zero-batch lookups).
        plan = QueryPlan(
            kind="point",
            keys=uniq,
            columns=tuple(columns) if columns is not None else None,
            fanout=True,
            morsel=self.max_batch,
            on_error=self.on_error,
        )
        chunks: Dict[str, List[np.ndarray]] = {}
        exists_u = np.zeros(uniq.shape[0], dtype=bool)
        # Drive the plan stream through an explicit PlanStream (rather
        # than the stream_plan convenience) so the server can read the
        # run's route time and plan-cache outcome — the ServeStats
        # fields are sourced from the executor's accounting, not
        # re-measured here.
        run = PlanStream(self.store, plan)
        for morsel in _stream_run(run, MORSEL_WINDOW):
            exists_u[morsel.start : morsel.start + morsel.exists.shape[0]] = (
                morsel.exists
            )
            for c, arr in morsel.values.items():
                chunks.setdefault(c, []).append(arr)
            self.stats.batches += 1
            self.stats.infer_s += morsel.stats.infer_s
            self.stats.exist_s += morsel.stats.exist_s
            self.stats.aux_s += morsel.stats.aux_s
            self.stats.filter_s += morsel.stats.filter_s
            self.stats.decode_s += morsel.stats.decode_s
        self.stats.route_s += run.route_s
        if run.cache_state == "hit":
            self.stats.cache_hits += 1
        elif run.cache_state == "miss":
            self.stats.cache_misses += 1
        else:
            self.stats.cache_bypass += 1
        # Gather: concatenate per column (rather than filling a
        # preallocated buffer) so chunks that disagree on dtype — e.g.
        # a baseline store's int placeholder chunk before a string
        # chunk — promote instead of crashing or truncating; then
        # scatter back to requesters.
        t_gather = time.perf_counter()
        vals_u = {c: np.concatenate(parts) for c, parts in chunks.items()}

        out: List[Tuple[Dict[str, np.ndarray], np.ndarray]] = []
        off = 0
        for n in lens:
            sel = inverse[off : off + n]
            out.append(({c: a[sel] for c, a in vals_u.items()}, exists_u[sel]))
            off += n
        elapsed_gather = time.perf_counter() - t_gather
        self.stats.gather_s += elapsed_gather
        self.stats.requests += len(requests)
        self.stats.keys += int(sum(lens))
        elapsed = time.perf_counter() - t0
        self.stats.total_s += elapsed
        depth.dec(len(requests))
        reg.counter(
            "deepmap_serve_requests_total", "Requests answered."
        ).inc(len(requests))
        reg.counter(
            "deepmap_serve_keys_total", "Keys looked up (pre-dedup)."
        ).inc(int(sum(lens)))
        reg.histogram(
            "deepmap_serve_batch_keys",
            "Unique keys per merged device batch.",
            buckets=obs.SIZE_BUCKETS,
        ).observe(int(uniq.shape[0]))
        lat = reg.histogram(
            "deepmap_serve_request_seconds",
            "Per-request latency (each merged request observes the "
            "merged batch's wall time — the caller-visible latency).",
        )
        for _ in range(len(requests)):
            lat.observe(elapsed)
        return out
