"""``ShardedDeepMappingStore`` — a fleet of per-partition DeepMapping
stores behind one ``DeepMappingStore``-shaped facade.

Rationale (ROADMAP north star; RMI's tree-of-models; NeurStore's
many-small-models storage): K small memorization MLPs each owning a
key partition build faster (parallel, independent training), retrain
locally (only dirty shards pay Algorithm-3/4/5 debt), and bound lookup
tail latency (each shard's aux table and bitvector stay small).

Invariants the router relies on:

* routing is a pure function of the key — a key's owning shard never
  changes between build and retrain (the partitioner is immutable);
* every key belongs to exactly ONE shard, so scatter/gather is a
  permutation and `(values, exists)` match a single store built on the
  same table (NULL rows carry per-shard placeholder values — callers
  must respect the ``exists`` mask, same contract as the single store);
* all shards charge decompressed partitions to one shared
  :class:`~repro.storage.pool.MemoryPool`, so cluster memory pressure
  is bounded globally, not per shard.

On-disk layout (atomic tmp+rename, shards reuse ``core/serialize.py``):

    cluster/
      manifest.msgpack   — version, partitioner state, shard dirs,
                           per-shard counters
      shard_00000/       — one ``core.serialize`` store directory
      shard_00001/
      ...
"""

from __future__ import annotations

import dataclasses
import os
import shutil
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.api.plan import ExplainStats, merge_agg_states
from repro.api.protocol import MappingStore
from repro.api.routing import LazyFanoutPool
from repro.cluster.partitioner import Partitioner, make_partitioner
from repro.cluster.router import ShardRouter
from repro.core.hybrid import DeepMappingConfig, DeepMappingStore
from repro.core.inference import EngineCache
from repro.core.serialize import (
    clean_stale_tmp,
    fsync_dir,
    load_store,
    pack_meta,
    read_artifact,
    save_store,
    unpack_meta,
)
from repro.core.table import Table
from repro.fault import injection as fault_injection
from repro.fault.errors import IntegrityError, OwnerFailure
from repro.fault.retry import DEFAULT_POLICY, RetryPolicy, call_guarded
from repro.storage import MemoryPool

#: v2 wraps the manifest in a crc32 envelope and records per-shard
#: columns/rows so quarantined shards keep the facade's accounting
#: coherent; v1 manifests still load (no verification, no quarantine
#: metadata).
MANIFEST_VERSION = 2


@dataclasses.dataclass
class _PendingShardedLookup:
    """Scattered lookup in flight: every shard's device inference is
    already enqueued (serial dispatch is cheap); collection gathers
    per-shard host halves, in parallel under fan-out."""

    keys: np.ndarray
    batches: list
    handles: list          # parallel to batches; (False, exc) on a
                           # dispatch-time failure (retried at collect)
    route_s: float
    use_fanout: bool
    columns: Optional[Tuple[str, ...]]
    predicates: tuple = ()
    keys_exist: bool = False
    on_error: str = "raise"
    #: True when device inference for this batch ran as ONE mesh
    #: shard-scatter launch (per-shard precomputed tickets) instead of
    #: per-shard dispatches; plan evidence reads ``mesh`` in place of
    #: ``fanout``/``serial``.
    mesh: bool = False


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Cluster-level knobs (per-shard knobs stay in DeepMappingConfig)."""

    num_shards: int = 4
    policy: str = "range"          # "range" (planner-balanced) | "hash"
    seed: int = 0                  # hash-policy mixing seed
    max_workers: Optional[int] = None  # build/retrain thread pool size
    #: Scatter device inference across a multi-device mesh when ≥ 2
    #: devices exist (``repro.cluster.mesh_scatter``); the thread-pool
    #: fan-out remains the fallback and the host-half path either way.
    #: Env kill-switch: ``REPRO_MESH_SCATTER=0``.
    mesh_scatter: bool = True


class _QuarantinedIndex:
    """Existence-index shim for a quarantined shard: every consult
    refuses loudly (scans/mutations must not silently skip the shard's
    keys)."""

    def __init__(self, owner: "QuarantinedShard"):
        self._owner = owner

    def keys_in_range(self, lo, hi):
        raise self._owner.refusal()

    def test(self, keys):
        raise self._owner.refusal()


class _QuarantinedAux:
    """Aux-table shim: zero rows, so fleet accounting stays additive."""

    num_rows = 0


class _QuarantinedSpec:
    """Spec shim carrying the column names recorded in the manifest."""

    def __init__(self, tasks: Tuple[str, ...]):
        self.tasks = tasks


class QuarantinedShard:
    """Placeholder for a shard whose on-disk artifacts failed checksum
    verification at load (``load_sharded_store(..., on_corrupt=
    'quarantine')``).

    The cluster facade stays serviceable over the healthy K-1 shards:
    point lookups routed here fail as a structured owner failure —
    degradable via ``Query.on_error('partial')`` — while scans and
    mutations touching this shard's key range raise
    :class:`~repro.fault.errors.IntegrityError` loudly (a scan that
    silently dropped a shard's rows would be a wrong answer, not a
    degraded one).  Accounting (rows from the manifest, zero bytes)
    keeps fleet totals coherent; re-saving a cluster holding one of
    these refuses, so a corrupt shard can never be laundered back to
    disk as healthy."""

    def __init__(
        self,
        shard_id: int,
        reason: str,
        columns: Tuple[str, ...] = (),
        num_rows: int = 0,
    ):
        self.shard_id = int(shard_id)
        self.reason = str(reason)
        self.spec = _QuarantinedSpec(tuple(columns))
        self.num_rows = int(num_rows)
        self.raw_bytes = 0
        self.modified_bytes = 0
        self.vexist = _QuarantinedIndex(self)
        self.aux = _QuarantinedAux()

    def refusal(self) -> IntegrityError:
        return IntegrityError(
            f"shard {self.shard_id} is quarantined (corrupt at load: "
            f"{self.reason}); restore it from a replica or rebuild, or "
            f"use Query.on_error('partial') for point lookups over the "
            f"healthy shards"
        )

    # Protocol surface: every data path refuses with the same evidence.
    def _dispatch_lookup(self, keys, columns=None, **kwargs):
        raise self.refusal()

    def _collect_lookup(self, pending):
        raise self.refusal()

    def insert(self, keys, columns):
        raise self.refusal()

    def delete(self, keys):
        raise self.refusal()

    def update(self, keys, columns):
        raise self.refusal()

    def retrain(self, verbose: bool = False):
        raise self.refusal()

    def materialize(self):
        raise self.refusal()

    # Accounting/bookkeeping surface the facade aggregates over.
    def mutation_version(self) -> int:
        return 0

    def should_retrain(self) -> bool:
        return False

    def size_breakdown(self) -> Dict[str, int]:
        return {}


class ShardedDeepMappingStore(MappingStore):
    """K independent :class:`DeepMappingStore` shards behind a router.

    Conforms to the :class:`~repro.api.protocol.MappingStore` protocol —
    drop-in for the single store everywhere the serving layer cares.
    Plan execution (``store.query()``) fans per-shard lookups out on a
    thread pool so scatter/gather overlaps per-shard inference; the
    legacy ``lookup`` shim stays serial for bit-for-bit continuity.
    """

    def __init__(
        self,
        partitioner: Partitioner,
        shards: List[DeepMappingStore],
        cluster: ClusterConfig,
        pool: MemoryPool,
        retry: RetryPolicy = DEFAULT_POLICY,
    ):
        if partitioner.num_shards != len(shards):
            raise ValueError(
                f"partitioner maps to {partitioner.num_shards} shards, "
                f"got {len(shards)} stores"
            )
        self.partitioner = partitioner
        self.router = ShardRouter(partitioner)
        self.shards = shards
        self.cluster = cluster
        self.pool = pool
        self.retry = retry
        self._fanout = LazyFanoutPool(cluster.max_workers, "shard-lookup")
        # Mesh scatter runner, built lazily on first eligible dispatch
        # (touching jax device state at construction would make simply
        # *holding* a cluster initialize a backend).
        self._mesh_runner_cache: object = None
        self._mesh_probed = False
        # One engine cache for the fleet: shard engines share a single
        # EngineStats, so identical (architecture, bucket) signatures
        # count as ONE compile cluster-wide and operators read one
        # counter set.  Shards warm from build keep their weight caches.
        self.engines = EngineCache()
        for s in shards:
            if not isinstance(s, QuarantinedShard):
                self.engines.adopt(s)

    # ------------------------------------------------------------------ build
    @classmethod
    def build(
        cls,
        table: Table,
        config: DeepMappingConfig = DeepMappingConfig(),
        cluster: ClusterConfig = ClusterConfig(),
        pool: Optional[MemoryPool] = None,
        verbose: bool = False,
    ) -> "ShardedDeepMappingStore":
        """Partition ``table`` and train every shard (thread pool).

        The planner may return fewer than ``cluster.num_shards`` shards
        on tiny/degenerate tables (quantile boundaries collapse); hash
        partitioning of a small table raises if a shard would be empty
        — lower ``num_shards`` or use the range policy there.
        """
        partitioner = make_partitioner(
            cluster.policy, table.keys, cluster.num_shards, seed=cluster.seed
        )
        pool = pool if pool is not None else MemoryPool(1 << 30)
        router = ShardRouter(partitioner)
        batches = {b.shard_id: b for b in router.scatter(table.keys)}
        missing = [i for i in range(partitioner.num_shards) if i not in batches]
        if missing:
            raise ValueError(
                f"shards {missing} would be empty; lower num_shards or "
                f"use the 'range' policy (planner guarantees non-empty)"
            )
        sub_tables = [
            table.take(batches[i].positions) for i in range(partitioner.num_shards)
        ]

        def build_one(i: int) -> DeepMappingStore:
            return DeepMappingStore.build(
                sub_tables[i], config, pool=pool, verbose=False
            )

        with ThreadPoolExecutor(max_workers=cluster.max_workers) as ex:
            shards = list(ex.map(build_one, range(partitioner.num_shards)))
        store = cls(partitioner, shards, cluster, pool)
        if verbose:
            rows = [s.num_rows for s in shards]
            print(
                f"[cluster] built {len(shards)} {cluster.policy} shards, "
                f"rows/shard min={min(rows)} max={max(rows)}, "
                f"ratio {store.compression_ratio():.4f}"
            )
        return store

    # ---------------------------------------------------------------- lookup
    @property
    def columns(self) -> Tuple[str, ...]:
        return self._healthy_shard().spec.tasks

    def _healthy_shard(self):
        """First non-quarantined shard (delegation target for typed
        zero-batch probes and column metadata)."""
        for s in self.shards:
            if not isinstance(s, QuarantinedShard):
                return s
        return self.shards[0]

    def quarantined_shards(self) -> List[int]:
        """Shard ids refused at load for failing checksum verification."""
        return [
            i for i, s in enumerate(self.shards)
            if isinstance(s, QuarantinedShard)
        ]

    def _dispatch_lookup(
        self,
        keys: np.ndarray,
        columns: Optional[Tuple[str, ...]] = None,
        fanout: Optional[bool] = None,
        predicates: tuple = (),
        keys_exist: bool = False,
        on_error: str = "raise",
    ) -> _PendingShardedLookup:
        """Scatter the batch and enqueue every shard's device inference
        (cheap serial dispatch — the device work itself overlaps);
        ``_collect_lookup`` gathers the host halves.  ``predicates``
        push down into every shard (code-level argmax filtering), so a
        scattered predicate plan never decodes a non-matching row on
        any shard; ``keys_exist`` forwards to every shard.

        A shard whose dispatch itself raises (a dying device engine)
        does not kill the plan here: the failure is captured in the
        handle slot and retried — then degraded around or surfaced as
        :class:`OwnerFailure`, per ``on_error`` — at collect time."""
        keys = np.asarray(keys, dtype=np.int64)
        t0 = time.perf_counter()
        batches = self.router.scatter(keys)
        route_s = time.perf_counter() - t0
        use_fanout = bool(fanout) and len(batches) > 1
        mesh_tickets = self._mesh_tickets(batches)
        handles = []
        for b in batches:
            try:
                shard = self.shards[b.shard_id]
                if mesh_tickets is not None and b.shard_id in mesh_tickets:
                    handles.append((True, shard._dispatch_precomputed(
                        b.keys, mesh_tickets[b.shard_id], columns, predicates,
                    )))
                else:
                    handles.append((True, shard._dispatch_lookup(
                        b.keys, columns, predicates=predicates,
                        keys_exist=keys_exist,
                    )))
            except Exception as exc:  # captured; retried at collect
                handles.append((False, exc))
        return _PendingShardedLookup(
            keys=keys, batches=batches, handles=handles, route_s=route_s,
            use_fanout=use_fanout, columns=columns, predicates=predicates,
            keys_exist=keys_exist, on_error=on_error,
            mesh=mesh_tickets is not None,
        )

    def _mesh_tickets(self, batches) -> Optional[dict]:
        """Precomputed per-shard inference tickets via the device mesh,
        or None (thread-pool path).  Any mesh failure degrades to None
        with a warning + counter — never a failed plan: the per-shard
        dispatch below answers the same batch."""
        if len(batches) < 2 or not self._mesh_enabled():
            return None
        runner = self._mesh_runner()
        if runner is None:
            return None
        try:
            return runner.tickets(batches)
        except Exception as exc:
            obs.counter(
                "deepmap_mesh_scatter_failures_total",
                "Mesh scatter launches degraded to the thread pool.",
            ).inc()
            warnings.warn(f"mesh scatter failed, using thread pool: {exc}")
            return None

    def _mesh_enabled(self) -> bool:
        if not self.cluster.mesh_scatter:
            return False
        return os.environ.get("REPRO_MESH_SCATTER", "").strip() != "0"

    def _mesh_runner(self):
        """Lazily built :class:`~repro.cluster.mesh_scatter.
        MeshShardRunner` (None when < 2 devices or the fleet is not
        stackable).  Probed once; retrain-driven drift is re-validated
        per launch inside the runner, which degrades to None."""
        if not self._mesh_probed:
            from repro.cluster.mesh_scatter import MeshShardRunner

            self._mesh_runner_cache = MeshShardRunner.maybe_build(self.shards)
            self._mesh_probed = True
        return self._mesh_runner_cache

    def _collect_lookup(
        self, pending: _PendingShardedLookup
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray, Optional[np.ndarray], ExplainStats]:
        keys, batches = pending.keys, pending.batches
        route_s, use_fanout = pending.route_s, pending.use_fanout
        preds = pending.predicates
        if not batches:
            # Zero-length request: delegate to one healthy shard for
            # typed empty columns + per-head stats (no scatter, no
            # inference).
            probe_shard = self._healthy_shard()
            values, exists, match, stats = probe_shard._collect_lookup(
                probe_shard._dispatch_lookup(
                    keys[:0], pending.columns, predicates=preds
                )
            )
            stats.plan = ("scatter[0]",) + stats.plan
            stats.route_s += route_s
            exists = np.zeros(keys.shape[0], dtype=bool)
            return values, exists, exists.copy() if preds else None, stats

        def visit(batch_handle):
            batch, (ok, payload) = batch_handle
            shard = self.shards[batch.shard_id]
            owner = f"shard:{batch.shard_id}"

            def attempt(i: int):
                # Injection site sits inside the guarded attempt so a
                # `times=1` spec fails attempt 0 and the retry recovers.
                fault_injection.maybe_fail("shard_collect", owner)
                if i == 0:
                    if not ok:
                        raise payload  # dispatch-time failure = try 0
                    handle = payload
                else:
                    # The first try consumed (part of) the dispatched
                    # handle; retries re-dispatch fresh.
                    handle = shard._dispatch_lookup(
                        batch.keys, pending.columns,
                        predicates=preds, keys_exist=pending.keys_exist,
                    )
                return shard._collect_lookup(handle)

            t0 = time.perf_counter()
            outcome = call_guarded(
                attempt, owner=owner, site="shard_collect", policy=self.retry
            )
            t1 = time.perf_counter()
            # Per-shard telemetry, labeled by shard id — emitted from
            # the fan-out pool threads, which is exactly why the
            # registry (and PlanCache) increments are locked.
            reg = obs.registry()
            reg.counter(
                "deepmap_shard_visits_total", "Lookup batches per shard."
            ).inc(shard=batch.shard_id)
            if not outcome.ok:
                return batch, None, None, None, None, outcome
            reg.counter(
                "deepmap_shard_keys_total", "Keys answered per shard."
            ).inc(int(batch.keys.shape[0]), shard=batch.shard_id)
            reg.histogram(
                "deepmap_shard_collect_seconds",
                "Per-shard collect (host-half) latency.",
            ).observe(t1 - t0, shard=batch.shard_id)
            obs.tracer().add_span(
                "shard_collect", t0, t1, track="shards",
                shard=batch.shard_id, rows=int(batch.keys.shape[0]),
            )
            vals, exists, match, stats = outcome.value
            return batch, vals, exists, match, stats, outcome

        pairs = list(zip(batches, pending.handles))
        if use_fanout:
            parts = self._fanout.map(visit, pairs, owners=len(self.shards))
        else:
            parts = [visit(p) for p in pairs]

        healthy = [p for p in parts if p[5].ok]
        errors = tuple(p[5].error for p in parts if not p[5].ok)
        if errors and (pending.on_error != "partial" or not healthy):
            # 'raise' mode, or nothing survived to degrade to — either
            # way the structured owner evidence rides on the exception.
            raise OwnerFailure(errors)

        agg = ExplainStats(
            shards_visited=len(batches),
            shard_ids=tuple(int(b.shard_id) for b in batches),
            async_fanout=use_fanout,
            route_s=route_s,
            retries=sum(p[5].retries for p in parts),
            owners_failed=tuple(e.describe() for e in errors),
            keys_unresolved=sum(
                int(p[0].keys.shape[0]) for p in parts if not p[5].ok
            ),
        )
        for p in healthy:
            # merge_timings unions the pushdown evidence tuples, so a
            # shard that skipped different heads/columns than its peers
            # cannot make the aggregate under-report.
            agg.merge_timings(p[4])
        agg.plan = (
            f"scatter[{len(batches)} shards]",
            "mesh" if pending.mesh else ("fanout" if use_fanout else "serial"),
        ) + healthy[0][4].plan

        t1 = time.perf_counter()
        if errors:
            values, exists, _covered = ShardRouter.gather_partial(
                keys.shape[0], [(b, v, e) for b, v, e, _, _, _ in healthy]
            )
        else:
            values, exists = ShardRouter.gather(
                keys.shape[0], [(b, v, e) for b, v, e, _, _, _ in healthy]
            )
        match = None
        if preds:
            # Failed shards' positions stay False: unreachable rows are
            # excluded from filtered results (evidence keeps the count).
            match = np.zeros(keys.shape[0], dtype=bool)
            for b, _, _, m, _, _ in healthy:
                match[b.positions] = m
        agg.route_s += time.perf_counter() - t1
        return values, exists, match, agg

    def _collect_aggregate(self, pending: _PendingShardedLookup, group_by, aggregates):
        """Scattered ``group_by(...).agg(...)``: every shard folds its
        batch in code space (:meth:`DeepMappingStore._collect_aggregate`
        — zero rows decoded), and the facade merges the per-shard
        partial states.  States key on decoded group values, so shards
        with independent codecs (codes are NOT comparable across
        shards) merge exactly.  Failed shards degrade under
        ``on_error='partial'`` with the usual ``owners_failed``/
        ``keys_unresolved`` evidence — their batches' rows are simply
        absent from every group."""
        keys, batches = pending.keys, pending.batches
        route_s, use_fanout = pending.route_s, pending.use_fanout
        preds = pending.predicates
        if not batches:
            probe_shard = self._healthy_shard()
            state, stats = probe_shard._collect_aggregate(
                probe_shard._dispatch_lookup(
                    keys[:0], pending.columns, predicates=preds
                ),
                group_by, aggregates,
            )
            stats.plan = ("scatter[0]",) + stats.plan
            stats.route_s += route_s
            return state, stats

        def visit(batch_handle):
            batch, (ok, payload) = batch_handle
            shard = self.shards[batch.shard_id]
            owner = f"shard:{batch.shard_id}"

            def attempt(i: int):
                fault_injection.maybe_fail("shard_collect", owner)
                if i == 0:
                    if not ok:
                        raise payload  # dispatch-time failure = try 0
                    handle = payload
                else:
                    handle = shard._dispatch_lookup(
                        batch.keys, pending.columns,
                        predicates=preds, keys_exist=pending.keys_exist,
                    )
                return shard._collect_aggregate(handle, group_by, aggregates)

            outcome = call_guarded(
                attempt, owner=owner, site="shard_collect", policy=self.retry
            )
            obs.registry().counter(
                "deepmap_shard_visits_total", "Lookup batches per shard."
            ).inc(shard=batch.shard_id)
            if not outcome.ok:
                return batch, None, None, outcome
            state, stats = outcome.value
            return batch, state, stats, outcome

        pairs = list(zip(batches, pending.handles))
        if use_fanout:
            parts = self._fanout.map(visit, pairs, owners=len(self.shards))
        else:
            parts = [visit(p) for p in pairs]

        healthy = [p for p in parts if p[3].ok]
        errors = tuple(p[3].error for p in parts if not p[3].ok)
        if errors and (pending.on_error != "partial" or not healthy):
            raise OwnerFailure(errors)

        agg = ExplainStats(
            shards_visited=len(batches),
            shard_ids=tuple(int(b.shard_id) for b in batches),
            async_fanout=use_fanout,
            route_s=route_s,
            retries=sum(p[3].retries for p in parts),
            owners_failed=tuple(e.describe() for e in errors),
            keys_unresolved=sum(
                int(p[0].keys.shape[0]) for p in parts if not p[3].ok
            ),
        )
        state: Dict[tuple, list] = {}
        for p in healthy:
            agg.merge_timings(p[2])
            merge_agg_states(state, p[1], aggregates)
        agg.plan = (
            f"scatter[{len(batches)} shards]",
            "mesh" if pending.mesh else ("fanout" if use_fanout else "serial"),
        ) + healthy[0][2].plan
        return state, agg

    def _lookup_with_stats(
        self,
        keys: np.ndarray,
        columns: Optional[Tuple[str, ...]] = None,
        fanout: Optional[bool] = None,
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray, ExplainStats]:
        """Algorithm 1, scattered: route each key to its shard, answer
        per-shard batches (in parallel when ``fanout``), gather results
        back in request order — the dispatch/collect pair back-to-back."""
        values, exists, _, stats = self._collect_lookup(
            self._dispatch_lookup(keys, columns, fanout)
        )
        return values, exists, stats

    def lookup(
        self, keys: np.ndarray, columns: Optional[Tuple[str, ...]] = None
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Legacy serial shim (prefer ``store.query()``, whose executor
        fans out and returns per-plan ``ExplainStats``)."""
        values, exists, _stats = self._lookup_with_stats(keys, columns, fanout=False)
        return values, exists

    def _range_keys(self, lo: int, hi: Optional[int]) -> np.ndarray:
        """Range scatter (§IV-E): only shards whose ranges overlap
        ``[lo, hi)`` scan their existence index (all shards under hash
        partitioning), in parallel on the fan-out pool; merged
        ascending.  ``hi=None`` scans all shards unbounded (the scan
        plan's key source)."""
        if hi is None:
            sids: List[int] = list(range(len(self.shards)))
        else:
            sids = [int(s) for s in self.partitioner.shards_for_range(int(lo), int(hi))]

        def scan_one(s: int) -> np.ndarray:
            return self.shards[s].vexist.keys_in_range(lo, hi)

        if len(sids) > 1:
            parts = self._fanout.map(scan_one, sids, owners=len(self.shards))
        else:
            parts = [scan_one(s) for s in sids]
        parts = [p for p in parts if p.size]
        if not parts:
            return np.zeros(0, dtype=np.int64)
        merged = np.concatenate(parts)
        if self.partitioner.policy != "range":
            # Range shards are disjoint and visited in key order, so
            # their concatenation is already ascending; hash shards
            # interleave the domain and need the sort.
            merged = np.sort(merged, kind="stable")
        return merged

    # ------------------------------------------------ modifications (Alg 3-5)
    def insert(self, keys: np.ndarray, columns: Dict[str, np.ndarray]) -> None:
        """Algorithm 3 per shard.  Validates against ALL shards before
        mutating ANY, so a duplicate key cannot leave the cluster
        half-inserted."""
        keys = np.asarray(keys, dtype=np.int64)
        if np.unique(keys).size != keys.size:
            # Checked at the facade: a per-shard duplicate raise could
            # otherwise leave earlier shards mutated.
            raise ValueError("duplicate keys in insert batch")
        batches = self.router.scatter(keys)
        for b in batches:
            if self.shards[b.shard_id].vexist.test(b.keys).any():
                raise ValueError("insert of existing key; use update()")
        for b in batches:
            self.shards[b.shard_id].insert(
                b.keys, ShardRouter.take_columns(columns, b.positions)
            )
        self._note_mutation()

    def delete(self, keys: np.ndarray) -> None:
        """Algorithm 4 per shard (idempotent, like the single store)."""
        keys = np.asarray(keys, dtype=np.int64)
        for b in self.router.scatter(keys):
            self.shards[b.shard_id].delete(b.keys)
        self._note_mutation()

    def update(self, keys: np.ndarray, columns: Dict[str, np.ndarray]) -> None:
        """Algorithm 5 per shard; all-exist validated before mutating."""
        keys = np.asarray(keys, dtype=np.int64)
        batches = self.router.scatter(keys)
        for b in batches:
            if not self.shards[b.shard_id].vexist.test(b.keys).all():
                raise ValueError("update of non-existing key; use insert()")
        for b in batches:
            self.shards[b.shard_id].update(
                b.keys, ShardRouter.take_columns(columns, b.positions)
            )
        self._note_mutation()

    def mutation_version(self):
        """Facade counter + per-shard tokens: direct mutations of a
        shard (bypassing the facade) still invalidate cached plans, and
        the facade bump on :meth:`retrain` keeps a rebuilt shard's
        reset counter from colliding with an earlier cluster state."""
        return (
            getattr(self, "_mutation_version", 0),
            tuple(s.mutation_version() for s in self.shards),
        )

    # ------------------------------------------------------- lazy retrain
    def dirty_shards(self) -> List[int]:
        """Shard ids whose modified-bytes debt crossed the threshold."""
        return [i for i, s in enumerate(self.shards) if s.should_retrain()]

    def should_retrain(self) -> bool:
        return bool(self.dirty_shards())

    def retrain(
        self, shard_ids: Optional[Sequence[int]] = None, verbose: bool = False
    ) -> List[int]:
        """Rebuild ONLY the given (default: dirty) shards, in place.

        This is the sharding payoff over the single store's whole-
        relation retrain: modification debt is paid per partition.
        Returns the retrained shard ids.
        """
        ids = list(shard_ids) if shard_ids is not None else self.dirty_shards()

        def retrain_one(i: int) -> DeepMappingStore:
            return self.shards[i].retrain(verbose=False)

        if ids:
            with ThreadPoolExecutor(max_workers=self.cluster.max_workers) as ex:
                rebuilt = list(ex.map(retrain_one, ids))
            for i, store in zip(ids, rebuilt):
                self.shards[i] = store
                self.engines.adopt(store)  # rebuilt shard joins fleet stats
            self._note_mutation()  # a fresh shard's reset counter must
            # not recreate an earlier cluster-wide version token
        if verbose:
            print(f"[cluster] retrained shards {ids}")
        return ids

    # ------------------------------------------------------------- teardown
    def close(self) -> None:
        """Release the lookup fan-out pool's threads (idempotent; the
        store remains usable — a later fan-out lazily re-creates the
        pool).  Without it, pool threads live until interpreter exit."""
        self._fanout.close()

    def __enter__(self) -> "ShardedDeepMappingStore":
        """Context-manager entry; :meth:`close` runs on exit."""
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        """Close the fan-out pool on scope exit."""
        self.close()

    # ---------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        """Protocol persistence — the manifest directory-of-stores
        format (atomic tmp+rename)."""
        save_sharded_store(self, path)

    @classmethod
    def load(
        cls,
        path: str,
        pool: Optional[MemoryPool] = None,
        on_corrupt: str = "raise",
    ) -> "ShardedDeepMappingStore":
        return load_sharded_store(path, pool=pool, on_corrupt=on_corrupt)

    def materialize(self) -> Table:
        """Reconstruct the full logical table, ascending key order."""
        tables = [s.materialize() for s in self.shards]
        keys = np.concatenate([t.keys for t in tables])
        order = np.argsort(keys, kind="stable")
        columns = {
            name: np.concatenate([t.columns[name] for t in tables])[order]
            for name in tables[0].columns
        }
        return Table(keys=keys[order], columns=columns)

    # ------------------------------------------------------------- accounting
    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @property
    def num_rows(self) -> int:
        return sum(s.num_rows for s in self.shards)

    @property
    def raw_bytes(self) -> int:
        return sum(s.raw_bytes for s in self.shards)

    @property
    def modified_bytes(self) -> int:
        return sum(s.modified_bytes for s in self.shards)

    def size_breakdown(self) -> Dict[str, int]:
        total: Dict[str, int] = {}
        for s in self.shards:
            for k, v in s.size_breakdown().items():
                total[k] = total.get(k, 0) + v
        return total

    def size_bytes(self) -> int:
        return sum(self.size_breakdown().values())

    def compression_ratio(self) -> float:
        return self.size_bytes() / max(1, self.raw_bytes)

    def memorized_fraction(self) -> float:
        aux_rows = sum(s.aux.num_rows for s in self.shards)
        return 1.0 - aux_rows / max(1, self.num_rows)


# ------------------------------------------------------------- serialization
def save_sharded_store(store: ShardedDeepMappingStore, path: str) -> None:
    """Directory-of-stores format: manifest + one ``core.serialize``
    directory per shard.  Atomic (tmp + rename), like the single-store
    format; the manifest is written LAST, crc32-enveloped, after every
    shard directory landed (a manifest's presence marks the save
    complete)."""
    bad = store.quarantined_shards()
    if bad:
        raise IntegrityError(
            f"refusing to save: shards {bad} are quarantined (corrupt at "
            f"load) — saving would persist placeholders as data loss"
        )
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    shard_dirs = [f"shard_{i:05d}" for i in range(store.num_shards)]
    for shard, d in zip(store.shards, shard_dirs):
        save_store(shard, os.path.join(tmp, d))

    manifest = {
        "version": MANIFEST_VERSION,
        "partitioner": store.partitioner.to_state(),
        "cluster": {
            "num_shards": store.num_shards,
            "policy": store.cluster.policy,
            "seed": store.cluster.seed,
            # governs build/retrain AND lookup fan-out pools — an
            # operator's concurrency cap must survive reload
            "max_workers": store.cluster.max_workers,
        },
        "shards": shard_dirs,
        # Quarantine metadata: lets a QuarantinedShard placeholder keep
        # the facade's columns and row accounting coherent when one
        # shard directory fails verification on a later load.
        "columns": list(store.columns),
        "shard_rows": [int(s.num_rows) for s in store.shards],
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(pack_meta(manifest))
        f.flush()
        os.fsync(f.fileno())
    fsync_dir(tmp)

    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    fsync_dir(os.path.dirname(os.path.abspath(path)))


def load_sharded_store(
    path: str,
    pool: Optional[MemoryPool] = None,
    on_corrupt: str = "raise",
) -> ShardedDeepMappingStore:
    """Load a saved cluster, verifying every shard's checksums.

    ``on_corrupt='raise'`` (default) propagates the first shard's
    :class:`~repro.fault.errors.IntegrityError`; ``'quarantine'``
    replaces corrupt shards with :class:`QuarantinedShard` placeholders
    — the healthy K-1 shards keep serving (point lookups degrade via
    ``Query.on_error('partial')``), each quarantine warns and counts
    into ``deepmap_fault_quarantines_total`` — and still raises when
    EVERY shard is corrupt (nothing left to serve)."""
    if on_corrupt not in ("raise", "quarantine"):
        raise ValueError(
            f"on_corrupt must be 'raise' or 'quarantine', got {on_corrupt!r}"
        )
    clean_stale_tmp(path)
    manifest = unpack_meta(
        read_artifact(path, "manifest.msgpack", None),
        os.path.join(path, "manifest.msgpack"),
    )
    if manifest["version"] > MANIFEST_VERSION:
        raise ValueError(f"cluster manifest {manifest['version']} newer than reader")
    pool = pool if pool is not None else MemoryPool(1 << 30)
    partitioner = Partitioner.from_state(manifest["partitioner"])
    columns = tuple(manifest.get("columns", ()))
    shard_dirs = manifest["shards"]
    shard_rows = manifest.get("shard_rows", [0] * len(shard_dirs))
    shards: List[DeepMappingStore] = []
    corrupt = 0
    for i, d in enumerate(shard_dirs):
        try:
            shards.append(load_store(os.path.join(path, d), pool=pool))
        except (IntegrityError, OSError, ValueError, KeyError) as err:
            if on_corrupt != "quarantine":
                raise
            corrupt += 1
            warnings.warn(
                f"quarantining shard {i} ({os.path.join(path, d)}): {err}",
                RuntimeWarning,
                stacklevel=2,
            )
            owner = f"shard:{i}"  # bounded by the manifest's shard count
            obs.registry().counter(
                "deepmap_fault_quarantines_total",
                "Owners quarantined (consecutive failures, or corrupt "
                "artifacts at load).",
            ).inc(owner=owner)
            shards.append(
                QuarantinedShard(
                    i, str(err), columns=columns, num_rows=int(shard_rows[i])
                )
            )
    if corrupt and corrupt == len(shard_dirs):
        raise IntegrityError(
            f"every shard of {path!r} failed verification; nothing to serve"
        )
    cluster = ClusterConfig(
        num_shards=manifest["cluster"]["num_shards"],
        policy=manifest["cluster"]["policy"],
        seed=manifest["cluster"]["seed"],
        # .get: PR-1-era manifests predate the field
        max_workers=manifest["cluster"].get("max_workers"),
    )
    return ShardedDeepMappingStore(partitioner, shards, cluster, pool)
