"""Cross-store federation: one plan over several member stores.

:class:`FederatedStore` composes N :class:`~repro.api.protocol.MappingStore`
members — any mix of DeepMapping, sharded, and baseline stores —
behind the same protocol surface, so every query-layer feature (plans,
projection + predicate pushdown, the streaming executor, the serving
engine) runs unchanged against the federation.  Two composition modes:

* ``mode="partition"`` — members own **disjoint key ranges** split at
  ``boundaries`` (sorted ints, one fewer than members; member *i* owns
  ``[boundaries[i-1], boundaries[i])`` with open ends).  Lookups
  scatter per member and gather back in request order; range/scan key
  sources concatenate the members' ascending streams; mutations route
  to the owning member.  E.g. two sharded clusters over disjoint key
  spaces behind one facade.

* ``mode="replicate"`` — every member holds the **same relation**
  (e.g. a DeepMapping primary + a HashStore replica).  Each dispatched
  morsel is answered by ONE member: ``policy="primary"`` always asks
  member 0 (deterministic), ``policy="round_robin"`` rotates members
  per dispatch so a morsel stream load-balances across replicas while
  earlier morsels' host halves are still draining.  Mutations apply to
  every member, keeping replicas in sync.

Federation invariants:

* members expose identical column sets (checked at construction);
* partition members' key ranges are disjoint by construction — a key
  is answered by exactly one member, so scatter/gather is a
  permutation (the sharded-cluster invariant, one level up);
* replicate members agree on content (the caller's responsibility —
  e.g. built from one table or kept in sync through the facade);
  *values* equality across replicas is semantic, not byte-level
  (different store types may decode to different dtypes).

A federation is a runtime composition, not a storage format: ``save``
is intentionally unsupported — persist the members individually and
recompose.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.api.plan import ExplainStats, merge_agg_states
from repro.api.protocol import MappingStore
from repro.api.routing import (
    LazyFanoutPool,
    gather_parts,
    gather_parts_partial,
    group_runs,
)
from repro.fault import injection as fault_injection
from repro.fault.errors import OwnerFailure
from repro.fault.health import HealthPolicy, HealthTracker
from repro.fault.retry import DEFAULT_POLICY, RetryPolicy, call_guarded

MODES = ("partition", "replicate")
POLICIES = ("primary", "round_robin")

#: Replicate-mode behaviour for mutations while a replica is
#: quarantined: ``"reject"`` raises (no member mutates, replicas never
#: diverge); ``"queue"`` buffers the op and applies it — in order —
#: once every replica is healthy again (:meth:`FederatedStore
#: .flush_mutations`, also attempted before the next mutation).
MUTATION_POLICIES = ("reject", "queue")


class _PendingFederatedLookup:
    """Per-member dispatches in flight for one request batch."""

    __slots__ = (
        "keys", "parts", "route_s", "predicates", "member_ids", "use_fanout",
        "columns", "keys_exist", "on_error",
    )

    def __init__(self, keys, parts, route_s, predicates, member_ids,
                 use_fanout, columns, keys_exist, on_error):
        self.keys = keys
        self.parts = parts          # [(member, positions, (ok, payload))]
        self.route_s = route_s
        self.predicates = predicates
        self.member_ids = member_ids
        self.use_fanout = use_fanout
        self.columns = columns
        self.keys_exist = keys_exist
        self.on_error = on_error


class FederatedStore(MappingStore):
    """One logical store over several member stores (see module doc)."""

    def __init__(
        self,
        members: Sequence[MappingStore],
        mode: str = "partition",
        boundaries: Optional[Sequence[int]] = None,
        policy: str = "primary",
        retry: RetryPolicy = DEFAULT_POLICY,
        health: HealthPolicy = HealthPolicy(),
        mutation_policy: str = "reject",
    ):
        if not members:
            raise ValueError("federation needs at least one member store")
        if mode not in MODES:
            raise ValueError(f"unknown federation mode {mode!r}; have {MODES}")
        if policy not in POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; have {POLICIES}")
        if mutation_policy not in MUTATION_POLICIES:
            raise ValueError(
                f"unknown mutation policy {mutation_policy!r}; "
                f"have {MUTATION_POLICIES}"
            )
        cols = tuple(members[0].columns)
        for i, m in enumerate(members[1:], 1):
            # set equality: different store types canonicalize column
            # ORDER differently (MLPSpec sorts tasks, baselines keep
            # table order); values are keyed by name, so order is
            # presentation only and member 0's wins.
            if set(m.columns) != set(cols):
                raise ValueError(
                    f"member {i} columns {tuple(m.columns)} != member 0 "
                    f"columns {cols}; federation needs one schema"
                )
        if mode == "partition":
            if boundaries is None or len(boundaries) != len(members) - 1:
                raise ValueError(
                    "partition mode needs len(members)-1 sorted boundaries"
                )
            b = [int(x) for x in boundaries]
            if sorted(b) != b:
                raise ValueError(f"boundaries must be ascending: {b}")
            self.boundaries = np.asarray(b, dtype=np.int64)
        else:
            if boundaries is not None:
                raise ValueError("replicate mode takes no boundaries")
            self.boundaries = None
        self.members = list(members)
        self.mode = mode
        self.policy = policy
        self.retry = retry
        self.mutation_policy = mutation_policy
        self.health = HealthTracker(health)
        self._columns = cols
        self._names = tuple(f"member:{i}" for i in range(len(members)))
        self._rr = 0  # round-robin cursor (replicate mode)
        # Replicate-mode mutations deferred under mutation_policy=
        # "queue" while a replica is quarantined: [(op, keys, columns)].
        # Mutations are caller-serialized (same contract as the
        # members'), so no lock.
        self._mutation_queue: List[Tuple[str, np.ndarray, Optional[Dict]]] = []
        # Morsel-parallel collect: member host halves gather on the
        # same lazy fan-out pool machinery the sharded store uses.
        self._fanout = LazyFanoutPool(None, "fed-collect")
        # One PlanCache across the federation: a predicate/aggregate
        # code table compiled against one member's decode map is
        # content-matched (PlanCache._table_memo) and reused by every
        # member whose vocabulary coincides — a plan no longer
        # recompiles its tables per member.  Member versions fence
        # entries individually, so divergent members just occupy
        # separate variants.
        shared_cache = self.plan_cache()
        for m in self.members:
            m._plan_cache = shared_cache

    # --------------------------------------------------------------- routing
    def _member_of(self, keys: np.ndarray) -> np.ndarray:
        return np.searchsorted(self.boundaries, keys, side="right")

    def _scatter(self, keys: np.ndarray) -> List[Tuple[int, np.ndarray]]:
        """Partition-mode scatter -> ``[(member_id, positions), ...]``
        (ascending member id; empty members skipped).  Zero-length
        batches scatter to nobody — mutations stay no-ops."""
        if keys.shape[0] == 0:
            return []
        return group_runs(self._member_of(keys))

    def _pick_replica(self) -> int:
        if self.policy == "primary":
            return 0
        i = self._rr % len(self.members)
        self._rr += 1
        return i

    # -------------------------------------------------------------- protocol
    @property
    def columns(self) -> Tuple[str, ...]:
        """Member 0's column order (sets are identical by contract)."""
        return self._columns

    def _dispatch_lookup(self, keys, columns=None, fanout=None, predicates=(),
                         keys_exist=False, on_error="raise"):
        """Per-member scatter: every touched member's device work is
        enqueued before any host half runs, so a federated morsel
        overlaps member inference the same way the sharded store
        overlaps shard inference.  ``keys_exist`` forwards to every
        member (partition-mode range/scan keys come from the members'
        own existence indexes).

        In replicate mode the serving replica is the health tracker's
        :meth:`~repro.fault.health.HealthTracker.pick` over the routing
        policy's preference — quarantined replicas are routed around
        (and periodically probed back in).  A member whose dispatch
        raises is captured in its handle slot; collect retries and, in
        replicate mode, fails over to the next replica."""
        keys = np.asarray(keys, dtype=np.int64)
        t0 = time.perf_counter()
        if self.mode == "replicate" or keys.shape[0] == 0:
            mid = 0
            if self.mode == "replicate":
                mid = self.health.pick(self._names, self._pick_replica())
            groups = [(mid, np.arange(keys.shape[0], dtype=np.int64))]
        else:
            groups = self._scatter(keys)
        route_s = time.perf_counter() - t0
        parts = []
        for m, pos in groups:
            try:
                parts.append((m, pos, (True, self.members[m]._dispatch_lookup(
                    keys[pos], columns, fanout=fanout, predicates=predicates,
                    keys_exist=keys_exist,
                ))))
            except Exception as exc:  # captured; retried at collect
                parts.append((m, pos, (False, exc)))
        use_fanout = (fanout is None or bool(fanout)) and len(parts) > 1
        return _PendingFederatedLookup(
            keys, parts, route_s, tuple(predicates), [m for m, _ in groups],
            use_fanout, columns, keys_exist, on_error,
        )

    def _visit_member(self, pending: _PendingFederatedLookup, part, aggregate=None):
        """Collect one member's part under the guarded retry loop ->
        ``(member, positions, values, exists, match, stats, outcome)``
        (result fields are ``None`` on terminal failure).  Health is
        recorded on every outcome, so replicate-mode routing learns.
        With ``aggregate=(group_by, aggregates)`` the member folds its
        part in code space instead (``_collect_aggregate``) and the
        partial state rides in the ``values`` slot — tuple shape is
        unchanged so the failover walk handles both."""
        m, pos, (ok, payload) = part
        owner = self._names[m]

        def attempt(i: int):
            fault_injection.maybe_fail("member_collect", owner)
            if i == 0 and ok:
                handle = payload
            elif i == 0 and payload is not None:
                raise payload  # dispatch-time failure = try 0
            else:
                # Retry, or a handle-less part (replicate failover):
                # dispatch fresh.
                handle = self.members[m]._dispatch_lookup(
                    pending.keys[pos], pending.columns,
                    predicates=pending.predicates,
                    keys_exist=pending.keys_exist,
                )
            if aggregate is not None:
                return self.members[m]._collect_aggregate(handle, *aggregate)
            return self.members[m]._collect_lookup(handle)

        outcome = call_guarded(
            attempt, owner=owner, site="member_collect", policy=self.retry
        )
        if not outcome.ok:
            self.health.record_failure(owner)
            return m, pos, None, None, None, None, outcome
        self.health.record_success(owner, outcome.latency_s)
        if aggregate is not None:
            state, stats = outcome.value
            stats.shard_ids = tuple(f"m{m}:{s}" for s in stats.shard_ids)
            return m, pos, state, None, None, stats, outcome
        values, exists, match, stats = outcome.value
        # Namespace member-local shard ids before the union: two
        # sharded members both have a "shard 0", and deduping them
        # would under-report the federation's true fan-out.
        stats.shard_ids = tuple(f"m{m}:{s}" for s in stats.shard_ids)
        return m, pos, values, exists, match, stats, outcome

    def _failover_replicate(
        self, pending: _PendingFederatedLookup, first, aggregate=None
    ):
        """Replicate-mode failover: the picked replica failed
        terminally — walk the remaining replicas in ring order (fresh
        dispatch each) until one serves.  Returns the winning visit
        plus the accumulated failures; raises :class:`OwnerFailure`
        when every replica is down (there is no partial result to
        degrade to — replicas hold the SAME relation)."""
        m0, pos = first[0], first[1]
        errors = [first[6].error]
        retries = first[6].retries
        for step in range(1, len(self.members)):
            mid = (m0 + step) % len(self.members)
            obs.registry().counter(
                "deepmap_fault_failovers_total",
                "Replicate-mode lookups failed over to another replica.",
            ).inc(member=mid)
            # Handle-less part: _visit_member's attempt 0 dispatches
            # fresh on the failover member.
            visit = self._visit_member(
                pending, (mid, pos, (False, None)), aggregate=aggregate
            )
            retries += visit[6].retries
            if visit[6].ok:
                return visit, tuple(errors), retries
            errors.append(visit[6].error)
        raise OwnerFailure(tuple(errors))

    def _collect_lookup(self, pending: _PendingFederatedLookup):
        """Morsel-parallel gather: collect the members' host halves —
        on the lazy fan-out pool when more than one member answered
        (``Query.fanout(False)`` restores serial visits) — and permute
        results back to request order.

        Failure semantics: each member's collect runs under the
        bounded-retry guard.  Replicate mode fails over to the next
        replica until one serves (lookups keep succeeding with any
        healthy replica); partition mode degrades around failed members
        under ``on_error='partial'`` or raises :class:`OwnerFailure`."""
        n = pending.keys.shape[0]
        agg = ExplainStats(route_s=pending.route_s, async_fanout=pending.use_fanout)

        if pending.use_fanout:
            visited = self._fanout.map(
                lambda p: self._visit_member(pending, p),
                pending.parts, owners=len(self.members),
            )
        else:
            visited = [self._visit_member(pending, p) for p in pending.parts]

        failover_errors: Tuple = ()
        if self.mode == "replicate" and not visited[0][6].ok:
            winner, failover_errors, retries = self._failover_replicate(
                pending, visited[0]
            )
            visited = [winner]
            agg.retries += retries - winner[6].retries

        healthy = [v for v in visited if v[6].ok]
        errors = tuple(v[6].error for v in visited if not v[6].ok)
        if errors and (pending.on_error != "partial" or not healthy):
            raise OwnerFailure(errors)
        agg.retries += sum(v[6].retries for v in visited)
        agg.owners_failed = tuple(
            e.describe() for e in tuple(failover_errors) + errors
        )
        agg.keys_unresolved = sum(
            int(v[1].shape[0]) for v in visited if not v[6].ok
        )

        collected = []
        member_plan: Tuple[str, ...] = ()
        for _, pos, values, exists, match, stats, _ in healthy:
            agg.merge_timings(stats)
            if not member_plan:
                member_plan = stats.plan
            collected.append((pos, values, exists, match))
        t0 = time.perf_counter()
        if pending.predicates and any(m is None for _, _, _, m in collected):
            # Contract: a member given predicates must return a match
            # selector; substituting "nothing matched" would silently
            # drop rows instead of surfacing the broken member hook.
            raise RuntimeError(
                "federation member returned match=None for a predicated "
                "lookup; its _collect_lookup violates the hook contract"
            )
        if len(collected) == 1 and not errors and np.array_equal(
            collected[0][0], np.arange(n, dtype=np.int64)
        ):
            # One member answered the whole batch in request order
            # (always true in replicate mode): the inverse permutation
            # is the identity — skip the per-column fancy-index copies.
            _, values, exists, match = collected[0]
        elif errors:
            values, exists, _covered = gather_parts_partial(
                n, ((p, v, e) for p, v, e, _ in collected)
            )
            match = None
            if pending.predicates:
                # Failed members' positions stay False: unreachable
                # rows are excluded from filtered results (the
                # keys_unresolved evidence keeps the count).
                match = np.zeros(n, dtype=bool)
                for pos, _, _, m in collected:
                    match[pos] = m
        else:
            values, exists = gather_parts(
                n, ((p, v, e) for p, v, e, _ in collected)
            )
            match = None
            if pending.predicates:
                match = np.zeros(n, dtype=bool)
                for pos, _, _, m in collected:
                    match[pos] = m
        agg.gather_s += time.perf_counter() - t0
        agg.plan = (
            f"federate[{self.mode}:"
            f"{','.join(str(m) for m in pending.member_ids)}]",
        ) + member_plan
        return values, exists, match, agg

    def _collect_aggregate(self, pending: _PendingFederatedLookup, group_by, aggregates):
        """Federated ``group_by(...).agg(...)``: each member folds its
        part through its own aggregate hook (code space on DeepMapping
        members — zero rows decoded; decode-then-aggregate on baseline
        members), and the facade merges the partial states.  Decoded
        group values are the shared vocabulary, so a federation mixing
        store types still aggregates exactly.  Replicate mode fails
        over to the next replica; partition mode degrades around failed
        members under ``on_error='partial')`` with the usual
        evidence."""
        agg = ExplainStats(route_s=pending.route_s, async_fanout=pending.use_fanout)
        spec = (group_by, aggregates)

        if pending.use_fanout:
            visited = self._fanout.map(
                lambda p: self._visit_member(pending, p, aggregate=spec),
                pending.parts, owners=len(self.members),
            )
        else:
            visited = [
                self._visit_member(pending, p, aggregate=spec)
                for p in pending.parts
            ]

        failover_errors: Tuple = ()
        if self.mode == "replicate" and not visited[0][6].ok:
            winner, failover_errors, retries = self._failover_replicate(
                pending, visited[0], aggregate=spec
            )
            visited = [winner]
            agg.retries += retries - winner[6].retries

        healthy = [v for v in visited if v[6].ok]
        errors = tuple(v[6].error for v in visited if not v[6].ok)
        if errors and (pending.on_error != "partial" or not healthy):
            raise OwnerFailure(errors)
        agg.retries += sum(v[6].retries for v in visited)
        agg.owners_failed = tuple(
            e.describe() for e in tuple(failover_errors) + errors
        )
        agg.keys_unresolved = sum(
            int(v[1].shape[0]) for v in visited if not v[6].ok
        )

        state: Dict[tuple, list] = {}
        member_plan: Tuple[str, ...] = ()
        for _, _, part_state, _, _, stats, _ in healthy:
            agg.merge_timings(stats)
            if not member_plan:
                member_plan = stats.plan
            merge_agg_states(state, part_state, aggregates)
        agg.plan = (
            f"federate[{self.mode}:"
            f"{','.join(str(m) for m in pending.member_ids)}]",
        ) + member_plan
        return state, agg

    def lookup(
        self, keys: np.ndarray, columns: Optional[Tuple[str, ...]] = None
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Batched exact-match lookup across the members (scatter in
        partition mode, one replica in replicate mode)."""
        values, exists, _, _ = self._collect_lookup(
            self._dispatch_lookup(keys, columns)
        )
        return values, exists

    def _range_keys(self, lo: int, hi: Optional[int]) -> np.ndarray:
        if self.mode == "replicate":
            # Health-aware: a quarantined primary must not source the
            # range/scan key stream either.
            return self.members[self.health.pick(self._names, 0)]._range_keys(
                lo, hi
            )
        parts = []
        for i, m in enumerate(self.members):
            m_lo = lo if i == 0 else max(lo, int(self.boundaries[i - 1]))
            m_hi = hi if i == len(self.members) - 1 else (
                int(self.boundaries[i])
                if hi is None
                else min(hi, int(self.boundaries[i]))
            )
            if m_hi is not None and m_hi <= m_lo:
                continue
            part = m._range_keys(m_lo, m_hi)
            if part.size:
                parts.append(part)
        if not parts:
            return np.zeros(0, dtype=np.int64)
        # members are ordered by boundary, so concatenation is ascending
        return np.concatenate(parts)

    # ---------------------------------------------------------- mutations
    # Validated against EVERY affected member before mutating ANY
    # (same discipline as the sharded facade): a rejected batch must
    # leave the federation untouched, not half-mutated up to the
    # member that raised.
    # Queue bookkeeping is NOT store state: a queued op changes no
    # query result until flush applies it through the members' public
    # mutators, which bump their mutation versions themselves.
    # deeplint: ignore[mutation-version]
    def _mutation_gate(self, op: str, keys, columns) -> bool:
        """Replicate-mode admission for one mutation.  Returns True to
        proceed now.  With a quarantined replica: ``"reject"`` raises
        (nothing mutates, replicas cannot diverge); ``"queue"`` buffers
        the op — applied in order by :meth:`flush_mutations` — and
        returns False.  Queued ops are flushed here first, so a
        mutation can never overtake an earlier queued one."""
        if self.mode != "replicate":
            return True
        self.flush_mutations()
        quarantined = [
            n for n in self._names if self.health.is_quarantined(n)
        ]
        if not quarantined:
            return True
        reg = obs.registry()
        if self.mutation_policy == "reject":
            reg.counter(
                "deepmap_fault_mutations_rejected_total",
                "Replicate-mode mutations rejected while a replica is "
                "quarantined (mutation_policy='reject').",
            ).inc(op=op)
            raise RuntimeError(
                f"{op} rejected: replica(s) {quarantined} are quarantined "
                f"and would diverge; retry after recovery or construct the "
                f"federation with mutation_policy='queue'"
            )
        reg.counter(
            "deepmap_fault_mutations_queued_total",
            "Replicate-mode mutations queued while a replica is "
            "quarantined (mutation_policy='queue').",
        ).inc(op=op)
        self._mutation_queue.append((op, keys, columns))
        return False

    # Pops happen only after _apply_replicate already mutated through
    # the members' public ops (which bump their versions) — the queue
    # itself is never consulted by a lookup.
    # deeplint: ignore[mutation-version]
    def flush_mutations(self) -> int:
        """Apply queued replicate-mode mutations in arrival order, once
        every replica is healthy again; returns the number applied (0
        while any replica stays quarantined).  A queued op that fails
        validation at flush time raises, leaving it and its successors
        queued — order is never reordered around a failure."""
        if not self._mutation_queue:
            return 0
        if any(self.health.is_quarantined(n) for n in self._names):
            return 0
        applied = 0
        while self._mutation_queue:
            op, keys, columns = self._mutation_queue[0]
            self._apply_replicate(op, keys, columns)
            self._mutation_queue.pop(0)
            applied += 1
        return applied

    def _apply_replicate(self, op: str, keys, columns) -> None:
        """Validate-all-then-mutate one replicate-mode op (the pre-gate
        mutation body, shared by the direct path and the flush)."""
        if op == "insert":
            # every member validates (a drifted replica must reject the
            # batch BEFORE any member mutates, or replicas diverge more)
            for m in self.members:
                if m.lookup(keys, columns=())[1].any():
                    raise ValueError("insert of existing key; use update()")
            for m in self.members:
                m.insert(keys, columns)
        elif op == "delete":
            for m in self.members:
                m.delete(keys)
        else:
            for m in self.members:
                if not m.lookup(keys, columns=())[1].all():
                    raise ValueError("update of non-existing key; use insert()")
            for m in self.members:
                m.update(keys, columns)

    def insert(self, keys: np.ndarray, columns: Dict[str, np.ndarray]) -> None:
        """Insert new rows — routed to owners (partition) or applied to
        every member (replicate); validated before any member mutates."""
        keys = np.asarray(keys, dtype=np.int64)
        if keys.size and np.unique(keys).size != keys.size:
            raise ValueError("duplicate keys in insert batch")
        if self.mode == "replicate":
            if self._mutation_gate("insert", keys, columns):
                self._apply_replicate("insert", keys, columns)
            return
        batches = self._scatter(keys)
        for mid, pos in batches:
            if self.members[mid].lookup(keys[pos], columns=())[1].any():
                raise ValueError("insert of existing key; use update()")
        for mid, pos in batches:
            self.members[mid].insert(
                keys[pos], {c: v[pos] for c, v in columns.items()}
            )

    def delete(self, keys: np.ndarray) -> None:
        """Idempotent like the members — no validation needed."""
        keys = np.asarray(keys, dtype=np.int64)
        if self.mode == "replicate":
            if self._mutation_gate("delete", keys, None):
                self._apply_replicate("delete", keys, None)
            return
        for mid, pos in self._scatter(keys):
            self.members[mid].delete(keys[pos])

    def update(self, keys: np.ndarray, columns: Dict[str, np.ndarray]) -> None:
        """Overwrite existing rows (validated against every affected
        member before mutating any, like :meth:`insert`)."""
        keys = np.asarray(keys, dtype=np.int64)
        if self.mode == "replicate":
            if self._mutation_gate("update", keys, columns):
                self._apply_replicate("update", keys, columns)
            return
        batches = self._scatter(keys)
        for mid, pos in batches:
            if not self.members[mid].lookup(keys[pos], columns=())[1].all():
                raise ValueError("update of non-existing key; use insert()")
        for mid, pos in batches:
            self.members[mid].update(
                keys[pos], {c: v[pos] for c, v in columns.items()}
            )

    def mutation_version(self):
        """Tuple of member tokens: a mutation through the facade OR
        directly on a member store invalidates the federation's cached
        plans (members are caller-owned and reachable)."""
        return tuple(m.mutation_version() for m in self.members)

    # --------------------------------------------------------- accounting
    @property
    def num_rows(self) -> int:
        """Logical row count (member sum in partition mode; member 0's
        in replicate mode — replicas hold the same relation)."""
        if self.mode == "replicate":
            return int(self.members[0].num_rows)
        return int(sum(m.num_rows for m in self.members))

    def size_breakdown(self) -> Dict[str, int]:
        """Per-member storage accounting, keys namespaced ``memberN.*``."""
        out: Dict[str, int] = {}
        for i, m in enumerate(self.members):
            for k, v in m.size_breakdown().items():
                out[f"member{i}.{k}"] = v
        return out

    # ----------------------------------------------------------- teardown
    def close(self) -> None:
        """Release the collect fan-out pool's threads (idempotent; the
        federation stays usable — a later fan-out re-creates the pool).
        Member stores are caller-owned and NOT closed here; close a
        sharded member's own pool with ``member.close()``."""
        self._fanout.close()

    def __enter__(self) -> "FederatedStore":
        """Context-manager entry; :meth:`close` runs on exit."""
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        """Close the fan-out pool on scope exit."""
        self.close()

    # -------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        """Intentionally unsupported — persist members individually."""
        raise NotImplementedError(
            "a federation is a runtime composition; save each member "
            "store individually and recompose with FederatedStore(...)"
        )

    @classmethod
    def load(cls, path: str, pool=None) -> "FederatedStore":
        """Intentionally unsupported — load members and recompose."""
        raise NotImplementedError(
            "load the member stores individually (repro.open) and "
            "recompose with FederatedStore(...)"
        )
