"""Sharded DeepMapping cluster: a relation range- or hash-partitioned
into K independent :class:`~repro.core.hybrid.DeepMappingStore` shards
behind a scatter/gather router — parallel build, per-shard lazy
retrain, shared memory pool, directory-of-stores serialization.
"""

from repro.cluster.partitioner import (  # noqa: F401
    HashPartitioner,
    Partitioner,
    RangePartitioner,
    make_partitioner,
    plan_range_partitions,
)
from repro.cluster.router import ShardBatch, ShardRouter  # noqa: F401
from repro.cluster.sharded_store import (  # noqa: F401
    ClusterConfig,
    QuarantinedShard,
    ShardedDeepMappingStore,
    load_sharded_store,
    save_sharded_store,
)
