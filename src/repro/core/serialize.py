"""On-disk format for DeepMapping hybrid stores.

Directory layout (atomic: written to ``<dir>.tmp`` then renamed):

    store/
      meta.msgpack      — spec, encoder, config, counters
      params.npz        — model weights (flattened path -> array)
      aux.msgpack       — compacted T_aux state (compressed partitions)
      vexist.bin        — compressed existence bitvector
      decode_<col>.npy  — f_decode arrays (numpy native, no pickle for
                          numeric/string dtypes)

The format is self-describing and versioned; restore works with any
later minor version.  No pickle anywhere — partitions and weights are
raw buffers, metadata is msgpack.
"""

from __future__ import annotations

import os
import shutil
from typing import Dict

import msgpack
import numpy as np

from repro.core import model as model_lib
from repro.core.aux_table import AuxTable
from repro.core.bitvector import BitVector
from repro.core.encoding import KeyEncoder, ValueCodec
from repro.core.hybrid import DeepMappingConfig, DeepMappingStore
from repro.core.model import MLPSpec
from repro.storage import MemoryPool

FORMAT_VERSION = 1


def _flatten_params(params: Dict, prefix: str = "") -> Dict[str, np.ndarray]:
    flat: Dict[str, np.ndarray] = {}

    def rec(node, path):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(v, f"{path}/{k}")
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(v, f"{path}/{i}")
        else:
            flat[path] = np.asarray(node)

    rec(params, prefix)
    return flat


def _unflatten_params(flat: Dict[str, np.ndarray], spec: MLPSpec) -> Dict:
    params = model_lib.init_params(spec, seed=0)
    ref = _flatten_params(params)
    if set(ref) != set(flat):
        raise ValueError("param tree mismatch on load")

    def rec(node, path):
        if isinstance(node, dict):
            return {k: rec(v, f"{path}/{k}") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return [rec(v, f"{path}/{i}") for i, v in enumerate(node)]
        import jax.numpy as jnp

        return jnp.asarray(flat[path])

    return rec(params, "")


def save_store(store: DeepMappingStore, path: str) -> None:
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    meta = {
        "version": FORMAT_VERSION,
        "spec": {
            "base": store.spec.base,
            "width": store.spec.width,
            "shared": list(store.spec.shared),
            "private": [[k, list(v)] for k, v in store.spec.private],
            "out_cards": [[k, v] for k, v in store.spec.out_cards],
            "dtype": store.spec.dtype,
        },
        "encoder": {
            "max_key_capacity": store.encoder.capacity,
            "base": store.encoder.base,
            "residues": list(store.encoder.residues),
        },
        "config": {
            "codec": store.config.codec,
            "partition_bytes": store.config.partition_bytes,
            "base": store.config.base,
        },
        "raw_bytes": store.raw_bytes,
        "num_rows": store.num_rows,
        "modified_bytes": store.modified_bytes,
        "columns": list(store.spec.tasks),
    }
    with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))

    np.savez(os.path.join(tmp, "params.npz"), **_flatten_params(store.params))

    aux_state = store.aux.to_state()
    aux_blob = msgpack.packb(
        {
            "codec": aux_state["codec"],
            "partition_bytes": aux_state["partition_bytes"],
            "num_values": aux_state["num_values"],
            "partitions": aux_state["partitions"],
            "boundaries": aux_state["boundaries"].tobytes(),
            "part_rows": aux_state["part_rows"],
            "rows": aux_state["rows"],
        }
    )
    with open(os.path.join(tmp, "aux.msgpack"), "wb") as f:
        f.write(aux_blob)

    with open(os.path.join(tmp, "vexist.bin"), "wb") as f:
        f.write(store.vexist.to_bytes())

    for col in store.spec.tasks:
        dm = store.codecs[col].decode_map
        if dm.dtype == object:
            dm = dm.astype(str)  # unicode arrays serialize without pickle
        np.save(os.path.join(tmp, f"decode_{col}.npy"), dm, allow_pickle=False)

    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def load_store(path: str, pool: MemoryPool | None = None) -> DeepMappingStore:
    with open(os.path.join(path, "meta.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    if meta["version"] > FORMAT_VERSION:
        raise ValueError(f"store format {meta['version']} newer than reader")

    s = meta["spec"]
    spec = MLPSpec(
        base=s["base"],
        width=s["width"],
        shared=tuple(s["shared"]),
        private={k: tuple(v) for k, v in s["private"]},
        out_cards={k: v for k, v in s["out_cards"]},
        dtype=s["dtype"],
    )
    with np.load(os.path.join(path, "params.npz")) as z:
        flat = {k: z[k] for k in z.files}
    params = _unflatten_params(flat, spec)

    with open(os.path.join(path, "aux.msgpack"), "rb") as f:
        a = msgpack.unpackb(f.read())
    aux = AuxTable.from_state(
        {
            "codec": a["codec"],
            "partition_bytes": a["partition_bytes"],
            "num_values": a["num_values"],
            "partitions": a["partitions"],
            "boundaries": np.frombuffer(a["boundaries"], dtype=np.int64),
            "part_rows": a["part_rows"],
            "rows": a["rows"],
        },
        pool=pool,
    )

    with open(os.path.join(path, "vexist.bin"), "rb") as f:
        vexist = BitVector.from_bytes(f.read())

    codecs: Dict[str, ValueCodec] = {}
    for col in meta["columns"]:
        dm = np.load(os.path.join(path, f"decode_{col}.npy"), allow_pickle=False)
        codecs[col] = ValueCodec.from_decode_map(col, dm)

    # Reconstruct the KeyEncoder with the same width/base/residues.
    base = meta["encoder"]["base"]
    cap = meta["encoder"]["max_key_capacity"]
    residues = tuple(meta["encoder"].get("residues", ()))
    enc = KeyEncoder(max_key=max(0, cap - 1), base=base, residues=residues)
    if enc.capacity != cap:
        raise RuntimeError(
            f"corrupt manifest: rebuilt encoder capacity {enc.capacity} "
            f"does not match stored capacity {cap}"
        )

    cfg = DeepMappingConfig(
        base=meta["config"]["base"],
        codec=meta["config"]["codec"],
        partition_bytes=meta["config"]["partition_bytes"],
    )
    store = DeepMappingStore(
        encoder=enc,
        spec=spec,
        params=params,
        codecs=codecs,
        aux=aux,
        vexist=vexist,
        raw_bytes=meta["raw_bytes"],
        num_rows=meta["num_rows"],
        config=cfg,
    )
    store.modified_bytes = meta["modified_bytes"]
    return store
