"""Quickstart: compress a table into a DeepMapping hybrid structure,
look up keys, modify, and measure Eq. 1.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --shards 4 --policy range

With ``--shards K > 1`` the same workload runs against the sharded
cluster (``repro.cluster``): K per-partition stores built in parallel
behind a scatter/gather router, with per-shard lazy retrain.
"""

import argparse

import numpy as np

from repro.core import DeepMappingConfig, DeepMappingStore, Table
from repro.core.trainer import TrainConfig


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--shards", type=int, default=1,
                    help="number of cluster shards (1 = single store)")
    ap.add_argument("--policy", default="range", choices=("range", "hash"),
                    help="cluster partition policy (with --shards > 1)")
    args = ap.parse_args()

    # A small relation: order_id -> (status, priority).  Values follow a
    # periodic pattern along the key (the paper's high-correlation regime).
    n = 20_000
    keys = np.arange(n, dtype=np.int64) * 2  # sparse even keys
    table = Table(
        keys=keys,
        columns={
            "status": np.array(["F", "O", "P"])[(keys // 64) % 3],
            "priority": ((keys // 128) % 5).astype(np.int32),
        },
    )

    cfg = DeepMappingConfig(
        shared=(128, 64),
        private=(16,),
        codec="zstd",
        train=TrainConfig(epochs=40, batch_size=4096),
    )
    if args.shards > 1:
        from repro.cluster import ClusterConfig, ShardedDeepMappingStore

        store = ShardedDeepMappingStore.build(
            table,
            cfg,
            ClusterConfig(num_shards=args.shards, policy=args.policy),
            verbose=True,
        )
        print(f"  {store.num_shards} {args.policy} shards, "
              f"rows/shard: {[s.num_rows for s in store.shards]}")
    else:
        store = DeepMappingStore.build(table, cfg, verbose=True)

    print("\n-- Eq.1 accounting ------------------------------")
    for k, v in store.size_breakdown().items():
        print(f"  {k:>16}: {v:,} bytes")
    print(f"  compression ratio: {store.compression_ratio():.4f}")
    print(f"  memorized by model: {store.memorized_fraction():.1%}")

    print("\n-- Lookups (Algorithm 1) -------------------------")
    q = np.array([0, 2, 128, 3, 999_999], dtype=np.int64)
    vals, exists = store.lookup(q)
    for i, k in enumerate(q):
        if exists[i]:
            print(f"  key {k}: status={vals['status'][i]} priority={vals['priority'][i]}")
        else:
            print(f"  key {k}: NULL (existence bitvector)")

    print("\n-- Modifications (Algorithms 3-5) ----------------")
    store.insert(
        np.array([10**6], dtype=np.int64),
        {"status": np.array(["X"]), "priority": np.array([9], np.int32)},
    )
    v, e = store.lookup(np.array([10**6]))
    print(f"  inserted unseen category: status={v['status'][0]} (exists={e[0]})")
    store.update(
        np.array([0], dtype=np.int64),
        {"status": np.array(["P"]), "priority": np.array([4], np.int32)},
    )
    v, _ = store.lookup(np.array([0]))
    print(f"  updated key 0: status={v['status'][0]} priority={v['priority'][0]}")
    store.delete(np.array([2], dtype=np.int64))
    _, e = store.lookup(np.array([2]))
    print(f"  deleted key 2: exists={e[0]}")

    if args.shards > 1:
        print("\n-- Per-shard lazy retrain ------------------------")
        print(f"  dirty shards after modifications: {store.dirty_shards() or 'none'}")
        print(f"  range scatter [0, 1000): shards "
              f"{store.partitioner.shards_for_range(0, 1000).tolist()}")


if __name__ == "__main__":
    main()
