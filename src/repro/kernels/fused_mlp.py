"""Fused multi-task MLP inference kernel (pl.pallas_call + BlockSpec).

TPU adaptation of the paper's ONNX-on-GPU batch inference: all model
weights stay resident in VMEM across the batch (mapping models are
small — KBs to a few MB); the grid walks batch tiles, so activations
make exactly ONE HBM round trip instead of one per layer.  The one-hot
encoding of key digits is materialized per-tile in VMEM as an
(TILE_N, base) compare-with-iota and immediately consumed by the MXU —
it never exists in HBM (DESIGN.md §3).

Layout contract (enforced by ops.py):
* every dense dimension padded to multiples of 128 (MXU lane width);
* batch tiles of ``tile_n`` rows (multiple of 8, default 256);
* rank-3 first-layer weights are (width, base_pad, h_pad);
* with ``emit_codes=True`` each head reduces to int32 argmax codes
  in-kernel (padded logit columns masked to -inf), shrinking the HBM
  write from O(Σ cards) floats to one int32 per task per row.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.model import MLPSpec


def _plan(spec: MLPSpec) -> Tuple[List[str], Dict[str, List[str]]]:
    """Layer kinds for trunk and heads: 'embed' (rank-3 from input) or
    'dense'."""
    trunk = ["embed" if i == 0 else "dense" for i in range(len(spec.shared))]
    heads = {}
    priv = spec.private_map
    for t in spec.tasks:
        kinds = []
        first = len(trunk) == 0
        for _ in priv[t]:
            kinds.append("embed" if first else "dense")
            first = False
        kinds.append("embed_out" if first else "dense_out")
        heads[t] = kinds
    return trunk, heads


def _apply_embed(w_ref, b_ref, digits, base_pad):
    """One-hot-in-VMEM gather-matmul: sum_p onehot(d_p) @ W[p]."""
    width = w_ref.shape[0]
    acc = None
    iota = jax.lax.broadcasted_iota(jnp.int32, (digits.shape[0], base_pad), 1)
    for p in range(width):
        onehot = (digits[:, p][:, None] == iota).astype(w_ref.dtype)
        part = jnp.dot(onehot, w_ref[p], preferred_element_type=jnp.float32)
        acc = part if acc is None else acc + part
    return acc + b_ref[...]


def make_fused_kernel(
    spec: MLPSpec,
    base_pad: int,
    card_pads: Dict[str, int],
    emit_codes: bool,
):
    """Build the kernel body for this model structure (static closure)."""
    trunk_kinds, head_kinds = _plan(spec)
    n_trunk = len(trunk_kinds)
    cards = spec.card_map

    def kernel(digits_ref, *refs):
        n_heads = len(spec.tasks)
        out_refs = refs[len(refs) - n_heads :]
        w_refs = list(refs[: len(refs) - n_heads])
        it = iter(w_refs)
        digits = digits_ref[...]

        x = None
        for kind in trunk_kinds:
            w_ref, b_ref = next(it), next(it)
            if kind == "embed":
                x = _apply_embed(w_ref, b_ref, digits, base_pad)
            else:
                x = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32) + b_ref[...]
            x = jnp.maximum(x, 0.0)

        for ti, t in enumerate(spec.tasks):
            h = x
            for kind in head_kinds[t]:
                w_ref, b_ref = next(it), next(it)
                if kind == "embed":
                    h = jnp.maximum(_apply_embed(w_ref, b_ref, digits, base_pad), 0.0)
                elif kind == "dense":
                    h = jnp.maximum(
                        jnp.dot(h, w_ref[...], preferred_element_type=jnp.float32)
                        + b_ref[...],
                        0.0,
                    )
                elif kind == "embed_out":
                    h = _apply_embed(w_ref, b_ref, digits, base_pad)
                else:  # dense_out
                    h = (
                        jnp.dot(h, w_ref[...], preferred_element_type=jnp.float32)
                        + b_ref[...]
                    )
            if emit_codes:
                # mask padded logit columns, reduce to codes in-kernel
                card = cards[t]
                col = jax.lax.broadcasted_iota(jnp.int32, h.shape, 1)
                masked = jnp.where(col < card, h, -jnp.inf)
                out_refs[ti][...] = jnp.argmax(masked, axis=-1).astype(jnp.int32)[:, None]
            else:
                out_refs[ti][...] = h

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("spec", "tile_n", "base_pad", "card_pads", "emit_codes", "interpret"),
)
def fused_mlp_call(
    digits: jnp.ndarray,
    flat_weights: Tuple[jnp.ndarray, ...],
    spec: MLPSpec,
    tile_n: int,
    base_pad: int,
    card_pads: Tuple[Tuple[str, int], ...],
    emit_codes: bool,
    interpret: bool,
):
    """digits (N_pad, width) int32; flat_weights in plan order (padded).

    Returns tuple per task: (N_pad, 1) int32 codes if emit_codes else
    (N_pad, card_pad) float32 logits.
    """
    card_pads_d = dict(card_pads)
    n = digits.shape[0]
    assert n % tile_n == 0
    grid = (n // tile_n,)
    kernel = make_fused_kernel(spec, base_pad, card_pads_d, emit_codes)

    in_specs = [pl.BlockSpec((tile_n, digits.shape[1]), lambda i: (i, 0))]
    for w in flat_weights:
        # weights are grid-invariant: whole tensor resident per step
        in_specs.append(pl.BlockSpec(w.shape, lambda i, nd=w.ndim: (0,) * nd))

    out_shapes, out_specs = [], []
    for t in spec.tasks:
        if emit_codes:
            out_shapes.append(jax.ShapeDtypeStruct((n, 1), jnp.int32))
            out_specs.append(pl.BlockSpec((tile_n, 1), lambda i: (i, 0)))
        else:
            cp = card_pads_d[t]
            out_shapes.append(jax.ShapeDtypeStruct((n, cp), jnp.float32))
            out_specs.append(pl.BlockSpec((tile_n, cp), lambda i: (i, 0)))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(digits, *flat_weights)
