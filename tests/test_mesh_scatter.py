"""Cluster mesh-scatter conformance: K-shard lookups answered by one
``shard_map`` launch must be byte-identical to the thread-pool fan-out
on every query shape, degrade cleanly, and restack on mutation drift.

The multi-device cases need ≥ 2 devices — CI provides them with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; under a plain
single-device run they skip and the fallback tests still execute."""

import os

import numpy as np
import pytest

import jax

from conftest import make_periodic_table, make_random_table
from repro.cluster import ClusterConfig, ShardedDeepMappingStore
from repro.cluster.mesh_scatter import MeshShardRunner, _pow2_at_least
from repro.core import DeepMappingConfig
from repro.core.trainer import TrainConfig
from repro.kernels import bitvector as bv_kernel

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_device_count=N)",
)

FAST = DeepMappingConfig(
    shared=(32,), private=(8,), train=TrainConfig(epochs=10, batch_size=512)
)


@pytest.fixture()
def threadpool_env(monkeypatch):
    """Force the thread-pool path via the env kill switch."""
    monkeypatch.setenv("REPRO_MESH_SCATTER", "0")


@pytest.fixture(scope="module")
def cluster():
    table = make_periodic_table(n=2400, period=16, cards=(5, 3))
    return table, ShardedDeepMappingStore.build(
        table, FAST, ClusterConfig(num_shards=4, policy="range")
    )


def probe_keys(table, seed=7):
    rng = np.random.default_rng(seed)
    return np.concatenate([
        table.keys,
        rng.integers(-50, int(table.keys.max()) + 500, 600),
        np.array([-1, 0, 2**31 - 1, 2**31, 2**40], dtype=np.int64),
    ]).astype(np.int64)


def direct_lookup(store, keys):
    pend = store._dispatch_lookup(keys, fanout=True)
    return store._collect_lookup(pend)


class TestUnits:
    def test_pow2_at_least(self):
        assert _pow2_at_least(1, 128) == 128
        assert _pow2_at_least(128, 128) == 128
        assert _pow2_at_least(129, 128) == 256
        assert _pow2_at_least(1000, 128) == 1024

    def test_pack_words32_layout(self):
        words = np.arange(4, dtype=np.uint64)
        packed = bv_kernel.pack_words32(words)
        assert packed.dtype == np.uint32
        assert packed.shape[0] == 8
        # little-endian split: low word first — the k>>5 indexing contract
        np.testing.assert_array_equal(packed[0::2], np.arange(4, dtype=np.uint32))
        np.testing.assert_array_equal(packed[1::2], np.zeros(4, dtype=np.uint32))

    def test_maybe_build_rejects_small_fleets(self, cluster):
        _, c = cluster
        assert MeshShardRunner.maybe_build(c.shards[:1]) is None

    @pytest.mark.skipif(N_DEV != 1, reason="single-device fallback case")
    def test_single_device_fallback_is_noop(self, cluster):
        table, c = cluster
        assert MeshShardRunner.maybe_build(c.shards) is None
        _, _, _, stats = direct_lookup(c, probe_keys(table))
        assert not any("mesh" in p for p in stats.plan)

    def test_config_kill_switch(self, cluster, monkeypatch):
        _, c = cluster
        monkeypatch.setenv("REPRO_MESH_SCATTER", "0")
        assert not c._mesh_enabled()
        monkeypatch.delenv("REPRO_MESH_SCATTER")
        assert c._mesh_enabled() == c.cluster.mesh_scatter


@multi_device
class TestMeshConformance:
    def test_runner_builds(self, cluster):
        _, c = cluster
        runner = MeshShardRunner.maybe_build(c.shards)
        assert runner is not None
        assert runner.k == 4
        assert runner.k_pad % runner.n_dev == 0

    def test_lookup_byte_identical(self, cluster, monkeypatch):
        table, c = cluster
        keys = probe_keys(table)
        vm, em, _, sm = direct_lookup(c, keys)
        assert any("mesh" in p for p in sm.plan), sm.plan
        monkeypatch.setenv("REPRO_MESH_SCATTER", "0")
        vt, et, _, st = direct_lookup(c, keys)
        assert not any("mesh" in p for p in st.plan), st.plan
        np.testing.assert_array_equal(em, et)
        for col in vm:
            np.testing.assert_array_equal(vm[col][em], vt[col][et])

    def test_scan_and_range_byte_identical(self, cluster, monkeypatch):
        table, c = cluster
        lo, hi = int(table.keys[100]), int(table.keys[-100])
        rm_scan = c.query().scan().execute()
        rm_rng = c.query().where_range(lo, hi).execute()
        monkeypatch.setenv("REPRO_MESH_SCATTER", "0")
        rt_scan = c.query().scan().execute()
        rt_rng = c.query().where_range(lo, hi).execute()
        for rm, rt in ((rm_scan, rt_scan), (rm_rng, rt_rng)):
            np.testing.assert_array_equal(rm.keys, rt.keys)
            for col in rm.values:
                np.testing.assert_array_equal(rm.values[col], rt.values[col])

    def test_predicates_and_projection(self, cluster, monkeypatch):
        _, c = cluster
        q = lambda: (  # noqa: E731
            c.query().scan().where("col0", "<=", 2).select("col1").execute()
        )
        rm = q()
        monkeypatch.setenv("REPRO_MESH_SCATTER", "0")
        rt = q()
        np.testing.assert_array_equal(rm.keys, rt.keys)
        for col in rm.values:
            np.testing.assert_array_equal(rm.values[col], rt.values[col])

    def test_mutation_drift_restacks(self, monkeypatch):
        table = make_periodic_table(n=1600, period=16, cards=(4,))
        c = ShardedDeepMappingStore.build(
            table, FAST, ClusterConfig(num_shards=4, policy="range")
        )
        keys = probe_keys(table, seed=11)
        direct_lookup(c, keys)  # prime the runner + stacked arrays
        c.delete(table.keys[10:40])
        new_keys = np.array(
            [10**6 + 2 * i for i in range(30)], dtype=np.int64
        )
        c.insert(
            new_keys, {"col0": np.ones(30, dtype=np.int32)}
        )
        probe = np.concatenate([keys, new_keys])
        vm, em, _, sm = direct_lookup(c, probe)
        assert any("mesh" in p for p in sm.plan), sm.plan
        monkeypatch.setenv("REPRO_MESH_SCATTER", "0")
        vt, et, _, _ = direct_lookup(c, probe)
        np.testing.assert_array_equal(em, et)
        for col in vm:
            np.testing.assert_array_equal(vm[col][em], vt[col][et])

    def test_trunkless_hash_cluster(self, monkeypatch):
        table = make_random_table(n=900, cards=(7, 4))
        c = ShardedDeepMappingStore.build(
            table,
            DeepMappingConfig(
                shared=(), private=(12,),
                train=TrainConfig(epochs=8, batch_size=256),
            ),
            ClusterConfig(num_shards=3, policy="hash"),
        )
        keys = np.concatenate(
            [table.keys, np.arange(0, 6000, 7, dtype=np.int64)]
        )
        vm, em, _, sm = direct_lookup(c, keys)
        assert any("mesh" in p for p in sm.plan), sm.plan
        monkeypatch.setenv("REPRO_MESH_SCATTER", "0")
        vt, et, _, _ = direct_lookup(c, keys)
        np.testing.assert_array_equal(em, et)
        for col in vm:
            np.testing.assert_array_equal(vm[col][em], vt[col][et])

    def test_kill_switch_mid_flight(self, cluster):
        """Flipping the env between lookups swaps paths per dispatch."""
        table, c = cluster
        keys = table.keys[::5]  # strided: spans every range shard
        _, _, _, s1 = direct_lookup(c, keys)
        assert any("mesh" in p for p in s1.plan)
        os.environ["REPRO_MESH_SCATTER"] = "0"
        try:
            _, _, _, s2 = direct_lookup(c, keys)
            assert not any("mesh" in p for p in s2.plan)
        finally:
            del os.environ["REPRO_MESH_SCATTER"]
        _, _, _, s3 = direct_lookup(c, keys)
        assert any("mesh" in p for p in s3.plan)
