"""TPC-H-like table generators (categorical/integer attributes only —
the paper removes float attributes, §V-A1).  Column domains follow the
TPC-H specification; value distributions are uniform over the domain,
which is what makes TPC-H the paper's *low*-correlation regime."""

from __future__ import annotations

import numpy as np

from repro.core.table import Table, pack_composite_key

_ORDERSTATUS = np.array(["F", "O", "P"])
_ORDERPRIORITY = np.array(
    ["1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW"]
)
_RETURNFLAG = np.array(["A", "N", "R"])
_LINESTATUS = np.array(["F", "O"])
_SHIPINSTRUCT = np.array(
    ["COLLECT COD", "DELIVER IN PERSON", "NONE", "TAKE BACK RETURN"]
)
_SHIPMODE = np.array(["AIR", "FOB", "MAIL", "RAIL", "REG AIR", "SHIP", "TRUCK"])
_MFGR = np.array([f"Manufacturer#{i}" for i in range(1, 6)])
_BRAND = np.array([f"Brand#{i}{j}" for i in range(1, 6) for j in range(1, 6)])
_CONTAINER = np.array(
    [f"{s} {t}" for s in ("SM", "MED", "LG", "JUMBO", "WRAP")
     for t in ("BAG", "BOX", "CAN", "CASE", "DRUM", "JAR", "PACK", "PKG")]
)


def orders_like(n: int = 150_000, seed: int = 0) -> Table:
    rng = np.random.default_rng(seed)
    # TPC-H orderkeys are sparse: only 4 of every 32 consecutive ints used.
    blocks = np.arange(n, dtype=np.int64)
    keys = (blocks // 4) * 32 + (blocks % 4) + 1
    return Table(
        keys=keys,
        columns={
            "o_orderstatus": _ORDERSTATUS[rng.integers(0, 3, n)],
            "o_orderpriority": _ORDERPRIORITY[rng.integers(0, 5, n)],
            "o_clerk": rng.integers(1, 1001, n).astype(np.int32),
            "o_shippriority": np.zeros(n, dtype=np.int32),
        },
    )


def lineitem_like(n: int = 600_000, seed: int = 0) -> Table:
    rng = np.random.default_rng(seed)
    # Composite (orderkey, linenumber 1..7) packed into one key.
    orders = np.repeat(np.arange(1, n // 4 + 2, dtype=np.int64), 7)[:n]
    lineno = np.concatenate(
        [np.arange(1, 8, dtype=np.int64)] * (n // 7 + 1)
    )[:n]
    keys = pack_composite_key([orders, lineno])
    return Table(
        keys=keys,
        columns={
            "l_returnflag": _RETURNFLAG[rng.integers(0, 3, n)],
            "l_linestatus": _LINESTATUS[rng.integers(0, 2, n)],
            "l_shipinstruct": _SHIPINSTRUCT[rng.integers(0, 4, n)],
            "l_shipmode": _SHIPMODE[rng.integers(0, 7, n)],
            "l_quantity": rng.integers(1, 51, n).astype(np.int32),
            "l_linenumber_mod": (lineno % 7).astype(np.int32),
        },
    )


def part_like(n: int = 200_000, seed: int = 0) -> Table:
    rng = np.random.default_rng(seed)
    keys = np.arange(1, n + 1, dtype=np.int64)
    return Table(
        keys=keys,
        columns={
            "p_mfgr": _MFGR[rng.integers(0, 5, n)],
            "p_brand": _BRAND[rng.integers(0, 25, n)],
            "p_size": rng.integers(1, 51, n).astype(np.int32),
            "p_container": _CONTAINER[rng.integers(0, 40, n)],
        },
    )
