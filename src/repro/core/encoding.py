"""Key featurization and value codecs.

The paper encodes discrete keys "as integers using one-hot encoding"
(§IV-A).  Materializing one-hot vectors over a multi-million key domain
is infeasible, so — like the reference implementation — a key is first
decomposed into ``width`` digits of a fixed ``base`` and each digit
position is one-hot encoded, giving a ``width*base`` feature vector.

On the optimized path the one-hot never exists: the first dense layer is
evaluated as a gather over rows of its weight (see
``repro.kernels.digit_gather``), which is mathematically identical.

Values are factorized per column by :class:`ValueCodec`; the inverse
maps are the paper's ``f_decode`` and their bytes count toward Eq. 1.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import numpy as np

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class KeyEncoderSpec:
    base: int
    width: int

    @property
    def feature_dim(self) -> int:
        return self.base * self.width


class KeyEncoder:
    """Fixed-width, fixed-base digit decomposition of int64 keys.

    ``residues`` (beyond-paper, DESIGN.md §Perf) appends extra feature
    positions carrying ``key % r`` for each residue ``r`` — encoded as
    ``ceil(log_base r)`` base-``base`` digits, so any period fits.  A
    value column that is periodic in the key with period dividing ``r``
    becomes a function of those few positions only — cross-product
    tables (TPC-DS customer_demographics) go from hard to trivially
    memorizable.  Positions reuse the same one-hot granularity, so
    model/kernels are untouched; disabled (paper-faithful) by default.
    """

    def __init__(self, max_key: int, base: int = 10, residues: Tuple[int, ...] = ()):
        if base < 2:
            raise ValueError("base must be >= 2")
        if max_key < 0:
            raise ValueError("max_key must be >= 0")
        if any(r < 2 for r in residues):
            raise ValueError(f"residues must be >= 2: {residues}")

        def width_for(maxval: int) -> int:
            w, cap = 1, base
            while cap <= maxval:
                cap *= base
                w += 1
            return w

        digit_width = width_for(max_key)
        cap = base ** digit_width
        self.residues = tuple(int(r) for r in residues)
        self._digit_width = digit_width
        self._capacity = cap
        self._res_widths = tuple(width_for(r - 1) for r in self.residues)
        width = digit_width + sum(self._res_widths)
        self.spec = KeyEncoderSpec(base=base, width=width)
        # Most-significant digit first, so nearby keys share a prefix.
        divisors = [base ** (digit_width - 1 - i) for i in range(digit_width)]
        self._divisors = np.array(divisors, dtype=np.int64)
        self._res_divisors = [
            np.array([base ** (w - 1 - i) for i in range(w)], dtype=np.int64)
            for w in self._res_widths
        ]

    @property
    def base(self) -> int:
        return self.spec.base

    @property
    def width(self) -> int:
        return self.spec.width

    @property
    def feature_dim(self) -> int:
        return self.spec.feature_dim

    @property
    def capacity(self) -> int:
        """Exclusive upper bound on encodable keys."""
        return self._capacity

    def digits(self, keys: np.ndarray) -> np.ndarray:
        """(n,) int64 keys -> (n, width) int32 codes: digit positions in
        [0, base) then residue positions (key % r)."""
        keys = np.asarray(keys, dtype=np.int64)
        if keys.size and (keys.min() < 0 or keys.max() >= self.capacity):
            raise ValueError(
                f"key out of range [0, {self.capacity}) for encoder {self.spec}"
            )
        parts = [((keys[..., None] // self._divisors) % self.base).astype(np.int32)]
        for r, div in zip(self.residues, self._res_divisors):
            v = keys % r
            parts.append(((v[..., None] // div) % self.base).astype(np.int32))
        return np.concatenate(parts, axis=-1) if len(parts) > 1 else parts[0]

    def digits_jax(self, keys: jnp.ndarray) -> jnp.ndarray:
        """Traceable digit decomposition (used inside jitted lookup)."""
        parts = [((keys[..., None] // jnp.asarray(self._divisors)) % self.base).astype(jnp.int32)]
        for r, div in zip(self.residues, self._res_divisors):
            v = keys % r
            parts.append(((v[..., None] // jnp.asarray(div)) % self.base).astype(jnp.int32))
        return jnp.concatenate(parts, axis=-1) if len(parts) > 1 else parts[0]

    def position_ops(self) -> Tuple[Tuple[int, int], ...]:
        """Per-position ``(modulus, divisor)`` pairs such that position
        ``p``'s digit is ``((key % modulus) // divisor) % base`` — the
        uniform form the fused lookup kernel evaluates in-device (main
        digit positions use ``modulus = capacity``, a no-op for in-range
        keys, so every position is the same three integer ops)."""
        ops = [(self._capacity, int(d)) for d in self._divisors]
        for r, divs in zip(self.residues, self._res_divisors):
            ops.extend((int(r), int(d)) for d in divs)
        return tuple(ops)

    def onehot(self, keys: np.ndarray, dtype=np.float32) -> np.ndarray:
        """(n,) keys -> (n, width*base) one-hot features (reference path)."""
        d = self.digits(keys)
        n = d.shape[0]
        out = np.zeros((n, self.feature_dim), dtype=dtype)
        cols = d + (np.arange(self.width, dtype=np.int32) * self.base)[None, :]
        rows = np.repeat(np.arange(n), self.width)
        out[rows, cols.reshape(-1)] = 1
        return out

    def size_bytes(self) -> int:
        return 16  # (base, width) — negligible, but accounted.


def onehot_digits(digits: jnp.ndarray, base: int, dtype=jnp.float32) -> jnp.ndarray:
    """(..., width) int digit codes -> (..., width*base) flattened one-hot."""
    eye = (digits[..., None] == jnp.arange(base, dtype=digits.dtype)).astype(dtype)
    return eye.reshape(*digits.shape[:-1], digits.shape[-1] * base)


class ValueCodec:
    """Per-column factorization: original discrete values <-> int32 codes.

    ``decode_map`` (the paper's ``f_decode``) is an array of originals
    indexed by code; its serialized bytes count toward Eq. 1.
    """

    def __init__(self, name: str, values: np.ndarray):
        self.name = name
        uniques, codes = np.unique(np.asarray(values), return_inverse=True)
        self.decode_map = uniques
        self._codes = codes.astype(np.int32)
        # Encoding dict for modification-time encode of unseen values.
        self._encode: Dict[object, int] = {v: i for i, v in enumerate(uniques.tolist())}

    @property
    def cardinality(self) -> int:
        return int(self.decode_map.shape[0])

    @property
    def codes(self) -> np.ndarray:
        return self._codes

    def encode(self, values: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Encode possibly-unseen values.

        Returns ``(codes, known_mask)``; unseen values get code -1 and
        ``known_mask`` False (the caller must route them to T_aux as raw
        values — the model can never predict an unseen class).
        """
        values = np.asarray(values)
        codes = np.empty(values.shape[0], dtype=np.int32)
        known = np.ones(values.shape[0], dtype=bool)
        for i, v in enumerate(values.tolist()):
            c = self._encode.get(v, -1)
            codes[i] = c
            if c < 0:
                known[i] = False
        return codes, known

    @classmethod
    def from_decode_map(cls, name: str, decode_map: np.ndarray) -> "ValueCodec":
        """Rebuild a codec from its serialized ``decode_map`` (the load
        paths of ``core.serialize`` and the baseline stores)."""
        vc = cls.__new__(cls)
        vc.name = name
        vc.decode_map = decode_map
        vc._codes = np.zeros(0, dtype=np.int32)  # codes only needed at build
        vc._encode = {v: i for i, v in enumerate(decode_map.tolist())}
        return vc

    def extend(self, values: np.ndarray) -> None:
        """Register new categories (used on insert of unseen values).

        One ``np.unique`` + one concatenate regardless of batch size;
        new categories keep first-occurrence order, matching the code
        assignment the old per-value ``np.append`` loop produced."""
        values = np.asarray(values)
        if values.size == 0:
            return
        uniq, first = np.unique(values, return_index=True)
        fresh = [
            v for v in uniq[np.argsort(first, kind="stable")].tolist()
            if v not in self._encode
        ]
        if not fresh:
            return
        start = len(self._encode)
        for off, v in enumerate(fresh):
            self._encode[v] = start + off
        # plain concatenate so dtype promotion (e.g. wider strings)
        # matches what np.append did
        self.decode_map = np.concatenate([self.decode_map, np.asarray(fresh)])

    def decode(self, codes: np.ndarray) -> np.ndarray:
        return self.decode_map[np.asarray(codes, dtype=np.int64)]

    def size_bytes(self) -> int:
        dm = self.decode_map
        if dm.dtype == object:
            return int(sum(len(str(x)) for x in dm)) + 8 * len(dm)
        return int(dm.nbytes)


def build_codecs(columns: Dict[str, np.ndarray]) -> Dict[str, ValueCodec]:
    return {name: ValueCodec(name, col) for name, col in columns.items()}


def detect_column_period(
    keys: np.ndarray,
    col: np.ndarray,
    max_period: int = 1 << 22,
    min_purity: float = 0.98,
    sample: int = 200_000,
) -> int | None:
    """Detect whether ``col`` is (near-)periodic along the key dimension.

    Cross-product tables (TPC-DS dimension tables) and run-length data
    make every column a function of ``key % period``.  Heuristic:
    stride = modal run length of equal values in key order; candidate
    periods = stride × cardinality × {1,2,4}; accept the smallest whose
    groups are ``min_purity`` single-valued (tolerates the synthetic
    datasets' noise rows).  Returns the period or None.
    """
    n = keys.shape[0]
    if n < 16:
        return None
    if n > sample:
        idx = np.sort(np.random.default_rng(0).choice(n, size=sample, replace=False))
        keys, col = keys[idx], col[idx]
    order = np.argsort(keys)
    k, v = keys[order], col[order]
    _, codes = np.unique(v, return_inverse=True)
    card = int(codes.max()) + 1
    if card <= 1:
        return 1
    # modal run length in KEY units
    change = np.flatnonzero(np.diff(codes) != 0)
    if change.size == 0:
        return 1
    run_key_lens = np.diff(np.concatenate([[k[0]], k[change + 1]]))
    run_key_lens = run_key_lens[run_key_lens > 0]
    if run_key_lens.size == 0:
        return None
    vals, counts = np.unique(run_key_lens, return_counts=True)
    stride = int(vals[np.argmax(counts)])

    def purity(period: int) -> float:
        g = (k % period).astype(np.int64)
        o = np.argsort(g, kind="stable")
        gs, cs = g[o], codes[o]
        starts = np.flatnonzero(np.diff(gs)) + 1
        bounds = np.concatenate([[0], starts, [gs.size]])
        agree = 0
        for a, b in zip(bounds[:-1], bounds[1:]):
            seg = cs[a:b]
            agree += int(np.bincount(seg, minlength=card).max())
        return agree / gs.size

    for mult in (1, 2, 4):
        period = stride * card * mult
        if period <= 1 or period > max_period:
            continue
        if purity(period) >= min_purity:
            return period
    return None


def detect_residues(
    keys: np.ndarray,
    columns: Dict[str, np.ndarray],
    base: int,
    max_positions: int = 24,
    max_period: int = 1 << 22,
) -> Tuple[int, ...]:
    """Periods worth adding as residue features, deduplicated (a period
    dividing another is subsumed), capped by total digit positions."""
    periods = []
    for col in columns.values():
        if col.dtype == object or col.dtype.kind in "SU":
            _, codes = np.unique(col, return_inverse=True)
            col = codes
        p = detect_column_period(keys, np.asarray(col), max_period=max_period)
        if p is not None and p > 1:
            periods.append(int(p))
    # Exact-dedup only.  A multiple q of p carries key%p INFORMATION, but
    # extracting it is as hard as the original problem — each column keeps
    # its own period so its value is a function of few positions.
    kept = sorted(set(periods))

    def width_for(maxval: int) -> int:
        w, cap = 1, base
        while cap <= maxval:
            cap *= base
            w += 1
        return w

    out, used = [], 0
    for p in kept:
        w = width_for(p - 1)
        if used + w > max_positions:
            continue
        out.append(p)
        used += w
    return tuple(out)


def codes_matrix(codecs: Dict[str, ValueCodec], order: Sequence[str]) -> np.ndarray:
    """Stack per-column codes into an (n, m) int32 matrix in column order."""
    return np.stack([codecs[name].codes for name in order], axis=1)
