"""seamless-m4t-medium — encoder-decoder, multimodal [arXiv:2308.11596].
12L d_model=1024 16H d_ff=4096 vocab=256206.  Audio frontend stubbed:
the encoder consumes precomputed frame embeddings.  12 encoder + 12
decoder layers (the assignment's '12L' read as per-stack depth)."""

from repro.configs.base import ArchSpec, register
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    is_encoder_decoder=True,
    enc_layers=12,
    dec_layers=12,
    modality="audio",
)

SMOKE = ModelConfig(
    name="seamless-smoke",
    family="encdec",
    num_layers=4,
    d_model=32,
    num_heads=4,
    num_kv_heads=4,
    head_dim=8,
    d_ff=64,
    vocab_size=128,
    is_encoder_decoder=True,
    enc_layers=2,
    dec_layers=2,
    modality="audio",
    dtype="float32",
    remat="none",
)

SPEC = register(
    ArchSpec(
        arch_id="seamless-m4t-medium",
        config=CONFIG,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        notes=(
            "Enc-dec: train_4k = enc 2048 + dec 2048; prefill_32k = enc 32768 "
            "frames + dec prefill 1024; decode vs dec-KV 32k + cross-KV. "
            "Full attention -> long_500k skipped."
        ),
    )
)
