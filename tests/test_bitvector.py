import numpy as np

from repro.core.bitvector import BitVector


class TestBitVector:
    def test_set_test(self):
        bv = BitVector(1000)
        keys = np.array([0, 63, 64, 65, 999])
        bv.set(keys, True)
        assert bv.test(keys).all()
        assert not bv.test(np.array([1, 62, 66, 998])).any()
        assert bv.count() == 5

    def test_unset(self):
        bv = BitVector.from_keys(np.arange(100))
        bv.set(np.arange(0, 100, 2), False)
        assert bv.count() == 50
        assert bv.test(np.array([1, 3, 99])).all()
        assert not bv.test(np.array([0, 2, 98])).any()

    def test_grow_on_set(self):
        bv = BitVector(10)
        bv.set(np.array([1_000_000]), True)
        assert bv.capacity == 1_000_001
        assert bv.test(np.array([1_000_000]))[0]
        assert not bv.test(np.array([999_999]))[0]

    def test_out_of_domain_false(self):
        bv = BitVector.from_keys(np.array([5]))
        out = bv.test(np.array([-3, 100, 5]))
        assert out.tolist() == [False, False, True]

    def test_serialize_roundtrip(self):
        keys = np.random.default_rng(0).permutation(10_000)[:777]
        bv = BitVector.from_keys(keys, capacity=10_000)
        bv2 = BitVector.from_bytes(bv.to_bytes())
        assert bv2.capacity == bv.capacity
        np.testing.assert_array_equal(bv2.words, bv.words)

    def test_compressed_at_rest_smaller_for_sparse(self):
        bv = BitVector(1 << 20)
        bv.set(np.array([17]), True)
        assert bv.size_bytes() < bv.runtime_bytes() / 10

    def test_empty(self):
        bv = BitVector(0)
        assert bv.count() == 0
        assert bv.test(np.array([0, 1])).tolist() == [False, False]


class TestCountAndVersion:
    def test_count_matches_unpackbits(self):
        rng = np.random.default_rng(5)
        keys = rng.choice(100_000, size=33_333, replace=False)
        bv = BitVector.from_keys(keys, capacity=100_000)
        assert bv.count() == 33_333
        want = int(np.unpackbits(bv.words.view(np.uint8)).sum())
        assert bv.count() == want

    def test_count_empty_and_full_word_edges(self):
        assert BitVector(0).count() == 0
        bv = BitVector.from_keys(np.arange(64))  # exactly one full word
        assert bv.count() == 64
        bv.set(np.array([63]), False)
        assert bv.count() == 63

    def test_version_bumps_on_mutation(self):
        bv = BitVector.from_keys(np.array([1, 5]))
        v0 = bv.version
        bv.set(np.array([2]), True)
        assert bv.version > v0
        v1 = bv.version
        bv.set(np.array([2]), False)
        assert bv.version > v1
        bv.set(np.array([], dtype=np.int64), True)  # no-op: unchanged
        assert bv.version > v1 and bv.version == v1 + 1
