"""Residency-tier ladder at the VMEM budget boundary, streamed-tier
byte-equality (interpret mode on CPU), in-kernel predicate filtering
vs the host filter on every predicate op, and the cost-model morsel
seed rule — the ISSUE-9 conformance additions."""

import numpy as np
import pytest

from conftest import make_periodic_table
from repro.api.executor import (
    ADAPT_MAX,
    ADAPT_MIN,
    seed_morsel_rows,
)
from repro.api.plan import DEFAULT_MORSEL, PREDICATE_OPS
from repro.core import DeepMappingConfig, DeepMappingStore
from repro.core.inference import InferenceEngine
from repro.core.trainer import TrainConfig
from repro.kernels import ops as kops
from repro.kernels.ref import ref_fused_lookup
from test_kernels import make_lookup_setup

TILE = 64


def _engine(enc, spec, params, bv, monkeypatch, budget=None):
    """Engine with an explicit VMEM budget (read at construction)."""
    if budget is None:
        monkeypatch.delenv("REPRO_VMEM_BUDGET", raising=False)
    else:
        monkeypatch.setenv("REPRO_VMEM_BUDGET", str(int(budget)))
    return InferenceEngine(
        enc, spec, params, bv, use_pallas=True, tile_n=TILE
    )


def _fused_vmem(eng) -> int:
    """Bytes the resident fused tier needs for the full task set —
    the exact quantity ``_fused_eligible`` compares to the budget."""
    entry = eng._entry(eng.spec.tasks)
    return (
        kops.padded_weight_bytes(entry.spec)
        + kops.activation_bytes(entry.spec, eng.tile_n)
        + int(eng.vexist.words.nbytes)
    )


def _assert_ref_identical(eng, enc, spec, params, bv, keys):
    t = eng.dispatch(keys, want_exists=True)
    path = t.path
    codes, exists = eng.collect(t)
    if exists is None:
        exists = bv.test(keys)
    ref_codes, ref_exists = ref_fused_lookup(params, keys, enc, bv, spec)
    np.testing.assert_array_equal(codes, ref_codes)
    np.testing.assert_array_equal(exists, ref_exists)
    return path


class TestVmemBoundaryTiers:
    """Tier selection must flip exactly at the budget boundary: the
    resident fused kernel at budget and budget+1, a non-resident tier
    one byte under — with byte-identical results on either side."""

    def setup_method(self):
        self.setup = make_lookup_setup(tasks=2)

    def test_budget_surfaces_in_stats(self, monkeypatch):
        enc, spec, params, bv = self.setup
        eng = _engine(enc, spec, params, bv, monkeypatch, budget=123456)
        assert eng.vmem_budget == 123456
        assert eng.stats.vmem_budget_bytes == 123456

    @pytest.mark.parametrize("delta", [0, 1])
    def test_at_and_above_budget_stays_fused(self, monkeypatch, delta):
        enc, spec, params, bv = self.setup
        probe = _engine(enc, spec, params, bv, monkeypatch)
        eng = _engine(
            enc, spec, params, bv, monkeypatch,
            budget=_fused_vmem(probe) + delta,
        )
        keys = np.random.default_rng(0).integers(0, 10000, 300).astype(np.int64)
        path = _assert_ref_identical(eng, enc, spec, params, bv, keys)
        assert path == "fused"
        assert eng.stats.fused_calls >= 1

    def test_one_byte_under_budget_leaves_fused(self, monkeypatch):
        enc, spec, params, bv = self.setup
        probe = _engine(enc, spec, params, bv, monkeypatch)
        eng = _engine(
            enc, spec, params, bv, monkeypatch,
            budget=_fused_vmem(probe) - 1,
        )
        keys = np.random.default_rng(1).integers(0, 10000, 300).astype(np.int64)
        path = _assert_ref_identical(eng, enc, spec, params, bv, keys)
        assert path != "fused"
        assert eng.stats.fused_calls == 0

    def test_streamed_tier_byte_identical(self, monkeypatch):
        """Below the digits tier's weight budget the engine must stream
        head pages (not fail, not fall to jit) and stay byte-identical
        — the kernel runs in interpret mode on CPU."""
        enc, spec, params, bv = self.setup
        probe = _engine(enc, spec, params, bv, monkeypatch)
        entry = probe._entry(spec.tasks)
        pallas_vmem = kops.padded_weight_bytes(
            entry.spec
        ) + kops.activation_bytes(entry.spec, TILE)
        eng = _engine(
            enc, spec, params, bv, monkeypatch, budget=pallas_vmem - 1
        )
        # the squeezed budget must still admit a single-head page
        assert eng._streamed_plan(entry, True) is not None
        for n in (1, 63, 64, 65, 200):
            keys = (
                np.random.default_rng(n).integers(0, 10000, n).astype(np.int64)
            )
            path = _assert_ref_identical(eng, enc, spec, params, bv, keys)
            assert path == "fused_streamed"
        assert eng.stats.fused_streamed_calls >= 5

    def test_streamed_handles_out_of_domain_keys(self, monkeypatch):
        enc, spec, params, bv = self.setup
        probe = _engine(enc, spec, params, bv, monkeypatch)
        entry = probe._entry(spec.tasks)
        pallas_vmem = kops.padded_weight_bytes(
            entry.spec
        ) + kops.activation_bytes(entry.spec, TILE)
        eng = _engine(
            enc, spec, params, bv, monkeypatch, budget=pallas_vmem - 1
        )
        keys = np.array(
            [0, 1, 9999, 10000, 10001, 2**31 - 1, 2**31, 2**40, -1, -7],
            dtype=np.int64,
        )
        _assert_ref_identical(eng, enc, spec, params, bv, keys)

    def test_kernel_filter_capability_follows_tier(self, monkeypatch):
        enc, spec, params, bv = self.setup
        probe = _engine(enc, spec, params, bv, monkeypatch)
        full = _fused_vmem(probe)
        assert _engine(
            enc, spec, params, bv, monkeypatch, budget=full
        ).kernel_filter_capable()
        assert not _engine(
            enc, spec, params, bv, monkeypatch, budget=full - 1
        ).kernel_filter_capable()


PRED_CASES = [
    ("==", 2),
    ("!=", 0),
    ("<", 3),
    ("<=", 1),
    (">", 2),
    (">=", 4),
    ("in", (0, 2, 4)),
]


class TestKernelPredicateFilter:
    """In-kernel predicate filtering must be byte-identical to the
    host filter for every predicate op, report ``kernel_filtered``
    evidence, and survive aux-overridden rows (mutations)."""

    @pytest.fixture(scope="class")
    def stores(self):
        table = make_periodic_table(n=1200, period=16, cards=(5, 3))
        cfg = DeepMappingConfig(
            shared=(32,), private=(8,),
            train=TrainConfig(epochs=10, batch_size=512),
        )
        kernel = DeepMappingStore.build(
            table,
            DeepMappingConfig(
                shared=cfg.shared, private=cfg.private, train=cfg.train,
                use_pallas=True,
            ),
        )
        host = DeepMappingStore.build(table, cfg)
        return table, kernel, host

    def test_capability_flag(self, stores):
        _, kernel, host = stores
        pred = [type("P", (), {"column": "col0"})()]
        assert kernel.supports_kernel_filter(pred)
        assert not host.supports_kernel_filter(pred)
        assert not kernel.supports_kernel_filter(())
        assert not kernel.supports_kernel_filter(
            [type("P", (), {"column": "nope"})()]
        )

    @pytest.mark.parametrize("op,value", PRED_CASES, ids=[c[0] for c in PRED_CASES])
    def test_ops_byte_identical(self, stores, op, value):
        assert op in PREDICATE_OPS
        _, kernel, host = stores
        rk = (
            kernel.query().scan().where("col0", op, value).execute()
        )
        rh = host.query().scan().where("col0", op, value).execute()
        rp = (
            kernel.query().scan().where("col0", op, value)
            .pushdown(False).execute()
        )
        assert rk.explain.kernel_filtered
        assert any("filter[kernel" in p for p in rk.explain.plan)
        np.testing.assert_array_equal(rk.keys, rh.keys)
        np.testing.assert_array_equal(rk.keys, rp.keys)
        for c in rk.values:
            np.testing.assert_array_equal(rk.values[c], rh.values[c])
            np.testing.assert_array_equal(rk.values[c], rp.values[c])

    def test_aux_overridden_rows_patched(self, stores):
        """Rows answered by the aux table carry build-time-corrected
        codes the kernel never saw — the collect-time patch must
        re-filter exactly those."""
        table, kernel, host = stores
        up = table.keys[5:25]
        cols = {
            "col0": np.full(20, 4, dtype=np.int32),
            "col1": np.full(20, 2, dtype=np.int32),
        }
        kernel.update(up, cols)
        host.update(up, cols)
        for op, value in (("==", 4), ("!=", 4), ("<=", 3)):
            rk = kernel.query().scan().where("col0", op, value).execute()
            rh = host.query().scan().where("col0", op, value).execute()
            np.testing.assert_array_equal(rk.keys, rh.keys)
            for c in rk.values:
                np.testing.assert_array_equal(rk.values[c], rh.values[c])


class TestMorselSeed:
    """Pure seeding rule: pick the initial morsel from the model's
    weight bytes instead of always starting at ``DEFAULT_MORSEL``."""

    def test_no_model_seeds_default(self):
        assert seed_morsel_rows(0) == DEFAULT_MORSEL
        assert seed_morsel_rows(-5) == DEFAULT_MORSEL

    def test_calibration_anchor(self):
        # ~300 KB of weights lands on the historical default, so the
        # seed only moves stores that are far from that anchor.
        assert seed_morsel_rows(300_000) == DEFAULT_MORSEL

    def test_tiny_model_seeds_large(self):
        assert seed_morsel_rows(1_000) == ADAPT_MAX

    def test_huge_model_seeds_small(self):
        assert seed_morsel_rows(1 << 30) == ADAPT_MIN

    def test_power_of_two_and_bounds(self):
        for nbytes in (1, 10_000, 123_456, 5_000_000, 1 << 28):
            rows = seed_morsel_rows(nbytes)
            assert ADAPT_MIN <= rows <= ADAPT_MAX
            assert rows & (rows - 1) == 0  # power of two

    def test_max_rows_caps_seed(self):
        assert seed_morsel_rows(1_000, max_rows=1 << 14) == 1 << 14
        # a cap below ADAPT_MIN clamps up, never under
        assert seed_morsel_rows(1_000, max_rows=16) == ADAPT_MIN

    def test_monotone_in_model_size(self):
        sizes = [1 << s for s in range(10, 31, 2)]
        seeds = [seed_morsel_rows(s) for s in sizes]
        assert all(a >= b for a, b in zip(seeds, seeds[1:]))
