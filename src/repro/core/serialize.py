"""On-disk format for DeepMapping hybrid stores.

Directory layout (atomic: written to ``<dir>.tmp`` then renamed):

    store/
      meta.msgpack      — spec, encoder, config, counters, checksums
      params.npz        — model weights (flattened path -> array)
      aux.msgpack       — compacted T_aux state (compressed partitions)
      vexist.bin        — compressed existence bitvector
      decode_<col>.npy  — f_decode arrays (numpy native, no pickle for
                          numeric/string dtypes)

The format is self-describing and versioned; restore works with any
later minor version.  No pickle anywhere — partitions and weights are
raw buffers, metadata is msgpack.

Durability discipline (v2):

* every artifact carries a ``zlib.crc32`` recorded in ``meta.msgpack``
  and verified on load — a bit-flipped or truncated artifact raises
  :class:`~repro.fault.errors.IntegrityError` instead of decoding into
  wrong values;
* ``meta.msgpack`` is written LAST (a directory with a meta file is a
  complete save) and wrapped in a crc32 envelope of its own, so meta
  corruption is detected too, not just artifact corruption;
* every file is fsynced before the tmp-directory rename (and the
  parent directory after), so a crash cannot publish a store whose
  artifacts are still in the page cache;
* a stale ``<dir>.tmp`` from an interrupted save is removed (with a
  warning) on the next load of ``<dir>``.

Reads flow through :func:`read_artifact`, which is instrumented for the
``artifact_read`` fault-injection site — tests corrupt payloads
in-memory (deterministically) and assert the checksums catch it.
"""

from __future__ import annotations

import io
import os
import shutil
import warnings
import zlib
from typing import Dict, Optional

import msgpack
import numpy as np

from repro.core import model as model_lib
from repro.core.aux_table import AuxTable
from repro.core.bitvector import BitVector
from repro.core.encoding import KeyEncoder, ValueCodec
from repro.core.hybrid import DeepMappingConfig, DeepMappingStore
from repro.core.model import MLPSpec
from repro.fault import injection as fault_injection
from repro.fault.errors import IntegrityError
from repro.storage import MemoryPool

#: v2 adds per-artifact crc32 checksums + the meta envelope; v1 stores
#: (no ``checksums`` map, flat meta) still load, without verification.
FORMAT_VERSION = 2


# ------------------------------------------------------------ durability
def crc32(data: bytes) -> int:
    """Stdlib crc32, normalized to unsigned (msgpack round-trip safe)."""
    return zlib.crc32(data) & 0xFFFFFFFF


def fsync_dir(path: str) -> None:
    """fsync a directory so its entries (renames, new files) are
    durable — POSIX requires syncing the directory, not just the
    files inside it."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def write_artifact(
    dirpath: str, name: str, data: bytes, checksums: Dict[str, int]
) -> None:
    """Write one artifact durably (flush + fsync) and record its crc."""
    with open(os.path.join(dirpath, name), "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    checksums[name] = crc32(data)


def read_artifact(
    dirpath: str, name: str, checksums: Optional[Dict[str, int]]
) -> bytes:
    """Read one artifact and verify its crc against ``checksums``.

    ``checksums=None`` (or a map without this artifact — a v1 save)
    skips verification.  The ``artifact_read`` injection site fires
    before the read (raise/delay) and on the payload (corrupt), so the
    corruption path is testable without touching real files.
    """
    fault_injection.maybe_fail("artifact_read", owner=name)
    with open(os.path.join(dirpath, name), "rb") as f:
        data = f.read()
    data = fault_injection.corrupt("artifact_read", name, data)
    if checksums is not None and name in checksums:
        got = crc32(data)
        want = int(checksums[name])
        if got != want:
            raise IntegrityError(
                f"{os.path.join(dirpath, name)}: crc32 mismatch "
                f"(stored {want:#010x}, read {got:#010x}) — artifact is "
                f"corrupt or truncated"
            )
    return data


def clean_stale_tmp(path: str) -> bool:
    """Remove a stale ``<path>.tmp`` left by an interrupted save.

    The atomic-save discipline writes to ``<path>.tmp`` and renames;
    a surviving tmp means a save died mid-write and its contents are
    unverifiable garbage.  Returns True (after warning) if one was
    removed."""
    tmp = path + ".tmp"
    if not os.path.exists(tmp):
        return False
    warnings.warn(
        f"removing stale {tmp!r} left by an interrupted save; the last "
        f"completed save at {path!r} is unaffected",
        RuntimeWarning,
        stacklevel=2,
    )
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    else:
        os.remove(tmp)
    return True


def pack_meta(meta: Dict) -> bytes:
    """Wrap a metadata dict in a self-verifying crc32 envelope."""
    payload = msgpack.packb(meta)
    return msgpack.packb({"crc32": crc32(payload), "payload": payload})


def unpack_meta(blob: bytes, label: str) -> Dict:
    """Open a metadata blob: crc32 envelope (v2) or flat dict (v1)."""
    obj = msgpack.unpackb(blob)
    if isinstance(obj, dict) and "payload" in obj and "crc32" in obj:
        payload = obj["payload"]
        if crc32(payload) != int(obj["crc32"]):
            raise IntegrityError(
                f"{label}: metadata crc32 mismatch — file is corrupt"
            )
        return msgpack.unpackb(payload)
    return obj  # v1 flat metadata, no checksum to verify


# ----------------------------------------------------------- store format
def _flatten_params(params: Dict, prefix: str = "") -> Dict[str, np.ndarray]:
    flat: Dict[str, np.ndarray] = {}

    def rec(node, path):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(v, f"{path}/{k}")
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(v, f"{path}/{i}")
        else:
            flat[path] = np.asarray(node)

    rec(params, prefix)
    return flat


def _unflatten_params(flat: Dict[str, np.ndarray], spec: MLPSpec) -> Dict:
    params = model_lib.init_params(spec, seed=0)
    ref = _flatten_params(params)
    if set(ref) != set(flat):
        raise ValueError("param tree mismatch on load")

    def rec(node, path):
        if isinstance(node, dict):
            return {k: rec(v, f"{path}/{k}") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return [rec(v, f"{path}/{i}") for i, v in enumerate(node)]
        import jax.numpy as jnp

        return jnp.asarray(flat[path])

    return rec(params, "")


def save_store(store: DeepMappingStore, path: str) -> None:
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    checksums: Dict[str, int] = {}

    buf = io.BytesIO()
    np.savez(buf, **_flatten_params(store.params))
    write_artifact(tmp, "params.npz", buf.getvalue(), checksums)

    aux_state = store.aux.to_state()
    aux_blob = msgpack.packb(
        {
            "codec": aux_state["codec"],
            "partition_bytes": aux_state["partition_bytes"],
            "num_values": aux_state["num_values"],
            "partitions": aux_state["partitions"],
            "boundaries": aux_state["boundaries"].tobytes(),
            "part_rows": aux_state["part_rows"],
            "rows": aux_state["rows"],
        }
    )
    write_artifact(tmp, "aux.msgpack", aux_blob, checksums)

    write_artifact(tmp, "vexist.bin", store.vexist.to_bytes(), checksums)

    for col in store.spec.tasks:
        dm = store.codecs[col].decode_map
        if dm.dtype == object:
            dm = dm.astype(str)  # unicode arrays serialize without pickle
        buf = io.BytesIO()
        np.save(buf, dm, allow_pickle=False)
        write_artifact(tmp, f"decode_{col}.npy", buf.getvalue(), checksums)

    meta = {
        "version": FORMAT_VERSION,
        "spec": {
            "base": store.spec.base,
            "width": store.spec.width,
            "shared": list(store.spec.shared),
            "private": [[k, list(v)] for k, v in store.spec.private],
            "out_cards": [[k, v] for k, v in store.spec.out_cards],
            "dtype": store.spec.dtype,
        },
        "encoder": {
            "max_key_capacity": store.encoder.capacity,
            "base": store.encoder.base,
            "residues": list(store.encoder.residues),
        },
        "config": {
            "codec": store.config.codec,
            "partition_bytes": store.config.partition_bytes,
            "base": store.config.base,
        },
        "raw_bytes": store.raw_bytes,
        "num_rows": store.num_rows,
        "modified_bytes": store.modified_bytes,
        "columns": list(store.spec.tasks),
        "checksums": checksums,
    }
    # Meta goes LAST: its presence marks the save complete, so a crash
    # before this point leaves a tmp dir load will never touch.
    with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
        f.write(pack_meta(meta))
        f.flush()
        os.fsync(f.fileno())
    fsync_dir(tmp)

    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    fsync_dir(os.path.dirname(os.path.abspath(path)))


def load_store(path: str, pool: MemoryPool | None = None) -> DeepMappingStore:
    clean_stale_tmp(path)
    meta = unpack_meta(
        read_artifact(path, "meta.msgpack", None),
        os.path.join(path, "meta.msgpack"),
    )
    if meta["version"] > FORMAT_VERSION:
        raise ValueError(f"store format {meta['version']} newer than reader")
    checksums = meta.get("checksums")  # absent on v1 saves

    s = meta["spec"]
    spec = MLPSpec(
        base=s["base"],
        width=s["width"],
        shared=tuple(s["shared"]),
        private={k: tuple(v) for k, v in s["private"]},
        out_cards={k: v for k, v in s["out_cards"]},
        dtype=s["dtype"],
    )
    with np.load(io.BytesIO(read_artifact(path, "params.npz", checksums))) as z:
        flat = {k: z[k] for k in z.files}
    params = _unflatten_params(flat, spec)

    a = msgpack.unpackb(read_artifact(path, "aux.msgpack", checksums))
    aux = AuxTable.from_state(
        {
            "codec": a["codec"],
            "partition_bytes": a["partition_bytes"],
            "num_values": a["num_values"],
            "partitions": a["partitions"],
            "boundaries": np.frombuffer(a["boundaries"], dtype=np.int64),
            "part_rows": a["part_rows"],
            "rows": a["rows"],
        },
        pool=pool,
    )

    vexist = BitVector.from_bytes(read_artifact(path, "vexist.bin", checksums))

    codecs: Dict[str, ValueCodec] = {}
    for col in meta["columns"]:
        dm = np.load(
            io.BytesIO(read_artifact(path, f"decode_{col}.npy", checksums)),
            allow_pickle=False,
        )
        codecs[col] = ValueCodec.from_decode_map(col, dm)

    # Reconstruct the KeyEncoder with the same width/base/residues.
    base = meta["encoder"]["base"]
    cap = meta["encoder"]["max_key_capacity"]
    residues = tuple(meta["encoder"].get("residues", ()))
    enc = KeyEncoder(max_key=max(0, cap - 1), base=base, residues=residues)
    if enc.capacity != cap:
        raise RuntimeError(
            f"corrupt manifest: rebuilt encoder capacity {enc.capacity} "
            f"does not match stored capacity {cap}"
        )

    cfg = DeepMappingConfig(
        base=meta["config"]["base"],
        codec=meta["config"]["codec"],
        partition_bytes=meta["config"]["partition_bytes"],
    )
    store = DeepMappingStore(
        encoder=enc,
        spec=spec,
        params=params,
        codecs=codecs,
        aux=aux,
        vexist=vexist,
        raw_bytes=meta["raw_bytes"],
        num_rows=meta["num_rows"],
        config=cfg,
    )
    store.modified_bytes = meta["modified_bytes"]
    return store
