"""Per-store plan-compilation cache (the adaptive-execution layer's
memory).

The streaming executor recompiles the same artifacts on every
``execute()`` of a repeated plan: the range/scan **key-source
materialization** (an existence-index scan), the resolved **projection
subset** (selected columns extended by post-hoc predicate columns),
and — on DeepMapping stores — the per-predicate boolean **code tables**
over a column's decode map.  Learned-index practice (RMI, NeurStore)
keeps learned/compiled artifacts resident across queries instead of
rebuilding them per call; :class:`PlanCache` does the same for plan
artifacts.

Every :class:`~repro.api.protocol.MappingStore` owns one lazily-created
``PlanCache`` (``store.plan_cache()``).  Entries are keyed by a **plan
fingerprint** (:func:`plan_fingerprint` — the plan minus its execution
knobs) and validated against the store's **mutation version**
(``store.mutation_version()``): every ``insert``/``delete``/``update``
bumps the version, so a cached key stream or code table can never
outlive the state it was computed from.  ``ValueCodec.extend`` growing
a decode map only ever happens inside ``insert``/``update``, so the
version bump covers decode-map growth too; the code-table memo
additionally checks decode-map object identity as a second fence.

The cache is bounded (LRU over plan entries, hard cap on predicate
tables) and advisory: a miss or an unfingerprintable plan (e.g. an
unhashable predicate literal) falls back to recomputation — never to
an error.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Optional, Tuple

import numpy as np

from repro import obs

#: LRU capacity for plan-level entries (fingerprint -> artifacts).
PLAN_ENTRIES = 64

#: Byte budget for cached key-stream materializations.  Entries pin
#: O(num_rows) int64 arrays (a scan of a 100M-row store is 800 MB), so
#: the LRU must bound BYTES, not just entry count — varying-bound range
#: plans on a huge store would otherwise pin ``PLAN_ENTRIES`` full key
#: streams.
KEY_BYTES_BUDGET = 256 * 1024 * 1024

#: Hard cap on memoized predicate/aggregate code tables (ad-hoc
#: predicate churn must not grow the cache without bound; tables are
#: tiny, so a full clear on overflow is cheaper than LRU bookkeeping).
PRED_TABLES = 64

#: Per-key cap on code-table variants.  A cache shared across
#: federation members (or store generations) holds one entry per
#: distinct (version, decode-map) pair under the same predicate/column
#: key; a small list avoids two members at different versions
#: thrashing a single slot.
TABLE_VARIANTS = 8


def plan_fingerprint(plan) -> Optional[Tuple]:
    """Cache key for a plan's compiled artifacts, or ``None`` when the
    plan cannot be fingerprinted (unhashable predicate literal) or has
    caching disabled.

    The fingerprint covers exactly what determines the cached
    artifacts: the key source (``range``/``scan`` bounds — point plans
    carry their keys explicitly, so only their projection/predicate
    artifacts are shared), the projection, the predicate conjunction,
    and the pushdown switch (post-hoc plans extend the decode set by
    predicate columns).  Execution knobs (morsel size, fan-out, error
    mode) are deliberately excluded: adaptive morsel resizing or
    switching to ``on_error='partial')`` must not bust the cache.
    """
    if not plan.cache:
        return None
    if plan.kind == "point":
        source: Tuple = ("point",)
    elif plan.kind == "range":
        source = ("range", int(plan.lo), int(plan.hi))
    else:
        source = ("scan",)
    fp = source + (
        plan.columns,
        plan.predicates,
        plan.pushdown,
        plan.group_by,
        plan.aggregates,
    )
    # ``plan.join`` is deliberately excluded: the cached artifacts (key
    # stream, resolved projection, code tables) describe the LEFT side
    # only — the right store answers probes through its own hooks, so a
    # plan with and without a join shares its compiled left half.
    try:
        hash(fp)
    except TypeError:  # unhashable predicate literal — skip the cache
        return None
    return fp


class _PlanEntry:
    """One cached plan's artifacts (``keys`` is ``None`` for point
    plans — their key stream arrives with the plan)."""

    __slots__ = ("version", "keys", "columns")

    def __init__(self, version, keys: Optional[np.ndarray], columns):
        self.version = version
        self.keys = keys
        self.columns = columns


class PlanCache:
    """Bounded per-store memo of plan-compilation artifacts.

    Three memo surfaces:

    * :meth:`get`/:meth:`put` — plan-level artifacts (key-source
      materialization, resolved projection subset), LRU-bounded;
    * :meth:`pred_table` — predicate -> boolean code table over a
      column's decode map (the DeepMapping pushdown compile);
    * :attr:`hits`/:attr:`misses`/:attr:`bypass` — cache telemetry
      (the benchmark's warm-vs-cold evidence), mirrored into the
      metrics registry as ``deepmap_plan_cache_events_total{outcome}``.

    Every entry records the store's mutation version at compute time
    and is dropped on mismatch, so stale artifacts are structurally
    unreachable.

    All state mutations are guarded by one lock: the sharded and
    federated stores' collect halves run on ``LazyFanoutPool`` threads,
    so concurrent ``get``/``put``/``pred_table`` calls on a single
    store's cache are routine, not exotic.  The predicate code-table
    *compute* runs outside the lock (a duplicated compute under a race
    is benign; serializing ``Predicate.code_table`` is not).
    """

    def __init__(
        self,
        plan_entries: int = PLAN_ENTRIES,
        pred_tables: int = PRED_TABLES,
        key_bytes_budget: int = KEY_BYTES_BUDGET,
    ):
        """Create an empty cache with the given capacity bounds."""
        self._plan_entries = int(plan_entries)
        self._pred_tables = int(pred_tables)
        self._key_bytes_budget = int(key_bytes_budget)
        self._key_bytes = 0  # guarded-by: _lock
        self._plans: "OrderedDict[Tuple, _PlanEntry]" = OrderedDict()  # guarded-by: _lock
        # key -> list of (version, decode_map, table) variants; keys are
        # Predicate objects (filter tables) or ("agg", column) tuples
        # (aggregate value tables)
        self._tables: Dict = {}  # guarded-by: _lock
        self._lock = threading.Lock()
        self.hits = 0  # guarded-by: _lock
        self.misses = 0  # guarded-by: _lock
        self.bypass = 0  # guarded-by: _lock
        self.table_hits = 0  # guarded-by: _lock
        self.table_misses = 0  # guarded-by: _lock

    def _note(self, outcome: str) -> None:
        obs.counter(
            "deepmap_plan_cache_events_total",
            "Plan-cache lookups by outcome (hit/miss/bypass).",
        ).inc(outcome=outcome)

    # -------------------------------------------------------- plan entries
    def get(self, fingerprint: Optional[Tuple], version) -> Optional[_PlanEntry]:
        """Look up a plan entry; a version mismatch evicts and misses;
        an unfingerprintable plan (``None``) counts as a bypass."""
        if fingerprint is None:
            with self._lock:
                self.bypass += 1
            self._note("bypass")
            return None
        with self._lock:
            entry = self._plans.get(fingerprint)
            if entry is not None and entry.version != version:
                self._evict(fingerprint)
                entry = None
            if entry is None:
                self.misses += 1
            else:
                self._plans.move_to_end(fingerprint)
                self.hits += 1
        self._note("miss" if entry is None else "hit")
        return entry

    def _evict(self, fingerprint: Tuple) -> None:  # holds-lock: _lock
        entry = self._plans.pop(fingerprint)
        if entry.keys is not None:
            self._key_bytes -= int(entry.keys.nbytes)

    def put(
        self,
        fingerprint: Optional[Tuple],
        version,
        keys: Optional[np.ndarray],
        columns,
    ) -> None:
        """Insert a plan entry (LRU-evicting over BOTH the entry count
        and the key-stream byte budget — key materializations are
        O(num_rows) and must not pin unbounded memory).

        Cached key arrays are frozen (``writeable=False``) so a
        downstream consumer can never corrupt a shared stream.  A
        single key stream larger than the whole budget is not cached
        at all (columns still are).
        """
        if fingerprint is None:
            return
        nbytes = 0
        if keys is not None:
            keys = np.asarray(keys)
            keys.flags.writeable = False
            nbytes = int(keys.nbytes)
            if nbytes > self._key_bytes_budget:
                keys, nbytes = None, 0
        with self._lock:
            while self._plans and (
                len(self._plans) >= self._plan_entries
                or self._key_bytes + nbytes > self._key_bytes_budget
            ):
                self._evict(next(iter(self._plans)))
            self._key_bytes += nbytes
            self._plans[fingerprint] = _PlanEntry(version, keys, columns)

    # ----------------------------------------- predicate/aggregate tables
    def _table_memo(self, key, decode_map: np.ndarray, version, compute) -> np.ndarray:
        """Shared memo for code-indexed tables (predicate filter tables
        and aggregate value tables).

        An entry matches on the store's mutation version AND the decode
        map — by object identity first (``ValueCodec.extend`` swaps in
        a new, larger array), falling back to an ``array_equal``
        content check so a cache shared across federation members lets
        member B reuse the table member A compiled when their
        vocabularies coincide (the cross-member sharing this repo's
        federation sets up).  Up to :data:`TABLE_VARIANTS` variants per
        key accommodate members at different versions/vocabularies.
        The compute itself runs outside the lock — two racing threads
        may both build the same table (benign), but neither blocks the
        other.
        """
        with self._lock:
            variants = self._tables.get(key, ())
            for entry in variants:
                if entry[0] == version and (
                    entry[1] is decode_map
                    or (
                        entry[1].dtype == decode_map.dtype
                        and np.array_equal(entry[1], decode_map)
                    )
                ):
                    self.table_hits += 1
                    return entry[2]
            self.table_misses += 1
        table = compute()
        with self._lock:
            if sum(len(v) for v in self._tables.values()) >= self._pred_tables:
                self._tables.clear()
            variants = self._tables.setdefault(key, [])
            if len(variants) >= TABLE_VARIANTS:
                del variants[0]
            variants.append((version, decode_map, table))
        return table

    def pred_table(self, pred, decode_map: np.ndarray, version) -> np.ndarray:
        """Memoized boolean code table for one predicate over
        ``decode_map`` (see ``Predicate.code_table``); version- and
        decode-map-fenced through :meth:`_table_memo`.  Unhashable
        predicate literals compute uncached."""
        try:
            hash(pred)
        except TypeError:  # unhashable literal (e.g. an array) — skip memo
            return pred.code_table(decode_map)
        return self._table_memo(
            pred, decode_map, version, lambda: pred.code_table(decode_map)
        )

    def agg_table(self, column: str, decode_map: np.ndarray, version) -> np.ndarray:
        """Memoized code→value table for ``sum``/``min``/``max`` below
        decode (see :func:`~repro.api.plan.agg_value_table`): the
        column's decode map cast once to the accumulator dtype, fenced
        exactly like the predicate tables."""
        from repro.api.plan import agg_value_table

        return self._table_memo(
            ("agg", column),
            decode_map,
            version,
            lambda: agg_value_table(column, decode_map),
        )

    # ------------------------------------------------------------- control
    def clear(self) -> None:
        """Drop every cached artifact (the benchmark's cold path)."""
        with self._lock:
            self._plans.clear()
            self._tables.clear()
            self._key_bytes = 0

    def __len__(self) -> int:
        """Number of live plan entries (predicate tables excluded)."""
        with self._lock:
            return len(self._plans)
