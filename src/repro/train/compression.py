"""Distributed-optimization tricks: int8 error-feedback gradient
compression and hierarchical cross-pod reduction.

At 1000+ nodes the inter-pod (DCN) hop is the gradient bottleneck:
int8 quantization cuts it 4x vs fp32 (2x vs bf16) and ERROR FEEDBACK
(residual carried into the next step) keeps SGD convergence —
1-bit-Adam/EF-SGD lineage.  ``hierarchical_psum`` reduce-scatters over
the fast intra-pod ICI first, all-reduces only the scattered shard over
the slow ``pod`` axis, then all-gathers — the DCN sees 1/N_data of the
gradient bytes.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    """Per-leaf error-feedback residuals (same structure as grads)."""

    residual: Dict


def ef_init(grads_like) -> EFState:
    return EFState(residual=jax.tree.map(jnp.zeros_like, grads_like))


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef: EFState) -> Tuple[Dict, EFState]:
    """Quantize (grad + residual) to int8; residual keeps what was lost.

    Returns (compressed tree of (q, scale), new EF state).  The caller
    transmits ``q``/``scale`` over the slow link and dequantizes on the
    far side; convergence-critical information is never dropped, only
    delayed — the EF guarantee."""

    def one(g, r):
        target = g.astype(jnp.float32) + r.astype(jnp.float32)
        q, scale = quantize_int8(target)
        deq = dequantize_int8(q, scale)
        return (q, scale), (target - deq).astype(r.dtype)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    qs, rs = [], []
    for g, r in zip(flat_g, flat_r):
        (q, s), nr = one(g, r)
        qs.append((q, s))
        rs.append(nr)
    return (
        jax.tree_util.tree_unflatten(treedef, qs),
        EFState(residual=jax.tree_util.tree_unflatten(treedef, rs)),
    )


def decompress_grads(compressed) -> Dict:
    return jax.tree.map(
        lambda qs: dequantize_int8(*qs),
        compressed,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2,
    )


# -- hierarchical collectives (shard_map domain) ------------------------------


def hierarchical_psum(x: jnp.ndarray, intra_axis: str = "data", inter_axis: str = "pod"):
    """DCN-friendly sum-reduction inside ``shard_map``:

    reduce-scatter over ``intra_axis`` (fast ICI) -> all-reduce the 1/N
    shard over ``inter_axis`` (slow DCN) -> all-gather over ``intra_axis``.
    Wire bytes on the DCN drop by the intra-pod world size vs a flat
    psum over both axes.  The tensor is flattened into a padded 1-D
    bucket first (production gradient buckets), so any shape works."""
    n = jax.lax.psum(1, intra_axis)
    shape, size = x.shape, x.size
    flat = x.reshape(-1)
    pad = (-size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    shard = jax.lax.psum_scatter(flat, intra_axis, scatter_dimension=0, tiled=True)
    shard = jax.lax.psum(shard, inter_axis)
    full = jax.lax.all_gather(shard, intra_axis, axis=0, tiled=True)
    return full[:size].reshape(shape)


def compressed_cross_pod_mean(grads, ef: EFState, inter_axis: str = "pod"):
    """Inside shard_map: int8-compress, mean-reduce across pods on the
    compressed representation (dequant -> psum -> requant would lose the
    EF guarantee, so we reduce dequantized fp32 of the int8 payload —
    the WIRE carried int8), return fp32 grads + new EF state."""
    compressed, ef = compress_grads(grads, ef)

    def reduce_one(qs):
        q, scale = qs
        deq = dequantize_int8(q, scale)
        return jax.lax.pmean(deq, inter_axis)

    reduced = jax.tree.map(
        reduce_one, compressed,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2,
    )
    return reduced, ef
