"""Training launcher.

On real hardware this is the per-host entrypoint (jax.distributed
initialization happens before any device use); on this container it
runs reduced configs on the host mesh.  Wires together: arch registry,
sharded train step, deterministic loader (optionally through the
DeepMapping-compressed token store), fault-tolerant runner with atomic
async checkpoints, straggler watchdog.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 50 --smoke --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--compressed-data", action="store_true")
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    args = ap.parse_args()

    import jax

    from repro.configs import get_arch
    from repro.data.loader import LoaderConfig, TokenBatchLoader
    from repro.data.tokens import DeepMappingTokenStore, make_structured_tokens
    from repro.launch.mesh import make_host_mesh
    from repro.sharding.partition import batch_shardings, state_shardings
    from repro.train.fault_tolerance import StepWatchdog, run_training
    from repro.train.optimizer import adamw, warmup_cosine
    from repro.train.train_step import init_state, make_train_step

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    if cfg.is_encoder_decoder or cfg.modality != "text":
        raise SystemExit("this launcher drives text decoder archs")

    toks = make_structured_tokens(200_000, vocab=cfg.vocab_size, run_len=8, seed=0)
    loader_cfg = LoaderConfig(global_batch=args.batch, seq_len=args.seq, seed=0)
    if args.compressed_data:
        store = DeepMappingTokenStore.build(toks, verbose=True)
        loader = TokenBatchLoader(loader_cfg, store=store)
    else:
        loader = TokenBatchLoader(loader_cfg, tokens=toks)

    opt = adamw(lr=warmup_cosine(3e-3, 10, args.steps), max_grad_norm=1.0)
    state = init_state(cfg, opt, seed=0)
    step = make_train_step(cfg, opt)
    mesh = make_host_mesh(args.data_mesh, args.model_mesh)
    st_like = jax.eval_shape(lambda: init_state(cfg, opt, seed=0))
    st_sh = state_shardings(cfg, mesh, st_like)
    batch0 = {k: jax.numpy.asarray(v) for k, v in loader.batch_for_step(0).items()}
    b_sh = batch_shardings(cfg, mesh, batch0)
    with mesh:
        step_fn = jax.jit(step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None))

        def batch_fn(s):
            return {k: jax.numpy.asarray(v) for k, v in loader.batch_for_step(s).items()}

        wd = StepWatchdog()
        t0 = time.time()
        report = run_training(
            step_fn, state, batch_fn, num_steps=args.steps,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, watchdog=wd,
        )
    print(
        f"arch={args.arch} steps={report.final_step} restarts={report.restarts} "
        f"stragglers={len(report.straggler_events)} wall={time.time()-t0:.1f}s"
    )
    print(f"loss {report.losses[0]:.4f} -> {report.losses[-1]:.4f}")


if __name__ == "__main__":
    main()
