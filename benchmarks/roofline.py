"""Roofline analysis (assignment deliverable g).

Reads the dry-run records (``results/dryrun.jsonl`` for compilability,
``results/dryrun_unrolled.jsonl`` for loop-accurate metrics — XLA cost
analysis counts while bodies once, so only unrolled records give true
per-step FLOPs/bytes) and derives the three per-device roofline terms
on TPU v5e constants:

    compute_s    = HLO_FLOPs_per_device  / 197e12   (bf16 peak)
    memory_s     = HLO_bytes_per_device  / 819e9    (HBM bandwidth)
    collective_s = collective_bytes_per_device / 50e9  (ICI link)

plus MODEL_FLOPS (6·N·D train / 2·N·D prefill / 2·N·B decode, MoE uses
active params) and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link

from repro.configs import SHAPES, get_arch  # noqa: E402


def model_flops_per_device(arch: str, shape: str, chips: int) -> float:
    cfg = get_arch(arch).config
    sh = SHAPES[shape]
    n_active = cfg.active_param_count_estimate()
    if sh["kind"] == "train":
        tokens = sh["seq_len"] * sh["global_batch"]
        total = 6.0 * n_active * tokens
    elif sh["kind"] == "prefill":
        tokens = sh["seq_len"] * sh["global_batch"]
        if cfg.is_encoder_decoder:
            tokens = sh["seq_len"] * sh["global_batch"] + 1024 * sh["global_batch"]
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * sh["global_batch"]
    return total / chips


def load_records(paths: List[str], variant: str | None = None) -> Dict:
    """Last-wins per (arch, shape, mesh) for the given variant (None =
    baseline); unrolled records preferred."""
    recs: Dict = {}
    for path in paths:
        if not os.path.exists(path):
            continue
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if not r.get("ok") or r.get("variant") != variant:
                    continue
                key = (r["arch"], r["shape"], r["mesh"])
                if key in recs and recs[key].get("unrolled") and not r.get("unrolled"):
                    continue
                recs[key] = r
    return recs


def compare_variants(arch: str, shape: str, mesh: str = "16x16", paths=None) -> List[Dict]:
    """§Perf helper: baseline vs every tagged variant for one cell."""
    paths = paths or ["results/dryrun.jsonl", "results/dryrun_unrolled.jsonl",
                      "results/dryrun_perf.jsonl"]
    variants: Dict[str, Dict] = {}
    for path in paths:
        if not os.path.exists(path):
            continue
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if not r.get("ok"):
                    continue
                if (r["arch"], r["shape"], r["mesh"]) != (arch, shape, mesh):
                    continue
                tag = r.get("variant") or "baseline"
                if tag in variants and variants[tag].get("unrolled") and not r.get("unrolled"):
                    continue
                variants[tag] = r
    out = []
    for tag in sorted(variants, key=lambda t: (t != "baseline", t)):
        a = analyse(variants[tag])
        a["variant"] = tag
        out.append(a)
    return out


def analyse(rec: Dict) -> Dict:
    chips = rec["chips"]
    flops = rec.get("flops_per_device", 0.0)
    compute_s = flops / PEAK_FLOPS
    memory_s = rec.get("bytes_accessed_per_device", 0.0) / HBM_BW
    coll_s = rec.get("collective_bytes_total", 0) / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], chips)
    useful = mf / flops if flops else 0.0
    bound_s = max(terms.values())
    roofline_frac = (mf / PEAK_FLOPS) / bound_s if bound_s else 0.0
    fixes = {
        "compute": "cut non-model FLOPs (remat policy, fused attention, avoid "
                   "replicated compute on the model axis)",
        "memory": "larger microbatch / fused layers to raise arithmetic "
                  "intensity; bf16 cache; better layouts",
        "collective": "reshard to kill involuntary re-gathers; overlap "
                      "collectives with compute; hierarchical / compressed "
                      "reductions",
    }
    return {
        **{k: rec.get(k) for k in ("arch", "shape", "mesh", "kind", "unrolled")},
        "chips": chips,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops_per_device": mf,
        "hlo_flops_per_device": flops,
        "useful_ratio": useful,
        "roofline_fraction": roofline_frac,
        "next_move": fixes[dominant],
    }


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | MODEL/HLO flops | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} |\n"
        )
    return hdr + body


def run(paths=None, mesh: Optional[str] = "16x16", emit_csv: bool = True) -> List[Dict]:
    paths = paths or ["results/dryrun.jsonl", "results/dryrun_unrolled.jsonl"]
    recs = load_records(paths)
    rows = []
    for key in sorted(recs):
        r = recs[key]
        if mesh and r["mesh"] != mesh:
            continue
        a = analyse(r)
        rows.append(a)
        if emit_csv:
            print(
                f"roofline/{a['arch']}/{a['shape']}/{a['mesh']},"
                f"{max(a['compute_s'], a['memory_s'], a['collective_s']) * 1e6:.1f},"
                f"dominant={a['dominant']};useful={a['useful_ratio']:.2f};"
                f"frac={a['roofline_fraction']:.2f}"
            )
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = run(mesh=None if args.mesh == "all" else args.mesh,
               emit_csv=not args.markdown)
    if args.markdown:
        md = to_markdown(rows)
        if args.out:
            with open(args.out, "w") as f:
                f.write(md)
        else:
            print(md)


if __name__ == "__main__":
    main()
