import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.encoding import KeyEncoder
from repro.core.model import (
    MLPSpec,
    count_params,
    forward_digits,
    forward_onehot,
    init_params,
    model_size_bytes,
    predict_codes,
)


def make_spec(shared=(32, 16), private=(8,), cards=(5, 3), base=10, width=4):
    return MLPSpec(
        base=base,
        width=width,
        shared=shared,
        private={f"c{i}": private for i in range(len(cards))},
        out_cards={f"c{i}": c for i, c in enumerate(cards)},
    )


class TestMLPSpec:
    def test_hashable_and_stable(self):
        a = make_spec()
        b = make_spec()
        assert hash(a) == hash(b) and a == b
        assert a.tasks == ("c0", "c1")

    def test_num_params_matches_init(self):
        spec = make_spec()
        params = init_params(spec, seed=0)
        assert count_params(params) == spec.num_params()
        assert model_size_bytes(params) == spec.size_bytes()

    @pytest.mark.parametrize(
        "shared,private",
        [((), ()), ((16,), ()), ((), (8,)), ((32, 16), (8, 4))],
    )
    def test_degenerate_depths(self, shared, private):
        """DAG search space includes 0-hidden paths (input->output edge)."""
        spec = make_spec(shared=shared, private=private)
        params = init_params(spec)
        digits = jnp.asarray(np.random.default_rng(0).integers(0, 10, (7, 4)), jnp.int32)
        out = forward_digits(params, digits, spec)
        assert out["c0"].shape == (7, 5) and out["c1"].shape == (7, 3)
        assert count_params(params) == spec.num_params()


class TestForward:
    def test_gather_matches_onehot(self):
        """The gather fast path must equal dense-on-one-hot exactly."""
        enc = KeyEncoder(max_key=9999, base=10)
        spec = make_spec(width=enc.width)
        params = init_params(spec, seed=1)
        keys = np.array([0, 42, 9999, 1234], dtype=np.int64)
        digits = jnp.asarray(enc.digits(keys))
        onehot = jnp.asarray(enc.onehot(keys))
        out_d = forward_digits(params, digits, spec)
        out_o = forward_onehot(params, onehot, spec)
        for t in spec.tasks:
            np.testing.assert_allclose(out_d[t], out_o[t], rtol=1e-5, atol=1e-5)

    def test_predict_codes_shape_order(self):
        spec = make_spec(cards=(5, 3))
        params = init_params(spec)
        digits = jnp.zeros((11, 4), jnp.int32)
        codes = predict_codes(params, digits, spec)
        assert codes.shape == (11, 2)
        assert codes.dtype == jnp.int32
        assert (codes[:, 0] < 5).all() and (codes[:, 1] < 3).all()

    def test_jit_and_grad(self):
        spec = make_spec()
        params = init_params(spec)
        digits = jnp.zeros((4, 4), jnp.int32)

        @jax.jit
        def loss(p):
            out = forward_digits(p, digits, spec)
            return sum(jnp.sum(v**2) for v in out.values())

        g = jax.grad(loss)(params)
        assert jnp.isfinite(loss(params))
        flat = jax.tree.leaves(g)
        assert all(jnp.all(jnp.isfinite(x)) for x in flat)

    def test_no_nans_large_batch(self):
        spec = make_spec(shared=(64,), private=())
        params = init_params(spec)
        digits = jnp.asarray(
            np.random.default_rng(0).integers(0, 10, (4096, 4)), jnp.int32
        )
        out = forward_digits(params, digits, spec)
        for v in out.values():
            assert bool(jnp.all(jnp.isfinite(v)))
