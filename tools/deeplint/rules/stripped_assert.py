"""Rule ``stripped-assert``: bare ``assert`` guarding runtime behaviour.

``python -O`` strips ``assert`` statements, so an assert that validates
user input, shapes, or invariants silently becomes a no-op in optimised
deployments.  Raise ``ValueError``/``RuntimeError`` instead.  Test code is
out of scope (the engine is pointed at ``src/repro``); a deliberate
debug-only assert can carry ``# deeplint: ignore[stripped-assert]``.
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from tools.deeplint.engine import Finding, Project

RULE_ID = "stripped-assert"
SUMMARY = (
    "bare assert in runtime code is stripped under python -O; "
    "raise an explicit error instead"
)


def check(project: Project) -> Iterable[Finding]:
    findings: List[Finding] = []
    for src in project.modules:
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Assert):
                findings.append(
                    src.finding(
                        RULE_ID,
                        node,
                        "bare assert is stripped under python -O; raise "
                        "ValueError/RuntimeError for runtime guards",
                    )
                )
    return findings
