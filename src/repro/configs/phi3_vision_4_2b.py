"""phi-3-vision-4.2b — phi3-mini backbone + CLIP frontend (stubbed)
[hf:microsoft/Phi-3-vision-128k-instruct].  32L d_model=3072 32H
(kv=32 -> MHA) d_ff=8192 vocab=32064.  ``input_specs`` supplies
precomputed patch embeddings merged as a sequence prefix."""

from repro.configs.base import ArchSpec, register
from repro.models.config import ModelConfig

NUM_PATCHES = 576  # 336px CLIP ViT-L/14 -> 24x24 patches

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    modality="vision",
)

SMOKE = ModelConfig(
    name="phi3v-smoke",
    family="dense",
    num_layers=2,
    d_model=48,
    num_heads=4,
    num_kv_heads=4,
    d_ff=96,
    vocab_size=128,
    modality="vision",
    dtype="float32",
    remat="none",
)

SPEC = register(
    ArchSpec(
        arch_id="phi-3-vision-4.2b",
        config=CONFIG,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        notes="VLM: text backbone + stub patch embeds; full attention -> long_500k skipped.",
    )
)
