"""Sharded cluster tests: routing invariants, single-store equivalence
(including after interleaved modifications and per-shard retrain),
manifest round-trip, shared-pool eviction pressure, serving."""

import os

import numpy as np
import pytest

from conftest import make_periodic_table, make_random_table
from repro.cluster import (
    ClusterConfig,
    HashPartitioner,
    Partitioner,
    RangePartitioner,
    ShardedDeepMappingStore,
    ShardRouter,
    load_sharded_store,
    plan_range_partitions,
    save_sharded_store,
)
from repro.core import DeepMappingConfig, DeepMappingStore
from repro.core.trainer import TrainConfig
from repro.serve import LookupServer
from repro.storage import MemoryPool

FAST = DeepMappingConfig(
    shared=(64,), private=(16,), train=TrainConfig(epochs=15, batch_size=512)
)


def assert_equivalent(single, cluster, query_keys):
    """(values, exists) equality on the existence-masked contract."""
    v1, e1 = single.lookup(query_keys)
    v2, e2 = cluster.lookup(query_keys)
    np.testing.assert_array_equal(e1, e2)
    assert set(v1) == set(v2)
    for c in v1:
        np.testing.assert_array_equal(v1[c][e1], v2[c][e2])


class TestPartitioner:
    def test_range_planner_balances_rows(self):
        rng = np.random.default_rng(0)
        # skewed key space: dense prefix + sparse tail
        keys = np.unique(
            np.concatenate([np.arange(500), rng.integers(10_000, 10**6, 500)])
        ).astype(np.int64)
        part = plan_range_partitions(keys, 4)
        assert part.num_shards == 4
        counts = np.bincount(part.shard_of(keys), minlength=4)
        assert counts.min() >= len(keys) // 8  # quantile split, not width split

    def test_range_assignment_is_contiguous(self):
        part = RangePartitioner([100, 200])
        sid = part.shard_of(np.array([0, 99, 100, 150, 199, 200, 10**9]))
        np.testing.assert_array_equal(sid, [0, 0, 1, 1, 1, 2, 2])

    def test_range_shards_for_range(self):
        part = RangePartitioner([100, 200])
        np.testing.assert_array_equal(part.shards_for_range(0, 50), [0])
        np.testing.assert_array_equal(part.shards_for_range(50, 150), [0, 1])
        np.testing.assert_array_equal(part.shards_for_range(0, 10**9), [0, 1, 2])
        assert part.shards_for_range(5, 5).size == 0

    def test_hash_is_deterministic_and_uniform(self):
        part = HashPartitioner(8, seed=7)
        keys = np.arange(0, 80_000, 2, dtype=np.int64)  # strided, low entropy
        sid = part.shard_of(keys)
        np.testing.assert_array_equal(sid, part.shard_of(keys))
        counts = np.bincount(sid, minlength=8)
        assert counts.min() > 0.8 * keys.size / 8  # mixer kills the stride

    def test_state_roundtrip(self):
        for part in (RangePartitioner([10, 20, 30]), HashPartitioner(5, seed=3)):
            clone = Partitioner.from_state(part.to_state())
            keys = np.arange(100, dtype=np.int64)
            np.testing.assert_array_equal(part.shard_of(keys), clone.shard_of(keys))


class TestRouter:
    def test_scatter_partitions_request(self):
        router = ShardRouter(HashPartitioner(4))
        keys = np.arange(1000, dtype=np.int64)
        batches = router.scatter(keys)
        assert sum(b.keys.size for b in batches) == keys.size
        recon = np.zeros_like(keys)
        for b in batches:
            recon[b.positions] = b.keys
        np.testing.assert_array_equal(recon, keys)

    def test_scatter_empty(self):
        assert ShardRouter(HashPartitioner(4)).scatter(np.zeros(0, np.int64)) == []


@pytest.fixture(scope="module", params=["range", "hash"])
def equivalent_pair(request):
    table = make_periodic_table(n=1600)
    single = DeepMappingStore.build(table, FAST)
    cluster = ShardedDeepMappingStore.build(
        table, FAST, ClusterConfig(num_shards=4, policy=request.param)
    )
    return table, single, cluster


class TestEquivalence:
    def test_lookup_matches_single_store(self, equivalent_pair):
        table, single, cluster = equivalent_pair
        assert cluster.num_shards == 4
        rng = np.random.default_rng(0)
        q = np.concatenate(
            [
                rng.permutation(table.keys),      # every existing key, shuffled
                table.keys[:100] + 1,             # stride-2 -> odd keys absent
                np.array([10**8], dtype=np.int64),  # far out of domain
            ]
        )
        assert_equivalent(single, cluster, q)

    def test_range_lookup_matches_single_store(self, equivalent_pair):
        table, single, cluster = equivalent_pair
        lo, hi = int(table.keys[100]), int(table.keys[900])
        k1, v1 = single.range_lookup(lo, hi)
        k2, v2 = cluster.range_lookup(lo, hi)
        np.testing.assert_array_equal(k1, k2)
        for c in v1:
            np.testing.assert_array_equal(v1[c], v2[c])

    def test_accounting_aggregates(self, equivalent_pair):
        _, _, cluster = equivalent_pair
        bd = cluster.size_breakdown()
        assert set(bd) == {"model", "aux_table", "exist_bitvector", "decode_map"}
        assert cluster.size_bytes() == sum(bd.values())
        assert 0.0 <= cluster.memorized_fraction() <= 1.0


class TestModificationEquivalence:
    @pytest.mark.parametrize("policy", ["range", "hash"])
    def test_interleaved_modifications_match_single_store(self, policy):
        table = make_periodic_table(n=900)
        single = DeepMappingStore.build(table, FAST)
        cluster = ShardedDeepMappingStore.build(
            table, FAST, ClusterConfig(num_shards=4, policy=policy)
        )
        rng = np.random.default_rng(1)
        base = int(table.keys.max())
        ins = np.arange(base + 3, base + 103, dtype=np.int64)
        cols = {
            "col0": rng.integers(0, 5, ins.size).astype(np.int32),
            "col1": rng.integers(0, 3, ins.size).astype(np.int32),
        }
        upd = {
            "col0": rng.integers(0, 5, 40).astype(np.int32),
            "col1": rng.integers(0, 3, 40).astype(np.int32),
        }
        for store in (single, cluster):
            store.insert(ins, cols)
            store.update(ins[:40], upd)
            store.delete(ins[40:70])
            store.delete(ins[40:70])  # idempotent
            store.update(table.keys[:10], {c: v[:10] for c, v in upd.items()})
            store.delete(table.keys[10:20])
        q = np.concatenate([table.keys, ins, ins + 200])
        assert_equivalent(single, cluster, q)
        assert single.num_rows == cluster.num_rows

    def test_insert_existing_raises_without_partial_mutation(self):
        table = make_periodic_table(n=600)
        cluster = ShardedDeepMappingStore.build(
            table, FAST, ClusterConfig(num_shards=4, policy="range")
        )
        base = int(table.keys.max())
        keys = np.array([base + 11, int(table.keys[0])], dtype=np.int64)  # 2nd exists
        with pytest.raises(ValueError):
            cluster.insert(
                keys,
                {"col0": np.array([1, 1], np.int32), "col1": np.array([1, 1], np.int32)},
            )
        _, exists = cluster.lookup(keys[:1])
        assert not exists.any()  # no shard mutated before validation failed

    def test_update_missing_raises(self):
        table = make_periodic_table(n=600)
        cluster = ShardedDeepMappingStore.build(
            table, FAST, ClusterConfig(num_shards=4, policy="hash")
        )
        with pytest.raises(ValueError):
            cluster.update(
                np.array([10**7]), {"col0": np.array([1]), "col1": np.array([1])}
            )


class TestPerShardRetrain:
    def test_only_dirty_shards_retrain(self):
        cfg = DeepMappingConfig(
            shared=(64,),
            private=(16,),
            train=TrainConfig(epochs=15, batch_size=512),
            retrain_after_modified_bytes=1,
        )
        table = make_periodic_table(n=800)
        cluster = ShardedDeepMappingStore.build(
            table, cfg, ClusterConfig(num_shards=4, policy="range")
        )
        untouched = [id(s) for s in cluster.shards]
        assert not cluster.should_retrain()
        # Dirty exactly one shard: modify the lowest-range keys.
        k = table.keys[:2]
        cluster.update(
            k, {"col0": np.array([1, 2], np.int32), "col1": np.array([0, 1], np.int32)}
        )
        dirty = cluster.dirty_shards()
        assert dirty == [0]
        retrained = cluster.retrain()
        assert retrained == [0]
        assert not cluster.should_retrain()
        assert id(cluster.shards[0]) != untouched[0]
        assert [id(s) for s in cluster.shards[1:]] == untouched[1:]
        vals, exists = cluster.lookup(k)
        assert exists.all()
        np.testing.assert_array_equal(vals["col0"], [1, 2])

    def test_equivalence_after_retrain(self):
        cfg = DeepMappingConfig(
            shared=(64,),
            private=(16,),
            train=TrainConfig(epochs=15, batch_size=512),
            retrain_after_modified_bytes=1,
        )
        table = make_periodic_table(n=800)
        single = DeepMappingStore.build(table, cfg)
        cluster = ShardedDeepMappingStore.build(
            table, cfg, ClusterConfig(num_shards=4, policy="hash")
        )
        base = int(table.keys.max())
        ins = np.arange(base + 2, base + 42, dtype=np.int64)
        cols = {
            "col0": (ins % 5).astype(np.int32),
            "col1": (ins % 3).astype(np.int32),
        }
        single.insert(ins, cols)
        cluster.insert(ins, cols)
        single = single.retrain()          # whole-relation rebuild
        assert cluster.retrain()           # only dirty shards rebuild
        assert_equivalent(single, cluster, np.concatenate([table.keys, ins, ins + 99]))


class TestClusterSerialization:
    def test_roundtrip(self, tmp_path):
        table = make_periodic_table(n=800)
        cluster = ShardedDeepMappingStore.build(
            table, FAST, ClusterConfig(num_shards=4, policy="range")
        )
        p = os.path.join(tmp_path, "cluster")
        save_sharded_store(cluster, p)
        clone = load_sharded_store(p)
        assert clone.num_shards == cluster.num_shards
        assert clone.cluster.policy == "range"
        q = np.concatenate([table.keys, table.keys[:64] + 1])
        assert_equivalent(cluster, clone, q)
        assert not os.path.exists(p + ".tmp")

    def test_overwrite_is_atomic(self, tmp_path):
        table = make_periodic_table(n=600)
        cluster = ShardedDeepMappingStore.build(
            table, FAST, ClusterConfig(num_shards=2, policy="hash")
        )
        p = os.path.join(tmp_path, "cluster")
        save_sharded_store(cluster, p)
        save_sharded_store(cluster, p)
        assert not os.path.exists(p + ".tmp")
        assert load_sharded_store(p).num_shards == 2


class TestSharedMemoryPool:
    def test_shards_share_one_pool_under_eviction(self):
        table = make_random_table(n=1200, cards=(17, 11))
        pool = MemoryPool(4096)  # tiny: forces partition eviction
        cfg = DeepMappingConfig(
            shared=(32,),
            private=(8,),
            partition_bytes=512,
            train=TrainConfig(epochs=3, batch_size=512),
        )
        cluster = ShardedDeepMappingStore.build(
            table, cfg, ClusterConfig(num_shards=4, policy="range"), pool=pool
        )
        assert all(s.aux.pool is pool for s in cluster.shards)
        for _ in range(3):
            vals, exists = cluster.lookup(table.keys)
            assert exists.all()
            np.testing.assert_array_equal(vals["col0"], table.columns["col0"])
        assert pool.evictions > 0            # pressure actually happened
        assert pool.used_bytes <= pool.budget_bytes


class TestServeIntegration:
    def test_lookup_server_over_sharded_store(self):
        table = make_periodic_table(n=1200)
        cluster = ShardedDeepMappingStore.build(
            table, FAST, ClusterConfig(num_shards=4, policy="range")
        )
        srv = LookupServer(cluster, max_batch=256)
        rng = np.random.default_rng(0)
        reqs = [rng.choice(table.keys, size=s) for s in (31, 200, 7)]
        results = srv.lookup_many(reqs)
        lut = dict(zip(table.keys.tolist(), table.columns["col0"].tolist()))
        for req, (vals, exists) in zip(reqs, results):
            assert exists.all()
            for k, v in zip(req.tolist(), vals["col0"].tolist()):
                assert lut[k] == v
        assert srv.stats.qps() > 0


class TestBuildValidation:
    def test_empty_hash_shard_raises(self):
        table = make_periodic_table(n=6)
        with pytest.raises(ValueError, match="empty"):
            ShardedDeepMappingStore.build(
                table, FAST, ClusterConfig(num_shards=64, policy="hash")
            )

    def test_range_planner_collapses_gracefully(self):
        table = make_periodic_table(n=6)
        cluster = ShardedDeepMappingStore.build(
            table, FAST, ClusterConfig(num_shards=4, policy="range")
        )
        assert 1 <= cluster.num_shards <= 4
        _, exists = cluster.lookup(table.keys)
        assert exists.all()

    def test_range_planner_more_shards_than_rows(self):
        # num_shards > rows: quantile cuts hit the minimum key, which
        # must not become a boundary (empty shard 0); count collapses.
        part = plan_range_partitions(np.array([5, 10], dtype=np.int64), 4)
        assert part.num_shards <= 2
        counts = np.bincount(part.shard_of(np.array([5, 10])),
                             minlength=part.num_shards)
        assert counts.min() > 0
        table = make_periodic_table(n=2)
        cluster = ShardedDeepMappingStore.build(
            table, FAST, ClusterConfig(num_shards=4, policy="range")
        )
        _, exists = cluster.lookup(table.keys)
        assert exists.all()
