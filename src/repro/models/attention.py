"""Attention variants: GQA (full/causal, sliding-window, decode), and
MLA (DeepSeek-V3 multi-head latent attention with compressed KV cache).

Memory discipline for long contexts (the 32k-prefill cells):
* full causal attention runs FLASH-style — ``lax.scan`` over KV chunks
  with running max/sum, so live memory is O(S · chunk) not O(S²);
* sliding-window attention runs BANDED — queries are chunked to the
  window size and attend only to (own chunk, previous chunk), which is
  exact for window ≤ chunk and skips far blocks entirely (a 32× FLOP
  cut for gemma3's 1024-window locals at 32k).
Decode attends one query against the cache with a length mask; under
pjit a sequence-sharded cache turns the softmax reductions into
all-reduces automatically (flash-decoding-style combine).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

NEG_INF = -2.0e38


def _constrain_batch_sharded(t: jnp.ndarray, cfg) -> jnp.ndarray:
    """Pin a tensor to (batch: data axes, rest: replicated) — explicit
    tensor-axis replication for attention intermediates (§Perf).  Tries
    multi-pod then single-pod batch axes; no-op without an ambient mesh."""
    if not getattr(cfg, "attn_replicated", False):
        return t
    from jax.sharding import PartitionSpec as P

    rest = (None,) * (t.ndim - 1)
    for batch_axes in (("pod", "data"), ("data",)):
        try:
            return jax.lax.with_sharding_constraint(t, P(batch_axes, *rest))
        except (RuntimeError, ValueError, KeyError):
            continue
    return t


# --------------------------------------------------------------------------
# parameter init
# --------------------------------------------------------------------------


def gqa_init(rng, cfg) -> Dict:
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    r = jax.random.split(rng, 4)
    dt = jnp.dtype(cfg.dtype)
    return {
        "wq": L.dense_init(r[0], d, H * hd, dt, bias=cfg.qkv_bias),
        "wk": L.dense_init(r[1], d, K * hd, dt, bias=cfg.qkv_bias),
        "wv": L.dense_init(r[2], d, K * hd, dt, bias=cfg.qkv_bias),
        "wo": L.dense_init(r[3], H * hd, d, dt),
    }


def mla_init(rng, cfg) -> Dict:
    d, H = cfg.d_model, cfg.num_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ql, kvl = cfg.q_lora_rank, cfg.kv_lora_rank
    r = jax.random.split(rng, 8)
    dt = jnp.dtype(cfg.dtype)
    return {
        "wq_a": L.dense_init(r[0], d, ql, dt),
        "q_norm": L.rmsnorm_init(ql, dt),
        "wq_b": L.dense_init(r[1], ql, H * (nope + rope), dt),
        "wkv_a": L.dense_init(r[2], d, kvl + rope, dt),
        "kv_norm": L.rmsnorm_init(kvl, dt),
        "wk_b": L.dense_init(r[3], kvl, H * nope, dt),
        "wv_b": L.dense_init(r[4], kvl, H * vd, dt),
        "wo": L.dense_init(r[5], H * vd, d, dt),
    }


# --------------------------------------------------------------------------
# core attention math
# --------------------------------------------------------------------------


def _flash_attend(q, k, v, q_positions, kv_positions, window: int, kv_chunk: int,
                  causal: bool = True, chunk_remat: bool = False):
    """Chunked causal softmax attention with running normalization.

    q (B,S,K,G,hd); k (B,T,K,hd); v (B,T,K,vd) — vd may differ from hd
    (MLA).  positions broadcastable (B,S)/(B,T).  window > 0 restricts to
    [pos-window+1, pos].  Returns (B,S,K,G,vd).
    """
    B, S, K, G, hd = q.shape
    vd = v.shape[-1]
    T = k.shape[1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    nchunks = (T + kv_chunk - 1) // kv_chunk
    Tp = nchunks * kv_chunk
    k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    kv_pos = jnp.pad(kv_positions, ((0, 0), (0, Tp - T)), constant_values=2**30)
    k = k.reshape(B, nchunks, kv_chunk, K, hd).transpose(1, 0, 2, 3, 4)
    v = v.reshape(B, nchunks, kv_chunk, K, vd).transpose(1, 0, 2, 3, 4)
    kv_pos = kv_pos.reshape(B, nchunks, kv_chunk).transpose(1, 0, 2)

    def step(carry, inp):
        m, l, acc = carry  # running max (B,S,K,G), denom, weighted sum
        kc, vc, pc = inp
        s = jnp.einsum("bskgh,bckh->bskgc", q.astype(jnp.float32), kc.astype(jnp.float32))
        s = s * scale
        if causal:
            valid = pc[:, None, :] <= q_positions[:, :, None]  # (B,S,C)
            if window > 0:
                valid &= pc[:, None, :] > (q_positions[:, :, None] - window)
        else:
            valid = jnp.broadcast_to(
                (pc < 2**29)[:, None, :], (pc.shape[0], q_positions.shape[1], pc.shape[1])
            )
        s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bskgc,bckh->bskgh", p, vc.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    if chunk_remat:
        # backward recomputes per-chunk softmax instead of saving
        # O(S x chunk x heads) fp32 residuals per layer (§Perf)
        step = jax.checkpoint(step)
    m0 = jnp.full((B, S, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, K, G), jnp.float32)
    a0 = jnp.zeros((B, S, K, G, vd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (k, v, kv_pos))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def _banded_attend(q, k, v, positions, window: int):
    """Exact sliding-window attention via (chunk, prev-chunk) banding.

    Requires S % window == 0 (caller pads).  q (B,S,K,G,hd), k/v (B,S,K,hd).
    """
    B, S, K, G, hd = q.shape
    w = window
    nc = S // w
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qc = q.reshape(B, nc, w, K, G, hd)
    kc = k.reshape(B, nc, w, K, hd)
    vc = v.reshape(B, nc, w, K, hd)
    pos_c = positions.reshape(B, nc, w)
    # previous chunk (zeros before chunk 0)
    kp = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    vp = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    pp = jnp.concatenate([jnp.full_like(pos_c[:, :1], 2**30), pos_c[:, :-1]], axis=1)
    kk = jnp.concatenate([kp, kc], axis=2)      # (B,nc,2w,K,hd)
    vv = jnp.concatenate([vp, vc], axis=2)
    pk = jnp.concatenate([pp, pos_c], axis=2)   # (B,nc,2w)
    s = jnp.einsum("bnwkgh,bnckh->bnwkgc", qc.astype(jnp.float32), kk.astype(jnp.float32))
    s = s * scale
    valid = (pk[:, :, None, :] <= pos_c[:, :, :, None]) & (
        pk[:, :, None, :] > pos_c[:, :, :, None] - w
    )
    s = jnp.where(valid[:, :, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnwkgc,bnckh->bnwkgh", p, vv.astype(jnp.float32))
    return out.reshape(B, S, K, G, hd).astype(q.dtype)


def _decode_attend(q, k_cache, v_cache, length):
    """q (B,1,K,G,hd) vs cache (B,T,K,hd); positions < length attend."""
    B, _, K, G, hd = q.shape
    T = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    s = jnp.einsum(
        "bskgh,btkh->bskgt", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    mask = jnp.arange(T)[None, :] < length[:, None]  # (B,T)
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bskgt,btkh->bskgh", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# GQA block
# --------------------------------------------------------------------------


def gqa_apply(
    p: Dict,
    cfg,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    window: jnp.ndarray | int = 0,
    cache: Optional[Dict] = None,
    kv_chunk: int = 1024,
    causal: bool = True,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x (B,S,d).  cache = {'k': (B,T,K,hd), 'v': ..., 'len': scalar} for
    decode (S==1).  ``causal=False`` gives bidirectional attention
    (encoder use).  Returns (out (B,S,d), updated cache)."""
    B, S, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // K
    q = _constrain_batch_sharded(L.dense(p["wq"], x), cfg).reshape(B, S, H, hd)
    k = _constrain_batch_sharded(L.dense(p["wk"], x), cfg).reshape(B, S, K, hd)
    v = _constrain_batch_sharded(L.dense(p["wv"], x), cfg).reshape(B, S, K, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    qg = q.reshape(B, S, K, G, hd)

    if cache is not None:
        idx = cache["len"]  # scalar int32: same step across batch
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, idx, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, idx, 0, 0))
        length = jnp.full((B,), idx + S, jnp.int32)
        if isinstance(window, int) and window > 0:
            # windowed decode: only last `window` positions attend
            lo = jnp.maximum(length - window, 0)
            T = k_cache.shape[1]
            mask_lo = jnp.arange(T)[None, :] >= lo[:, None]
            out = _decode_attend_window(qg, k_cache, v_cache, length, mask_lo)
        else:
            out = _decode_attend(qg, k_cache, v_cache, length)
        new_cache = {"k": k_cache, "v": v_cache, "len": idx + S}
    else:
        if causal and isinstance(window, int) and window > 0 and S % window == 0 and S > window:
            out = _banded_attend(qg, k, v, positions, window)
        else:
            w = window if isinstance(window, int) else 0
            out = _flash_attend(qg, k, v, positions, positions, w, kv_chunk,
                                causal=causal, chunk_remat=cfg.flash_remat)
        new_cache = None

    out = _constrain_batch_sharded(out.reshape(B, S, H * hd), cfg)
    return L.dense(p["wo"], out), new_cache


def _decode_attend_window(q, k_cache, v_cache, length, mask_lo):
    B, _, K, G, hd = q.shape
    T = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    s = jnp.einsum(
        "bskgh,btkh->bskgt", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    mask = (jnp.arange(T)[None, :] < length[:, None]) & mask_lo
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bskgt,btkh->bskgh", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def gqa_init_cache(cfg, batch: int, max_len: int, dtype=None) -> Dict:
    dt = jnp.dtype(dtype or cfg.dtype)
    K, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, K, hd), dt),
        "v": jnp.zeros((batch, max_len, K, hd), dt),
        "len": jnp.zeros((), jnp.int32),
    }


# --------------------------------------------------------------------------
# MLA block (DeepSeek-V3)
# --------------------------------------------------------------------------


def mla_apply(
    p: Dict,
    cfg,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cache: Optional[Dict] = None,
    kv_chunk: int = 1024,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Multi-head latent attention.  The cache stores ONLY the compressed
    latent (kv_lora_rank) + shared rope key (qk_rope_dim) per token —
    the architecture's memory win.  Decode uses the absorbed-matmul
    formulation (q projected into latent space), never re-expanding K."""
    B, S, d = x.shape
    H = cfg.num_heads
    nope, rope, vd, kvl = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank

    q = L.dense(p["wq_b"], L.rmsnorm(p["q_norm"], L.dense(p["wq_a"], x), cfg.norm_eps))
    q = q.reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = L.dense(p["wkv_a"], x)  # (B,S,kvl+rope)
    c_kv = L.rmsnorm(p["kv_norm"], kv_a[..., :kvl], cfg.norm_eps)
    k_rope = L.apply_rope(kv_a[..., None, kvl:], positions, cfg.rope_theta)  # (B,S,1,rope)

    wk_b = p["wk_b"]["w"].reshape(kvl, H, nope)
    wv_b = p["wv_b"]["w"].reshape(kvl, H, vd)

    if cache is None:
        # Expanded path for train/prefill: standard attention math.
        k_nope = jnp.einsum("bsc,chn->bshn", c_kv, wk_b)
        v = jnp.einsum("bsc,chv->bshv", c_kv, wv_b)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, rope))], axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        qg = qf.reshape(B, S, H, 1, nope + rope)
        # _flash_attend is dim-agnostic on v (vd != nope+rope is fine).
        out = _flash_attend(
            qg, k, v, positions, positions, 0, kv_chunk,
            chunk_remat=cfg.flash_remat,
        ).reshape(B, S, H * vd)
        new_cache = None
    else:
        # Absorbed decode: score = [q_nope @ wk_b] · c_kv + q_rope · k_rope.
        idx = cache["len"]
        c_cache = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, idx, 0))
        r_cache = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope[:, :, 0, :], (0, idx, 0)
        )
        q_lat = jnp.einsum("bshn,chn->bshc", q_nope.astype(jnp.float32), wk_b.astype(jnp.float32))
        scale = 1.0 / jnp.sqrt(nope + rope).astype(jnp.float32)
        s = (
            jnp.einsum("bshc,btc->bsht", q_lat, c_cache.astype(jnp.float32))
            + jnp.einsum(
                "bshr,btr->bsht", q_rope.astype(jnp.float32), r_cache.astype(jnp.float32)
            )
        ) * scale
        T = c_cache.shape[1]
        mask = jnp.arange(T)[None, :] < (idx + S)
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bsht,btc->bshc", pr, c_cache.astype(jnp.float32))
        out = jnp.einsum("bshc,chv->bshv", o_lat, wv_b.astype(jnp.float32))
        out = out.reshape(B, S, H * vd).astype(x.dtype)
        new_cache = {"c_kv": c_cache, "k_rope": r_cache, "len": idx + S}

    return L.dense(p["wo"], out), new_cache


def mla_init_cache(cfg, batch: int, max_len: int, dtype=None) -> Dict:
    dt = jnp.dtype(dtype or cfg.dtype)
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dt),
        "len": jnp.zeros((), jnp.int32),
    }
