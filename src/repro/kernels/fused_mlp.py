"""Fused multi-task MLP inference kernel (pl.pallas_call + BlockSpec).

TPU adaptation of the paper's ONNX-on-GPU batch inference: all model
weights stay resident in VMEM across the batch (mapping models are
small — KBs to a few MB); the grid walks batch tiles, so activations
make exactly ONE HBM round trip instead of one per layer.  The one-hot
encoding of key digits is materialized per-tile in VMEM as an
(TILE_N, base) compare-with-iota and immediately consumed by the MXU —
it never exists in HBM (DESIGN.md §3).

Two entrypoints share the forward body:

* ``fused_mlp_call``    — digits in, logits/codes out (the original
  kernel; still the reference-shaped staged path).
* ``fused_lookup_call`` — RAW int32 keys in, per-task argmax codes AND
  existence bits out.  Digit/residue decomposition happens in-kernel
  from per-position ``(modulus, divisor)`` scalars held in SMEM, so the
  HBM input shrinks from ``(N, width)`` int32 to ``(N,)`` keys, and the
  packed existence-bitvector word array rides in the same
  ``pallas_call`` (Algorithm 1 lines 3+5 in one device round trip).

Layout contract (enforced by ops.py):
* every dense dimension padded to multiples of 128 (MXU lane width);
* batch tiles of ``tile_n`` rows (multiple of 8, default 256);
* rank-3 first-layer weights are (width, base_pad, h_pad);
* with ``emit_codes=True`` each head reduces to int32 argmax codes
  in-kernel (padded logit columns masked to -inf), shrinking the HBM
  write from O(Σ cards) floats to one int32 per task per row.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces; interpret mode accepts them on any backend
    from jax.experimental.pallas import tpu as pltpu

    _SMEM = pltpu.SMEM
except Exception:  # pragma: no cover — pallas build without TPU support
    _SMEM = None

from repro.core.model import MLPSpec


def _plan(spec: MLPSpec) -> Tuple[List[str], Dict[str, List[str]]]:
    """Layer kinds for trunk and heads: 'embed' (rank-3 from input) or
    'dense'."""
    trunk = ["embed" if i == 0 else "dense" for i in range(len(spec.shared))]
    heads = {}
    priv = spec.private_map
    for t in spec.tasks:
        kinds = []
        first = len(trunk) == 0
        for _ in priv[t]:
            kinds.append("embed" if first else "dense")
            first = False
        kinds.append("embed_out" if first else "dense_out")
        heads[t] = kinds
    return trunk, heads


def _apply_embed(w_ref, b_ref, digits, base_pad):
    """One-hot-in-VMEM gather-matmul: sum_p onehot(d_p) @ W[p]."""
    width = w_ref.shape[0]
    acc = None
    iota = jax.lax.broadcasted_iota(jnp.int32, (digits.shape[0], base_pad), 1)
    for p in range(width):
        onehot = (digits[:, p][:, None] == iota).astype(w_ref.dtype)
        part = jnp.dot(onehot, w_ref[p], preferred_element_type=jnp.float32)
        acc = part if acc is None else acc + part
    return acc + b_ref[...]


def _forward_tile(
    digits,
    w_refs,
    spec: MLPSpec,
    trunk_kinds,
    head_kinds,
    base_pad: int,
    emit_codes: bool,
) -> List[jnp.ndarray]:
    """Whole-model forward on one batch tile: per-task codes (n, 1)
    int32 when ``emit_codes`` else logits (n, card_pad).  Shared by the
    digits-in and keys-in kernels so both paths compute bit-identical
    results."""
    cards = spec.card_map
    it = iter(w_refs)
    outs: List[jnp.ndarray] = []

    x = None
    for kind in trunk_kinds:
        w_ref, b_ref = next(it), next(it)
        if kind == "embed":
            x = _apply_embed(w_ref, b_ref, digits, base_pad)
        else:
            x = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32) + b_ref[...]
        x = jnp.maximum(x, 0.0)

    for t in spec.tasks:
        h = x
        for kind in head_kinds[t]:
            w_ref, b_ref = next(it), next(it)
            if kind == "embed":
                h = jnp.maximum(_apply_embed(w_ref, b_ref, digits, base_pad), 0.0)
            elif kind == "dense":
                h = jnp.maximum(
                    jnp.dot(h, w_ref[...], preferred_element_type=jnp.float32)
                    + b_ref[...],
                    0.0,
                )
            elif kind == "embed_out":
                h = _apply_embed(w_ref, b_ref, digits, base_pad)
            else:  # dense_out
                h = (
                    jnp.dot(h, w_ref[...], preferred_element_type=jnp.float32)
                    + b_ref[...]
                )
        if emit_codes:
            # mask padded logit columns, reduce to codes in-kernel
            card = cards[t]
            col = jax.lax.broadcasted_iota(jnp.int32, h.shape, 1)
            masked = jnp.where(col < card, h, -jnp.inf)
            outs.append(jnp.argmax(masked, axis=-1).astype(jnp.int32)[:, None])
        else:
            outs.append(h)
    return outs


def make_fused_kernel(
    spec: MLPSpec,
    base_pad: int,
    card_pads: Dict[str, int],
    emit_codes: bool,
):
    """Build the kernel body for this model structure (static closure)."""
    trunk_kinds, head_kinds = _plan(spec)

    def kernel(digits_ref, *refs):
        n_heads = len(spec.tasks)
        out_refs = refs[len(refs) - n_heads :]
        w_refs = list(refs[: len(refs) - n_heads])
        outs = _forward_tile(
            digits_ref[...], w_refs, spec, trunk_kinds, head_kinds, base_pad,
            emit_codes,
        )
        for ti in range(n_heads):
            out_refs[ti][...] = outs[ti]

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("spec", "tile_n", "base_pad", "card_pads", "emit_codes", "interpret"),
)
def fused_mlp_call(
    digits: jnp.ndarray,
    flat_weights: Tuple[jnp.ndarray, ...],
    spec: MLPSpec,
    tile_n: int,
    base_pad: int,
    card_pads: Tuple[Tuple[str, int], ...],
    emit_codes: bool,
    interpret: bool,
):
    """digits (N_pad, width) int32; flat_weights in plan order (padded).

    Returns tuple per task: (N_pad, 1) int32 codes if emit_codes else
    (N_pad, card_pad) float32 logits.
    """
    card_pads_d = dict(card_pads)
    n = digits.shape[0]
    if n % tile_n != 0:
        raise ValueError(f"batch size {n} must be a multiple of tile_n={tile_n}")
    grid = (n // tile_n,)
    kernel = make_fused_kernel(spec, base_pad, card_pads_d, emit_codes)

    in_specs = [pl.BlockSpec((tile_n, digits.shape[1]), lambda i: (i, 0))]
    for w in flat_weights:
        # weights are grid-invariant: whole tensor resident per step
        in_specs.append(pl.BlockSpec(w.shape, lambda i, nd=w.ndim: (0,) * nd))

    out_shapes, out_specs = [], []
    for t in spec.tasks:
        if emit_codes:
            out_shapes.append(jax.ShapeDtypeStruct((n, 1), jnp.int32))
            out_specs.append(pl.BlockSpec((tile_n, 1), lambda i: (i, 0)))
        else:
            cp = card_pads_d[t]
            out_shapes.append(jax.ShapeDtypeStruct((n, cp), jnp.float32))
            out_specs.append(pl.BlockSpec((tile_n, cp), lambda i: (i, 0)))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(digits, *flat_weights)


# --------------------------------------------------------------------------
# Fused key-encode + inference + existence kernel (one round trip lookup)
# --------------------------------------------------------------------------
def make_fused_lookup_kernel(
    spec: MLPSpec,
    base_pad: int,
    capacity: int,
    n_words32: int,
    pred_tasks: Tuple[int, ...] = (),
    with_exists: bool = True,
):
    """Kernel body answering Algorithm 1 lines 3+5 from raw int32 keys.

    Per tile: decompose keys into digit/residue positions from the SMEM
    ``(modulus, divisor)`` table, run the whole multi-task model, argmax
    to codes, and test the VMEM-resident packed existence words — codes
    and exist bits leave in the same HBM write set.  Keys outside
    ``[0, capacity)`` get code 0 (the host zero-fill contract of
    ``_infer_codes``) and keys outside the word domain exist=0, exactly
    matching ``BitVector.test``.

    With ``with_exists=False`` (streamed pages past the first) the
    words input and existence output are absent.  ``pred_tasks`` adds
    one boolean code-table input per pushdown predicate plus a single
    match-bit output: ``match = exists AND table_j[code(pred_tasks[j])]
    for all j`` — the same conjunction the host filter evaluates, so
    pushdown plans leave the kernel with their filtering already done.
    """
    trunk_kinds, head_kinds = _plan(spec)
    width = spec.width
    base = spec.base
    n_heads = len(spec.tasks)
    n_preds = len(pred_tasks)
    if n_preds and not with_exists:
        raise ValueError("in-kernel predicate filtering requires with_exists")
    n_outs = n_heads + (1 if with_exists else 0) + (1 if n_preds else 0)

    def kernel(keys_ref, ops_ref, *refs):
        # refs = [words]?, pred tables..., weights..., then outputs:
        # codes (one per task), [exists]?, [match]?
        idx = 1 if with_exists else 0
        words_ref = refs[0] if with_exists else None
        table_refs = refs[idx : idx + n_preds]
        w_refs = list(refs[idx + n_preds : len(refs) - n_outs])
        out_refs = refs[len(refs) - n_outs :]

        keys = keys_ref[...]
        in_cap = (keys >= 0) & (keys < capacity)
        safe = jnp.where(in_cap, keys, 0)

        # In-kernel digit/residue decomposition.  Every position is the
        # same three integer ops on scalars prefetched to SMEM; main
        # digit positions carry modulus == capacity (a no-op for keys
        # already clamped into [0, capacity)).
        cols = []
        for p in range(width):
            mod = ops_ref[p, 0]
            div = ops_ref[p, 1]
            cols.append((((safe % mod) // div) % base).astype(jnp.int32)[:, None])
        digits = jnp.concatenate(cols, axis=1)

        outs = _forward_tile(
            digits, w_refs, spec, trunk_kinds, head_kinds, base_pad,
            emit_codes=True,
        )
        codes = []
        for ti in range(n_heads):
            c = jnp.where(in_cap[:, None], outs[ti], 0)
            codes.append(c)
            out_refs[ti][...] = c

        if with_exists:
            # Existence test against the packed words (Algorithm 1 line
            # 5).  Bits past BitVector.capacity are never set, so the
            # word-domain mask alone reproduces BitVector.test
            # byte-for-byte.
            in_dom = (keys >= 0) & (
                jax.lax.shift_right_logical(keys, 5) < n_words32
            )
            sk = jnp.where(in_dom, keys, 0)
            w = jnp.take(
                words_ref[...], jax.lax.shift_right_logical(sk, 5), axis=0
            )
            bits = jnp.bitwise_and(
                jax.lax.shift_right_logical(
                    w, jnp.bitwise_and(sk, 31).astype(jnp.uint32)
                ),
                jnp.uint32(1),
            )
            exists = bits.astype(jnp.int32) * in_dom.astype(jnp.int32)
            out_refs[n_heads][...] = exists

            if n_preds:
                # The host contract (hybrid._collect_lookup): match
                # starts as the existence bit and ANDs each predicate's
                # table at the (in_cap-masked) model code — rows the aux
                # table later overrides are re-patched host-side.
                m = exists
                for j in range(n_preds):
                    code = codes[pred_tasks[j]][:, 0]
                    m = m * jnp.take(table_refs[j][...], code, axis=0)
                out_refs[n_heads + 1][...] = m

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=(
        "spec", "tile_n", "base_pad", "capacity", "interpret",
        "pred_tasks", "with_exists",
    ),
)
def fused_lookup_call(
    keys: jnp.ndarray,
    pos_ops: jnp.ndarray,
    words32,
    flat_weights: Tuple[jnp.ndarray, ...],
    spec: MLPSpec,
    tile_n: int,
    base_pad: int,
    capacity: int,
    interpret: bool,
    pred_tables: Tuple[jnp.ndarray, ...] = (),
    pred_tasks: Tuple[int, ...] = (),
    with_exists: bool = True,
):
    """keys (N_pad,) int32; pos_ops (width, 2) int32 [(mod, div)…];
    words32 (n_words32,) uint32 (None when ``with_exists=False``);
    flat_weights in plan order (padded); pred_tables one padded int32
    0/1 vector per pushdown predicate, indexed by the code of head
    ``pred_tasks[j]``.

    Returns ``(codes, exists, match)``: codes (N_pad, num_tasks) int32;
    exists (N_pad,) int32 0/1 or None without ``with_exists``; match
    (N_pad,) int32 0/1 or None without ``pred_tables`` — one device
    round trip for the whole batch.
    """
    n = keys.shape[0]
    if n % tile_n != 0:
        raise ValueError(f"batch size {n} must be a multiple of tile_n={tile_n}")
    grid = (n // tile_n,)
    n_heads = len(spec.tasks)
    kernel = make_fused_lookup_kernel(
        spec, base_pad, capacity,
        words32.shape[0] if with_exists else 0,
        pred_tasks=pred_tasks, with_exists=with_exists,
    )

    smem_kwargs = {"memory_space": _SMEM} if _SMEM is not None else {}
    inputs = [keys, pos_ops]
    in_specs = [
        pl.BlockSpec((tile_n,), lambda i: (i,)),
        pl.BlockSpec(pos_ops.shape, lambda i: (0, 0), **smem_kwargs),
    ]
    if with_exists:
        inputs.append(words32)
        in_specs.append(pl.BlockSpec(words32.shape, lambda i: (0,)))
    for tb in pred_tables:
        inputs.append(tb)
        in_specs.append(pl.BlockSpec(tb.shape, lambda i: (0,)))
    for w in flat_weights:
        inputs.append(w)
        in_specs.append(pl.BlockSpec(w.shape, lambda i, nd=w.ndim: (0,) * nd))

    out_shapes = [jax.ShapeDtypeStruct((n, 1), jnp.int32) for _ in spec.tasks]
    out_specs = [pl.BlockSpec((tile_n, 1), lambda i: (i, 0)) for _ in spec.tasks]
    if with_exists:
        out_shapes.append(jax.ShapeDtypeStruct((n,), jnp.int32))
        out_specs.append(pl.BlockSpec((tile_n,), lambda i: (i,)))
    if pred_tables:
        out_shapes.append(jax.ShapeDtypeStruct((n,), jnp.int32))
        out_specs.append(pl.BlockSpec((tile_n,), lambda i: (i,)))

    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(*inputs)
    codes = jnp.concatenate(outs[:n_heads], axis=1)
    exists = outs[n_heads] if with_exists else None
    match = outs[-1] if pred_tables else None
    return codes, exists, match
