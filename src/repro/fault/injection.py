"""Deterministic fault-injection harness for the read/serving path.

The training substrate has had injection for a while
(``train/fault_tolerance.py``'s ``fail_at``); this module brings the
same discipline to the query path.  A :class:`FaultPlan` is a list of
:class:`FaultSpec` rules — *at site X (optionally for owner Y), fire
kind K* — activated as a context manager around the code under test::

    plan = FaultPlan([FaultSpec(site="shard_collect", owner="shard:0",
                                kind="raise", times=1)])
    with plan.activate():
        store.query().where_keys(ks).on_error("partial").execute()
    assert plan.fired  # events were recorded

Everything is deterministic: specs fire by matching-event index
(``after``/``times`` windows) and, when ``probability < 1``, by a
counter-seeded RNG — ``(seed, spec_index, event_index)`` — so a run
replays identically regardless of wall clock, thread timing, or host.

Instrumented sites consult the active plan through the module-level
helpers; with no plan active they cost one attribute read:

* :func:`maybe_fail` — raise :class:`~repro.fault.errors.InjectedFault`
  (kind ``"raise"``) or sleep (kind ``"delay"``) at a site;
* :func:`corrupt` — deterministically flip one byte of an artifact
  payload (kind ``"corrupt"``, ``artifact_read`` site).

Sites instrumented in this repo: ``shard_collect`` (per-shard visit in
the sharded store), ``member_collect`` (per-member visit in the
federation), ``engine_dispatch`` (device inference dispatch), and
``artifact_read`` (persistence layer reads).  Every fired event counts
into ``deepmap_fault_injected_total{site,kind}``.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.fault.errors import InjectedFault

#: The instrumented injection sites (specs may only name these).
SITES = ("shard_collect", "member_collect", "engine_dispatch", "artifact_read")

#: Supported fault kinds.
KINDS = ("raise", "delay", "corrupt")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injection rule: *at ``site`` (for ``owner``), fire ``kind``*.

    ``owner=None`` matches every owner at the site.  The rule fires on
    matching events with index ``>= after``, at most ``times`` times
    (``None`` = unbounded), each firing gated by a seeded coin when
    ``probability < 1``.  ``delay_s`` is the sleep for ``kind="delay"``.
    """

    site: str
    kind: str = "raise"
    owner: Optional[str] = None
    times: Optional[int] = None
    after: int = 0
    probability: float = 1.0
    delay_s: float = 0.0

    def __post_init__(self) -> None:
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; have {SITES}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; have {KINDS}")
        if not (0.0 <= self.probability <= 1.0):
            raise ValueError("probability must be in [0, 1]")
        if self.times is not None and self.times < 0:
            raise ValueError("times must be >= 0")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """Record of one fired fault (for assertions and bench reports)."""

    site: str
    kind: str
    owner: Optional[str]
    spec_index: int
    event_index: int


class FaultPlan:
    """A set of :class:`FaultSpec` rules plus their firing state.

    Thread-safe: instrumented sites are hit from fan-out pool threads.
    Activation is process-global (one plan at a time, nesting
    disallowed) — the harness targets tests and benchmarks, not
    concurrent production traffic.
    """

    def __init__(self, specs: Sequence[FaultSpec], seed: int = 0):
        self.specs = tuple(specs)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._seen: List[int] = [0] * len(self.specs)   # guarded-by: _lock
        self._fired: List[int] = [0] * len(self.specs)  # guarded-by: _lock
        self._events: List[FaultEvent] = []             # guarded-by: _lock

    # ----------------------------------------------------------- inspection
    @property
    def events(self) -> Tuple[FaultEvent, ...]:
        """Every fired event, in firing order."""
        with self._lock:
            return tuple(self._events)

    @property
    def fired(self) -> int:
        """Total events fired across all specs."""
        with self._lock:
            return sum(self._fired)

    def fired_at(self, site: str) -> int:
        """Events fired at one site."""
        with self._lock:
            return sum(1 for e in self._events if e.site == site)

    # ------------------------------------------------------------- matching
    def _coin(self, spec_index: int, event_index: int) -> bool:
        spec = self.specs[spec_index]
        if spec.probability >= 1.0:
            return True
        # Counter-seeded: deterministic in (seed, spec, event), immune
        # to thread interleaving and draw order.
        rng = np.random.default_rng((self.seed, spec_index, event_index))
        return bool(rng.random() < spec.probability)

    def _arm(self, site: str, owner: Optional[str], kinds: Tuple[str, ...]
             ) -> Optional[Tuple[FaultSpec, FaultEvent]]:
        """Find the first matching spec that fires for this event (and
        record it); None when nothing fires."""
        owner = None if owner is None else str(owner)
        with self._lock:
            for i, spec in enumerate(self.specs):
                if spec.site != site or spec.kind not in kinds:
                    continue
                if spec.owner is not None and owner is not None \
                        and spec.owner != owner:
                    continue
                if spec.owner is not None and owner is None:
                    continue
                idx = self._seen[i]
                self._seen[i] = idx + 1
                if idx < spec.after:
                    continue
                if spec.times is not None and self._fired[i] >= spec.times:
                    continue
                if not self._coin(i, idx):
                    continue
                self._fired[i] += 1
                event = FaultEvent(
                    site=site, kind=spec.kind, owner=owner,
                    spec_index=i, event_index=idx,
                )
                self._events.append(event)
                return spec, event
        return None

    # ------------------------------------------------------------ lifecycle
    @contextlib.contextmanager
    def activate(self):
        """Install this plan as the process-wide active plan."""
        global _ACTIVE
        with _ACTIVATION_LOCK:
            if _ACTIVE is not None:
                raise RuntimeError("a FaultPlan is already active (no nesting)")
            _ACTIVE = self
        try:
            yield self
        finally:
            with _ACTIVATION_LOCK:
                _ACTIVE = None


_ACTIVE: Optional[FaultPlan] = None
_ACTIVATION_LOCK = threading.Lock()


def active() -> Optional[FaultPlan]:
    """The currently-activated plan (None almost always)."""
    return _ACTIVE


def _record(event: FaultEvent) -> None:
    obs.registry().counter(
        "deepmap_fault_injected_total",
        "Faults fired by the injection harness, by site and kind.",
    ).inc(site=event.site, kind=event.kind)


def maybe_fail(site: str, owner=None) -> None:
    """Instrumentation hook: raise or delay if the active plan says so.

    No-op (one global read) when no plan is active — safe to leave in
    hot paths.  ``kind="raise"`` raises :class:`InjectedFault`;
    ``kind="delay"`` sleeps ``delay_s`` then returns (the slow-owner
    case for deadline tests).
    """
    plan = _ACTIVE
    if plan is None:
        return
    hit = plan._arm(site, None if owner is None else str(owner),
                    ("raise", "delay"))
    if hit is None:
        return
    spec, event = hit
    _record(event)
    if spec.kind == "delay":
        time.sleep(spec.delay_s)
        return
    raise InjectedFault(site, None if owner is None else str(owner))


def corrupt(site: str, owner, data: bytes) -> bytes:
    """Instrumentation hook for artifact reads: deterministically flip
    one byte of ``data`` if a ``kind="corrupt"`` spec fires (checksum
    verification must then reject the artifact).  Empty payloads pass
    through untouched."""
    plan = _ACTIVE
    if plan is None or not data:
        return data
    hit = plan._arm(site, None if owner is None else str(owner), ("corrupt",))
    if hit is None:
        return data
    _record(hit[1])
    flipped = bytearray(data)
    flipped[len(flipped) // 2] ^= 0x01
    return bytes(flipped)
