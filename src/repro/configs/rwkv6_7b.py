"""rwkv6-7b — Finch: attention-free, data-dependent decay
[arXiv:2404.05892].  32L d_model=4096 d_ff=14336 vocab=65536; RWKV6
head size 64 -> 64 heads.  Constant-size WKV state makes this a
``long_500k`` arch."""

from repro.configs.base import ArchSpec, register
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,            # head dim 64
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=("rwkv",),
)

SMOKE = ModelConfig(
    name="rwkv6-smoke",
    family="ssm",
    num_layers=2,
    d_model=32,
    num_heads=2,
    num_kv_heads=2,
    d_ff=64,
    vocab_size=128,
    block_pattern=("rwkv",),
    dtype="float32",
    remat="none",
)

SPEC = register(
    ArchSpec(
        arch_id="rwkv6-7b",
        config=CONFIG,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
        notes="SSM family: O(1) decode state; long_500k applies.",
    )
)
