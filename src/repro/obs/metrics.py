"""Thread-safe metrics registry: labeled counters, gauges, and
log-bucketed histograms (the Prometheus data model, stdlib-only).

Every layer of the repo (executor, inference engine, plan cache,
serving engine, cluster) mirrors its counters into ONE registry so
latency/throughput claims stop living in four disconnected ad-hoc
stats classes.  The registry is **always on**: increments are a lock +
dict update on morsel/chunk granularity (never per key), so the
measured overhead stays under the <3% budget recorded in
``BENCH_lookup.json`` (``obs_overhead``).  ``registry().enabled =
False`` (or :func:`repro.obs.set_enabled`) turns every mutation into
an early return — the benchmark's off-switch for measuring that
budget.

Metric families are get-or-create by name (:meth:`MetricsRegistry.counter`
etc. return the existing family on repeat calls), and label values are
passed as kwargs at increment time::

    reg = metrics.registry()
    reg.counter("deepmap_executor_morsels_total").inc(kind="scan")
    reg.histogram("deepmap_executor_plan_seconds").observe(0.012, kind="scan")

There is a process-global default registry (:func:`registry`) plus
injectable instances (:func:`set_registry` swaps the default; tests
install a fresh one for isolation).  Naming scheme and the full metric
inventory are documented in DESIGN.md §Observability.
"""

from __future__ import annotations

import bisect
import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

#: Default histogram bucket upper bounds: powers of two from ~1 µs to
#: 64 s.  Log-spaced so one bucket layout covers µs-scale operator
#: stages and second-scale plans; quantiles interpolate geometrically
#: within a bucket.
LATENCY_BUCKETS: Tuple[float, ...] = tuple(2.0**e for e in range(-20, 7))

#: Bucket layout for size-like observations (rows per morsel, keys per
#: merged batch): powers of two from 1 to 2^24.
SIZE_BUCKETS: Tuple[float, ...] = tuple(2.0**e for e in range(0, 25))

_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> _LabelKey:
    """Canonical (sorted) hashable form of a label kwarg set."""
    if not labels:
        return ()
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Metric:
    """Base metric family: one name, one kind, many label children."""

    kind = "untyped"

    def __init__(self, name: str, help: str, registry: "MetricsRegistry"):
        self.name = name
        self.help = help
        self._registry = registry
        self._lock = threading.Lock()
        self._values: Dict[_LabelKey, float] = {}  # guarded-by: _lock

    # ------------------------------------------------------------- reading
    def value(self, **labels) -> float:
        """Current value for one label set (0.0 if never touched)."""
        with self._lock:
            return self._values.get(_label_key(labels), 0.0)

    def items(self) -> List[Tuple[_LabelKey, float]]:
        """Stable snapshot of ``(label_key, value)`` pairs."""
        with self._lock:
            return sorted(self._values.items())


class Counter(_Metric):
    """Monotonically increasing counter (negative increments raise)."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        """Add ``amount`` (default 1) to the labeled child."""
        if not self._registry.enabled:
            return
        if amount < 0:
            raise ValueError(f"counter {self.name} increment must be >= 0")
        key = _label_key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount


class Gauge(_Metric):
    """Point-in-time value (queue depth, in-flight morsels)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        """Set the labeled child to ``value``."""
        if not self._registry.enabled:
            return
        with self._lock:
            self._values[_label_key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        """Add ``amount`` (may be negative) to the labeled child."""
        if not self._registry.enabled:
            return
        key = _label_key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels) -> None:
        """Subtract ``amount`` from the labeled child."""
        self.inc(-amount, **labels)


class _HistState:
    """One label child's histogram state: bucket counts + sum + count."""

    __slots__ = ("counts", "sum", "count")

    def __init__(self, nbuckets: int):
        self.counts = [0] * (nbuckets + 1)  # +1 = +Inf overflow bucket
        self.sum = 0.0
        self.count = 0


class Histogram(_Metric):
    """Log-bucketed histogram with p50/p99 estimation.

    Buckets are fixed at construction (default
    :data:`LATENCY_BUCKETS`); an observation lands in the first bucket
    whose upper bound is >= the value, values beyond the last bound go
    to +Inf.  :meth:`quantile` interpolates geometrically inside the
    winning bucket — exact enough for the p50/p99 evidence the
    benchmarks record, at O(buckets) memory forever.
    """

    kind = "histogram"

    def __init__(
        self,
        name: str,
        help: str,
        registry: "MetricsRegistry",
        buckets: Optional[Sequence[float]] = None,
    ):
        super().__init__(name, help, registry)
        bounds = tuple(buckets) if buckets is not None else LATENCY_BUCKETS
        if list(bounds) != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError(f"histogram {name} buckets must be ascending")
        self.buckets = bounds
        self._states: Dict[_LabelKey, _HistState] = {}  # guarded-by: _lock

    def observe(self, value: float, **labels) -> None:
        """Record one observation into the labeled child."""
        if not self._registry.enabled:
            return
        key = _label_key(labels)
        idx = bisect.bisect_left(self.buckets, value)
        with self._lock:
            state = self._states.get(key)
            if state is None:
                state = self._states[key] = _HistState(len(self.buckets))
            state.counts[idx] += 1
            state.sum += value
            state.count += 1

    # ------------------------------------------------------------- reading
    def state(self, **labels) -> Optional[_HistState]:
        """The labeled child's state, or None if never observed."""
        with self._lock:
            return self._states.get(_label_key(labels))

    def value(self, **labels) -> float:
        """Observation count for the labeled child (counter parity)."""
        s = self.state(**labels)
        return float(s.count) if s is not None else 0.0

    def items(self) -> List[Tuple[_LabelKey, _HistState]]:
        """Stable snapshot of ``(label_key, state)`` pairs."""
        with self._lock:
            return sorted(self._states.items(), key=lambda kv: kv[0])

    def quantile(self, q: float, **labels) -> float:
        """Estimated ``q``-quantile (0..1) via geometric interpolation
        within the winning log bucket; 0.0 with no observations."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        state = self.state(**labels)
        if state is None or state.count == 0:
            return 0.0
        rank = q * state.count
        seen = 0.0
        for i, c in enumerate(state.counts):
            seen += c
            if seen >= rank and c:
                if i >= len(self.buckets):  # +Inf bucket: no upper bound
                    return self.buckets[-1]
                hi = self.buckets[i]
                lo = self.buckets[i - 1] if i else hi / 2.0
                frac = (rank - (seen - c)) / c
                return lo * math.exp(frac * math.log(hi / lo))
        return self.buckets[-1]


class MetricsRegistry:
    """Named metric families behind one lock, one ``enabled`` switch.

    Families are get-or-create: asking for an existing name returns
    the existing family (a kind mismatch raises — two layers must not
    silently write one name with different types).  ``snapshot()``
    produces the JSON-able view the benchmarks embed into
    ``BENCH_*.json``; the Prometheus/Chrome exporters live in
    :mod:`repro.obs.export`.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}  # guarded-by: _lock

    def _get_or_create(self, cls, name: str, help: str, **kwargs):
        with self._lock:
            metric = self._metrics.get(name)
            if metric is None:
                metric = self._metrics[name] = cls(name, help, self, **kwargs)
            elif not isinstance(metric, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {metric.kind}, "
                    f"requested {cls.kind}"
                )
            return metric

    def counter(self, name: str, help: str = "") -> Counter:
        """Get-or-create a :class:`Counter` family."""
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        """Get-or-create a :class:`Gauge` family."""
        return self._get_or_create(Gauge, name, help)

    def histogram(
        self, name: str, help: str = "", buckets: Optional[Sequence[float]] = None
    ) -> Histogram:
        """Get-or-create a :class:`Histogram` family (``buckets`` only
        applies at creation; later calls reuse the existing layout)."""
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def collect(self) -> List[_Metric]:
        """All families, name-sorted (the exporters' iteration order)."""
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def get(self, name: str) -> Optional[_Metric]:
        """Look up a family by exact name (None if absent)."""
        with self._lock:
            return self._metrics.get(name)

    def snapshot(self) -> Dict:
        """JSON-able dump of every family.

        Counters/gauges: ``{"kind", "help", "values": [{"labels",
        "value"}]}``.  Histograms additionally carry per-child bucket
        counts, sum, count, and estimated p50/p99 — the benchmark
        evidence format.
        """
        out: Dict = {}
        for metric in self.collect():
            fam: Dict = {"kind": metric.kind, "help": metric.help}
            if isinstance(metric, Histogram):
                fam["buckets"] = list(metric.buckets)
                fam["values"] = [
                    {
                        "labels": dict(key),
                        "count": st.count,
                        "sum": st.sum,
                        "bucket_counts": list(st.counts),
                        "p50": metric.quantile(0.5, **dict(key)),
                        "p99": metric.quantile(0.99, **dict(key)),
                    }
                    for key, st in metric.items()
                ]
            else:
                fam["values"] = [
                    {"labels": dict(key), "value": v} for key, v in metric.items()
                ]
            out[metric.name] = fam
        return out


# ----------------------------------------------------------- default registry
_default_registry = MetricsRegistry()
_default_lock = threading.Lock()


def registry() -> MetricsRegistry:
    """The process-global default registry (every built-in mirror
    resolves it at call time, so :func:`set_registry` swaps take effect
    immediately)."""
    return _default_registry


def set_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Install ``reg`` as the process default; returns the previous
    one (tests install a fresh registry and restore on teardown)."""
    global _default_registry
    with _default_lock:
        prev = _default_registry
        _default_registry = reg
    return prev
