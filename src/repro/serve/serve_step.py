"""Inference steps for the assigned LM architectures.

``make_prefill_step`` lowers the full forward over the prompt (logits
for every position — cache materialization is the decode path's first
iteration in this framework).  ``make_decode_step`` lowers one-token
decode against a KV/recurrent cache of a given length — the unit the
``decode_32k``/``long_500k`` dry-run cells compile.
"""

from __future__ import annotations

from typing import Callable, Dict

from repro.models import DecoderLM, EncDecLM
from repro.models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig) -> Callable:
    if cfg.is_encoder_decoder:
        model = EncDecLM(cfg)

        def prefill(params: Dict, batch: Dict):
            return model.apply(params, batch["frames"], batch["tokens"], remat=False)

        return prefill
    model = DecoderLM(cfg)

    def prefill(params: Dict, batch: Dict):
        return model.apply(
            params, batch["tokens"], prefix_embeds=batch.get("patch_embeds"),
            remat=False,
        )

    return prefill


def make_decode_step(cfg: ModelConfig) -> Callable:
    """(params, cache, tokens (B,1)) -> (logits (B,1,V), new cache)."""
    if cfg.is_encoder_decoder:
        model = EncDecLM(cfg)
        return model.decode_step
    model = DecoderLM(cfg)
    return model.decode_step


def make_cache_factory(cfg: ModelConfig) -> Callable:
    if cfg.is_encoder_decoder:
        model = EncDecLM(cfg)
        return model.init_cache
    model = DecoderLM(cfg)
    return model.init_cache
