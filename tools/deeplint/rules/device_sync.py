"""Rule ``device-sync``: no implicit host/device syncs on the jit path.

Inside any ``@jax.jit``-decorated function (including ``partial(jax.jit,
...)`` decorators), the following force a device round-trip or trace-time
materialisation and are flagged:

* host-numpy calls (``np.asarray``/``np.array``/any name bound to
  ``numpy``) on traced values;
* ``.item()`` / ``.tolist()`` / ``.block_until_ready()``;
* ``jax.device_get`` / ``float()``/``int()`` on traced arrays are not
  detectable soundly and are left to review, but ``print`` is flagged.

Sanctioned collect points — the one place the pipeline is *supposed* to
sync (e.g. ``InferenceEngine.collect``) — are host-side functions and
therefore naturally out of scope; a jit-side exception can be annotated
``# deeplint: collect-point`` on its ``def`` line.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Set

from tools.deeplint.engine import Finding, Project, SourceModule, module_import_map

RULE_ID = "device-sync"
SUMMARY = "implicit host/device sync inside a jit-traced function"

SYNC_ATTRS = {"item", "tolist", "block_until_ready"}


def _numpy_aliases(src: SourceModule) -> Set[str]:
    return {
        local
        for local, target in module_import_map(src).items()
        if target == "numpy"
    }


def _is_jit_decorator(dec: ast.expr) -> bool:
    """jax.jit / jit / partial(jax.jit, ...) / jax.jit(...) decorators."""

    def names_jit(expr: ast.expr) -> bool:
        if isinstance(expr, ast.Name):
            return expr.id == "jit"
        if isinstance(expr, ast.Attribute):
            return expr.attr == "jit"
        return False

    if names_jit(dec):
        return True
    if isinstance(dec, ast.Call):
        if names_jit(dec.func):
            return True
        return any(names_jit(a) for a in dec.args)
    return False


def check(project: Project) -> Iterable[Finding]:
    findings: List[Finding] = []
    for src in project.modules:
        np_aliases = _numpy_aliases(src)
        for fn in ast.walk(src.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not any(_is_jit_decorator(d) for d in fn.decorator_list):
                continue
            if src.is_collect_point(fn):
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                func = node.func
                if isinstance(func, ast.Name) and func.id == "print":
                    findings.append(
                        src.finding(
                            RULE_ID,
                            node,
                            f"print() inside jit function {fn.name!r} forces "
                            "a host sync; use jax.debug.print",
                        )
                    )
                if not isinstance(func, ast.Attribute):
                    continue
                if func.attr in SYNC_ATTRS:
                    findings.append(
                        src.finding(
                            RULE_ID,
                            node,
                            f".{func.attr}() inside jit function {fn.name!r} "
                            "forces a device sync; keep results on device",
                        )
                    )
                root = func.value
                while isinstance(root, ast.Attribute):
                    root = root.value
                if isinstance(root, ast.Name) and root.id in np_aliases:
                    findings.append(
                        src.finding(
                            RULE_ID,
                            node,
                            f"host numpy call {root.id}.{func.attr} inside "
                            f"jit function {fn.name!r} materialises traced "
                            "values at trace time; use jnp",
                        )
                    )
    return findings
