"""Per-owner health scoring for replica failover.

:class:`HealthTracker` keeps, per owner: a consecutive-failure count, a
latency EWMA, and a quarantine flag.  ``fail_threshold`` consecutive
failures quarantine the owner; while quarantined it is skipped by
:meth:`pick` (failover) until a *probe* — every ``probe_every``-th pick
that would have skipped it routes one request through it deliberately.
A successful probe clears the quarantine; a failed probe re-arms it.

Scoring is pick-count driven, not wall-clock driven, so fault tests
replay deterministically.  The tracker is thread-safe (fan-out pool
threads record results concurrently) and emits
``deepmap_fault_quarantines_total`` / ``deepmap_fault_probes_total``.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence

from repro import obs


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """Quarantine/probe knobs.

    ``fail_threshold`` consecutive failures quarantine an owner;
    every ``probe_every``-th skip of a quarantined owner routes one
    probe request through it instead.  ``ewma_alpha`` is the latency
    smoothing factor (higher = more reactive).
    """

    fail_threshold: int = 2
    probe_every: int = 8
    ewma_alpha: float = 0.3

    def __post_init__(self) -> None:
        if self.fail_threshold < 1:
            raise ValueError("fail_threshold must be >= 1")
        if self.probe_every < 1:
            raise ValueError("probe_every must be >= 1")
        if not (0.0 < self.ewma_alpha <= 1.0):
            raise ValueError("ewma_alpha must be in (0, 1]")


@dataclasses.dataclass
class _OwnerHealth:
    consecutive_failures: int = 0
    quarantined: bool = False
    ewma_latency_s: Optional[float] = None
    skips_since_probe: int = 0
    successes: int = 0
    failures: int = 0


class HealthTracker:
    """Tracks owner health and answers "which replica should serve?".

    Owners are opaque string names (``"member:0"``...).  The tracker
    never raises on unknown owners — first contact lazily registers
    them healthy.
    """

    def __init__(self, policy: HealthPolicy = HealthPolicy()):
        self.policy = policy
        self._lock = threading.Lock()
        self._owners: Dict[str, _OwnerHealth] = {}  # guarded-by: _lock

    def _get(self, owner: str) -> _OwnerHealth:
        # Callers hold self._lock.
        state = self._owners.get(owner)
        if state is None:
            state = _OwnerHealth()
            # Lazy registration; every caller holds self._lock (see the
            # method contract above).
            self._owners[owner] = state  # deeplint: ignore[lock-discipline]
        return state

    # ------------------------------------------------------------ recording
    def record_success(self, owner: str, latency_s: float) -> bool:
        """Record a successful call; returns True if this recovered the
        owner out of quarantine (a successful probe)."""
        with self._lock:
            state = self._get(owner)
            recovered = state.quarantined
            state.quarantined = False
            state.consecutive_failures = 0
            state.skips_since_probe = 0
            state.successes += 1
            if state.ewma_latency_s is None:
                state.ewma_latency_s = float(latency_s)
            else:
                a = self.policy.ewma_alpha
                state.ewma_latency_s = (
                    a * float(latency_s) + (1.0 - a) * state.ewma_latency_s
                )
        if recovered:
            obs.registry().counter(
                "deepmap_fault_recoveries_total",
                "Owners recovered out of quarantine by a successful probe.",
            ).inc(owner=owner)
        return recovered

    def record_failure(self, owner: str) -> bool:
        """Record a failed call; returns True if this call *newly*
        quarantined the owner (threshold crossed)."""
        with self._lock:
            state = self._get(owner)
            state.failures += 1
            state.consecutive_failures += 1
            newly = (
                not state.quarantined
                and state.consecutive_failures >= self.policy.fail_threshold
            )
            if newly:
                state.quarantined = True
                state.skips_since_probe = 0
        if newly:
            obs.registry().counter(
                "deepmap_fault_quarantines_total",
                "Owners quarantined (consecutive failures, or corrupt "
                "artifacts at load).",
            ).inc(owner=owner)
        return newly

    # ------------------------------------------------------------- querying
    def is_quarantined(self, owner: str) -> bool:
        """Whether the owner is currently quarantined."""
        with self._lock:
            state = self._owners.get(owner)
            return bool(state is not None and state.quarantined)

    def latency(self, owner: str) -> Optional[float]:
        """Latency EWMA in seconds (None before first success)."""
        with self._lock:
            state = self._owners.get(owner)
            return None if state is None else state.ewma_latency_s

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """Point-in-time health view for explain/debug output."""
        with self._lock:
            return {
                name: {
                    "quarantined": s.quarantined,
                    "consecutive_failures": s.consecutive_failures,
                    "ewma_latency_s": s.ewma_latency_s,
                    "successes": s.successes,
                    "failures": s.failures,
                }
                for name, s in self._owners.items()
            }

    # -------------------------------------------------------------- routing
    def pick(self, owners: Sequence[str], preferred: int) -> int:
        """Choose a serving replica among ``owners``.

        Starts from index ``preferred`` (the caller's primary or
        round-robin choice) and fails over to the next healthy owner in
        ring order.  Quarantined owners are skipped, except that every
        ``probe_every``-th skip deliberately routes through the
        quarantined owner as a probe (counted in
        ``deepmap_fault_probes_total``).  If *every* owner is
        quarantined, returns ``preferred`` — serving a possibly-dead
        replica beats refusing outright, and a success will recover it.
        """
        n = len(owners)
        if n == 0:
            raise ValueError("pick() needs at least one owner")
        preferred = int(preferred) % n
        probe_owner: Optional[str] = None
        choice = preferred
        with self._lock:
            for step in range(n):
                idx = (preferred + step) % n
                state = self._owners.get(owners[idx])
                if state is None or not state.quarantined:
                    choice = idx
                    break
                state.skips_since_probe += 1
                if state.skips_since_probe >= self.policy.probe_every:
                    state.skips_since_probe = 0
                    probe_owner = owners[idx]
                    choice = idx
                    break
            else:
                choice = preferred
        if probe_owner is not None:
            obs.registry().counter(
                "deepmap_fault_probes_total",
                "Probe requests routed through quarantined owners.",
            ).inc(owner=probe_owner)
        return choice

    def healthy(self, owners: Sequence[str]) -> List[str]:
        """The subset of ``owners`` not currently quarantined."""
        with self._lock:
            out = []
            for name in owners:
                state = self._owners.get(name)
                if state is None or not state.quarantined:
                    out.append(name)
            return out
