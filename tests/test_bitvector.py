import numpy as np

from repro.core.bitvector import BitVector


class TestBitVector:
    def test_set_test(self):
        bv = BitVector(1000)
        keys = np.array([0, 63, 64, 65, 999])
        bv.set(keys, True)
        assert bv.test(keys).all()
        assert not bv.test(np.array([1, 62, 66, 998])).any()
        assert bv.count() == 5

    def test_unset(self):
        bv = BitVector.from_keys(np.arange(100))
        bv.set(np.arange(0, 100, 2), False)
        assert bv.count() == 50
        assert bv.test(np.array([1, 3, 99])).all()
        assert not bv.test(np.array([0, 2, 98])).any()

    def test_grow_on_set(self):
        bv = BitVector(10)
        bv.set(np.array([1_000_000]), True)
        assert bv.capacity == 1_000_001
        assert bv.test(np.array([1_000_000]))[0]
        assert not bv.test(np.array([999_999]))[0]

    def test_out_of_domain_false(self):
        bv = BitVector.from_keys(np.array([5]))
        out = bv.test(np.array([-3, 100, 5]))
        assert out.tolist() == [False, False, True]

    def test_serialize_roundtrip(self):
        keys = np.random.default_rng(0).permutation(10_000)[:777]
        bv = BitVector.from_keys(keys, capacity=10_000)
        bv2 = BitVector.from_bytes(bv.to_bytes())
        assert bv2.capacity == bv.capacity
        np.testing.assert_array_equal(bv2.words, bv.words)

    def test_compressed_at_rest_smaller_for_sparse(self):
        bv = BitVector(1 << 20)
        bv.set(np.array([17]), True)
        assert bv.size_bytes() < bv.runtime_bytes() / 10

    def test_empty(self):
        bv = BitVector(0)
        assert bv.count() == 0
        assert bv.test(np.array([0, 1])).tolist() == [False, False]
