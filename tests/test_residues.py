"""Beyond-paper residue features: encoder widths, detection, and the
hybrid-store integration (EXPERIMENTS.md §Perf, technique dimension)."""

import numpy as np
import pytest

from repro.core import DeepMappingConfig, DeepMappingStore
from repro.core.encoding import KeyEncoder, detect_column_period, detect_residues
from repro.core.trainer import TrainConfig
from repro.data import customer_demographics_like


class TestResidueEncoder:
    def test_width_accounts_for_residues(self):
        enc = KeyEncoder(max_key=999, base=10, residues=(7, 49))
        assert enc.width == 3 + 1 + 2  # digits + 1-digit %7 + 2-digit %49

    def test_residue_positions_carry_mod(self):
        enc = KeyEncoder(max_key=999, base=10, residues=(7,))
        keys = np.array([0, 6, 7, 13, 700])
        d = enc.digits(keys)
        np.testing.assert_array_equal(d[:, -1], keys % 7)

    def test_multi_digit_residue_roundtrip(self):
        enc = KeyEncoder(max_key=10**6 - 1, base=10, residues=(1372,))
        keys = np.array([0, 1371, 1372, 987654], dtype=np.int64)
        d = enc.digits(keys)
        res_digits = d[:, -4:]  # 1371 needs 4 decimal digits
        recon = (res_digits * np.array([1000, 100, 10, 1])).sum(axis=1)
        np.testing.assert_array_equal(recon, keys % 1372)

    def test_jax_matches_numpy(self):
        import jax.numpy as jnp

        enc = KeyEncoder(max_key=99999, base=10, residues=(7, 343))
        keys = np.array([0, 1, 49, 342, 99999], dtype=np.int64)
        np.testing.assert_array_equal(
            np.asarray(enc.digits_jax(jnp.asarray(keys))), enc.digits(keys)
        )

    def test_onehot_consistent_with_digits(self):
        enc = KeyEncoder(max_key=999, base=10, residues=(7,))
        oh = enc.onehot(np.array([13]))
        assert oh.shape == (1, enc.width * 10)
        assert oh.sum() == enc.width

    def test_invalid_residue_raises(self):
        with pytest.raises(ValueError):
            KeyEncoder(max_key=10, base=10, residues=(1,))


class TestPeriodDetection:
    def test_detects_simple_period(self):
        keys = np.arange(5000, dtype=np.int64)
        col = ((keys // 10) % 4).astype(np.int32)
        p = detect_column_period(keys, col)
        assert p == 40

    def test_detects_stride_one(self):
        keys = np.arange(1, 5001, dtype=np.int64)
        col = ((keys - 1) % 7).astype(np.int32)
        assert detect_column_period(keys, col) == 7

    def test_tolerates_noise(self):
        rng = np.random.default_rng(0)
        keys = np.arange(8000, dtype=np.int64)
        col = ((keys // 16) % 5).astype(np.int32)
        flip = rng.random(8000) < 0.01
        col[flip] = rng.integers(0, 5, int(flip.sum()))
        assert detect_column_period(keys, col) == 80

    def test_random_column_none(self):
        rng = np.random.default_rng(1)
        keys = np.arange(5000, dtype=np.int64)
        col = rng.integers(0, 5, 5000).astype(np.int32)
        assert detect_column_period(keys, col) is None

    def test_constant_column(self):
        keys = np.arange(100, dtype=np.int64)
        assert detect_column_period(keys, np.zeros(100, np.int32)) == 1

    def test_detect_residues_cross_product(self):
        table = customer_demographics_like(n=30_000)
        res = detect_residues(table.keys, table.columns, base=10)
        assert 7 in res          # dep_college: stride 1, card 7
        assert 49 in res         # dep_employed
        assert len(res) >= 3

    def test_position_cap_respected(self):
        table = customer_demographics_like(n=30_000)
        res = detect_residues(table.keys, table.columns, base=10, max_positions=3)
        total = sum(len(str(r - 1)) for r in res)
        assert total <= 3


class TestStoreWithResidues:
    def test_lossless_and_better_memorization(self):
        table = customer_demographics_like(n=8000)
        train = TrainConfig(epochs=25, batch_size=2048)
        plain = DeepMappingStore.build(
            table, DeepMappingConfig(shared=(64,), private=(16,), train=train)
        )
        auto = DeepMappingStore.build(
            table,
            DeepMappingConfig(shared=(64,), private=(16,), train=train,
                              auto_residues=True),
        )
        # both lossless
        for store in (plain, auto):
            v, e = store.lookup(table.keys[:500])
            assert e.all()
            for c in table.columns:
                np.testing.assert_array_equal(v[c], table.columns[c][:500])
        assert auto.memorized_fraction() > plain.memorized_fraction()

    def test_residue_store_serializes(self, tmp_path):
        import os

        from repro.core.serialize import load_store, save_store

        table = customer_demographics_like(n=2000)
        store = DeepMappingStore.build(
            table,
            DeepMappingConfig(shared=(32,), private=(), residues=(7, 49),
                              train=TrainConfig(epochs=5, batch_size=512)),
        )
        p = os.path.join(tmp_path, "s")
        save_store(store, p)
        s2 = load_store(p)
        assert s2.encoder.residues == (7, 49)
        v1, e1 = store.lookup(table.keys[:100])
        v2, e2 = s2.lookup(table.keys[:100])
        np.testing.assert_array_equal(e1, e2)
        for c in v1:
            np.testing.assert_array_equal(v1[c], v2[c])
