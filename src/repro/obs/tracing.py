"""Always-on span tracing into a bounded ring buffer.

Spans form the plan → morsel → operator hierarchy of the streaming
executor (DESIGN.md §Observability).  Each span is one complete
interval — name, track, start/end on the shared
:func:`time.perf_counter` clock, small ``args`` dict — appended to a
``deque(maxlen=...)`` so memory stays bounded no matter how long a
server runs; old spans fall off the back.

Two recording styles:

* ``with tracer.span("collect", track="host", morsel=3):`` — timed by
  the context manager.  This is the common case for host-side work.
* ``tracer.add_span("infer_dispatch", t0, t1, track="device", ...)`` —
  explicitly-timed.  The executor uses this for device-window spans,
  which are only *known* retroactively: the dispatch span for morsel
  *i* spans [dispatch(i) → collect-start(i)], and collect-start only
  happens after morsel *i+1* was dispatched.  Recording them
  retroactively is what makes the overlap show up as overlapping
  tracks in the Chrome trace instead of nested ones.

Tracks are logical timelines ("host", "device"), not OS threads: the
executor's dispatch/collect both run on one Python thread, but the
device work they bracket proceeds asynchronously, so it gets its own
track.  The Chrome exporter maps each track to a tid with a
thread_name metadata event.

``tracer.enabled = False`` turns :meth:`Tracer.span` into a shared
no-op context manager and :meth:`add_span` into an early return — the
same kill-switch discipline as the metrics registry.
"""

from __future__ import annotations

import contextlib
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional

#: Default ring capacity — at 8 spans per morsel this holds ~4k
#: morsels of history, a few hundred bytes each.
DEFAULT_CAPACITY = 32768


@dataclass
class Span:
    """One completed interval on a logical track."""

    name: str
    track: str
    start: float  # perf_counter seconds
    end: float
    args: Dict[str, object] = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.end - self.start


class _SpanContext:
    """Context manager handed out by :meth:`Tracer.span`; records the
    span on exit (even when the body raises, so traces show the work
    that was attempted)."""

    __slots__ = ("_tracer", "_name", "_track", "_args", "_start")

    def __init__(self, tracer: "Tracer", name: str, track: str, args: Dict):
        self._tracer = tracer
        self._name = name
        self._track = track
        self._args = args
        self._start = 0.0

    def __enter__(self) -> "_SpanContext":
        self._start = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._tracer.add_span(
            self._name, self._start, time.perf_counter(), self._track, **self._args
        )


@contextlib.contextmanager
def _noop_span() -> Iterator[None]:
    yield None


class Tracer:
    """Bounded span recorder (thread-safe append, snapshot reads)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY, enabled: bool = True):
        self.enabled = enabled
        self.capacity = capacity
        self._lock = threading.Lock()
        self._spans: deque = deque(maxlen=capacity)  # guarded-by: _lock

    def span(self, name: str, track: str = "host", **args):
        """Context manager timing one span; ``args`` become trace-event
        args (keep them small and low-cardinality)."""
        if not self.enabled:
            return _noop_span()
        return _SpanContext(self, name, track, args)

    def add_span(
        self, name: str, start: float, end: float, track: str = "host", **args
    ) -> None:
        """Record an explicitly-timed span (perf_counter endpoints)."""
        if not self.enabled:
            return
        # clamp negative durations (clock skew between explicit endpoints)
        span = Span(name=name, track=track, start=start, end=max(start, end), args=args)
        with self._lock:
            self._spans.append(span)

    def spans(self, name: Optional[str] = None, track: Optional[str] = None) -> List[Span]:
        """Snapshot of recorded spans, oldest first, optionally
        filtered by exact name and/or track."""
        with self._lock:
            out = list(self._spans)
        if name is not None:
            out = [s for s in out if s.name == name]
        if track is not None:
            out = [s for s in out if s.track == track]
        return out

    def clear(self) -> None:
        """Drop all recorded spans (capacity unchanged)."""
        with self._lock:
            self._spans.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)


# ------------------------------------------------------------ default tracer
_default_tracer = Tracer()
_default_lock = threading.Lock()


def tracer() -> Tracer:
    """The process-global default tracer (resolved at call time)."""
    return _default_tracer


def set_tracer(t: Tracer) -> Tracer:
    """Install ``t`` as the process default; returns the previous one."""
    global _default_tracer
    with _default_lock:
        prev = _default_tracer
        _default_tracer = t
    return prev
