"""Serving substrate: prefill/decode steps for the LM architectures and
the DeepMapping batched lookup server (the paper's deployment)."""

from repro.serve.serve_step import make_decode_step, make_prefill_step  # noqa: F401
from repro.serve.engine import LookupServer  # noqa: F401
