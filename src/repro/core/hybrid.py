"""The DeepMapping hybrid structure ``M̂ = ⟨M, T_aux, V_exist, f_decode⟩``
(paper §IV) with Algorithm 1 lookup and Algorithm 3/4/5 modifications.

A :class:`DeepMappingStore` owns:

* ``params``/``spec``  — the multi-task memorization MLP ``M``;
* ``aux``              — :class:`~repro.core.aux_table.AuxTable` (``T_aux``);
* ``vexist``           — :class:`~repro.core.bitvector.BitVector`;
* ``codecs``           — per-column :class:`~repro.core.encoding.ValueCodec`
                         (``f_decode``);
* ``encoder``          — digit featurizer for keys.

Eq. 1 of the paper is :meth:`compression_ratio`:
``(size(M)+size(T_aux)+size(V_exist)+size(f_decode)) / size(D)``.

Modification semantics follow the paper exactly: inserts/updates/deletes
are materialized in the auxiliary structures without touching ``M``;
:meth:`should_retrain` triggers lazily once modified bytes exceed a
threshold (the paper's DM-Z1 retrains after 200 MB of modifications).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.api.plan import ExplainStats, agg_partials, fold_agg_partials
from repro.api.protocol import MappingStore
from repro.core import model as model_lib
from repro.core import trainer as trainer_lib
from repro.core.aux_table import AuxTable
from repro.core.bitvector import BitVector
from repro.core.encoding import KeyEncoder, ValueCodec, build_codecs
from repro.core.inference import InferenceEngine
from repro.core.model import MLPSpec
from repro.core.table import Table
from repro.storage import MemoryPool


@dataclasses.dataclass(frozen=True)
class DeepMappingConfig:
    """Build-time knobs. ``shared``/``private`` give the default manual
    architecture; MHAS (``repro.core.mhas``) searches these instead."""

    base: int = 10
    # Beyond-paper: residue feature positions (multi-digit key % r).
    # Empty + auto_residues=False = paper-faithful encoding.  See
    # DESIGN.md §Perf / EXPERIMENTS §Perf.
    residues: Tuple[int, ...] = ()
    auto_residues: bool = False   # detect per-column periods at build
    shared: Tuple[int, ...] = (256, 256)
    private: Tuple[int, ...] = (64,)
    codec: str = "zstd"                    # DM-Z; "lzma" = DM-L
    partition_bytes: int = 128 * 1024
    dtype: str = "float32"
    train: trainer_lib.TrainConfig = dataclasses.field(
        default_factory=trainer_lib.TrainConfig
    )
    # Retrain once this many raw bytes have been inserted/deleted/updated
    # (paper's DM-Z1 uses 200 MB). None disables auto-trigger.
    retrain_after_modified_bytes: Optional[int] = None
    inference_batch: int = 1 << 16
    # Route inference through the fused Pallas kernel (TPU hot path).
    # The SAME path is used for build-time misclassification evaluation
    # and lookup, so T_aux always corrects exactly the deployed model.
    use_pallas: bool = False


#: Device chunks in flight ahead of the host half.  Bounds device
#: residency for huge scan/range batches (the window slides forward as
#: chunks are collected) while still double-buffering the pipeline.
DISPATCH_WINDOW = 2


@dataclasses.dataclass
class _PendingLookup:
    """Handle returned by ``_dispatch_lookup``: device inference for
    the first ``DISPATCH_WINDOW`` chunks is enqueued; the host half
    (existence fallback, aux merge, decode) runs at ``_collect_lookup``
    time, which tops the window up as it drains — device inference of
    chunk *i+1* overlaps the host half of chunk *i*, with at most
    ``DISPATCH_WINDOW`` chunks resident on device."""

    keys: np.ndarray
    wanted: Tuple[str, ...]            # heads to evaluate (selected + predicate)
    decode: Tuple[str, ...]            # columns to decode (selected only)
    skipped: Tuple[str, ...]
    preds: tuple                       # [(wanted idx, code table, describe), ...]
    tickets: list                      # [(start, InferTicket), ...] in flight
    next_start: int                    # first key offset not yet dispatched
    dispatch_s: float
    #: ((column, bool code table), ...) shipped to the engine so the
    #: fused kernel can evaluate the predicate conjunction in-kernel.
    kernel_tables: tuple = ()


class DeepMappingStore(MappingStore):
    """Hybrid learned KV store for one relation (single packed key)."""

    def __init__(
        self,
        encoder: KeyEncoder,
        spec: MLPSpec,
        params: Dict,
        codecs: Dict[str, ValueCodec],
        aux: AuxTable,
        vexist: BitVector,
        raw_bytes: int,
        num_rows: int,
        config: DeepMappingConfig,
    ):
        self.encoder = encoder
        self.spec = spec
        self.params = params
        self.codecs = codecs
        self.aux = aux
        self.vexist = vexist
        self.raw_bytes = int(raw_bytes)
        self.num_rows = int(num_rows)
        self.config = config
        self.modified_bytes = 0
        self._bytes_per_row = raw_bytes / max(1, num_rows)
        # Device inference engine: padded-weight cache per task subset,
        # bucketed batch compiles, dispatch/collect pipeline.  Lazy —
        # build() attaches the warm engine it evaluated T_aux with; a
        # cluster attaches engines from its shared EngineCache.
        self._engine: Optional[InferenceEngine] = None

    @property
    def engine(self) -> InferenceEngine:
        if self._engine is None:
            self._engine = InferenceEngine.for_store(self)
        return self._engine

    def attach_engine(self, engine: InferenceEngine) -> None:
        """Adopt an externally-built engine (build-time warm cache, or
        a cluster's shared-stats engine); the engine's bitvector binding
        (and its device word cache) is refreshed to this store's."""
        engine.bind_vexist(self.vexist)
        self._engine = engine

    # ------------------------------------------------------------------ build
    @classmethod
    def build(
        cls,
        table: Table,
        config: DeepMappingConfig = DeepMappingConfig(),
        pool: Optional[MemoryPool] = None,
        spec: Optional[MLPSpec] = None,
        params: Optional[Dict] = None,
        verbose: bool = False,
    ) -> "DeepMappingStore":
        """Train (or accept) a mapping model and assemble the hybrid.

        Passing ``spec``+``params`` (e.g. from MHAS) skips training.
        """
        residues = config.residues
        if config.auto_residues:
            from repro.core.encoding import detect_residues

            residues = tuple(sorted(set(residues) | set(
                detect_residues(table.keys, table.columns, config.base)
            )))
            if verbose and residues:
                print(f"[build] auto-detected residue periods: {residues}")
        encoder = KeyEncoder(table.max_key, base=config.base, residues=residues)
        codecs = build_codecs(table.columns)
        if spec is None:
            spec = MLPSpec(
                base=config.base,
                width=encoder.width,
                shared=tuple(config.shared),
                private={n: tuple(config.private) for n in table.columns},
                out_cards={n: codecs[n].cardinality for n in table.columns},
                dtype=config.dtype,
            )
        digits = encoder.digits(table.keys)
        codes = np.stack([codecs[t].codes for t in spec.tasks], axis=1)
        if params is None:
            params, _, hist = trainer_lib.train(spec, digits, codes, config.train)
            if verbose:
                print(f"[build] trained {len(hist)} epochs, final loss {hist[-1]:.5f}")
        # Misclassification evaluation runs through the SAME engine that
        # will serve lookups (fused Pallas kernel or jit twin), so T_aux
        # always corrects exactly the deployed model; the warm weight
        # cache is adopted by the store below.
        engine = InferenceEngine(
            encoder, spec, params,
            use_pallas=config.use_pallas, max_bucket=config.inference_batch,
        )
        wrong = trainer_lib.evaluate_misclassified_engine(
            engine, table.keys, codes, batch=config.inference_batch
        )
        aux = AuxTable.build(
            table.keys[wrong],
            codes[wrong],
            codec=config.codec,
            partition_bytes=config.partition_bytes,
            pool=pool,
        )
        vexist = BitVector.from_keys(table.keys)
        store = cls(
            encoder=encoder,
            spec=spec,
            params=params,
            codecs=codecs,
            aux=aux,
            vexist=vexist,
            raw_bytes=table.raw_size_bytes(),
            num_rows=table.num_rows,
            config=config,
        )
        store.attach_engine(engine)
        if verbose:
            memorized = 1.0 - wrong.mean() if wrong.size else 1.0
            print(
                f"[build] memorized {memorized:.1%} of {table.num_rows} rows; "
                f"ratio {store.compression_ratio():.4f}"
            )
        return store

    # ---------------------------------------------------------------- lookup
    def _infer_codes(
        self, keys: np.ndarray, tasks: Optional[Tuple[str, ...]] = None
    ) -> np.ndarray:
        """Model predictions for (possibly out-of-capacity) keys.

        ``tasks`` restricts evaluation to a subset of heads (columns of
        the result follow ``tasks`` order); ``None`` evaluates all.
        Delegates to the :class:`InferenceEngine` (cached padded
        weights, bucketed compiles, pipelined chunks).
        """
        keys = np.asarray(keys, dtype=np.int64)
        return self.engine.infer(keys, tasks)

    @property
    def columns(self) -> Tuple[str, ...]:
        return self.spec.tasks

    def _dispatch_lookup(
        self,
        keys: np.ndarray,
        columns: Optional[Tuple[str, ...]] = None,
        fanout: Optional[bool] = None,
        predicates: tuple = (),
        keys_exist: bool = False,
        on_error: str = "raise",
    ) -> _PendingLookup:
        """Stage 1 of Algorithm 1: enqueue device inference (+ fused
        existence test) for the first chunks of the batch and return.
        The host half runs in :meth:`_collect_lookup`; a caller that
        dispatches batch *i+1* before collecting batch *i* overlaps
        device inference with host aux-merge + decode.  At most
        ``DISPATCH_WINDOW`` chunks are in flight (collect tops the
        window up), so a full-relation scan never pins the whole key
        set on device.  ``fanout`` is accepted for protocol parity
        (nothing to fan out here).

        ``predicates`` are pushed below decode: each compiles here to a
        boolean *code table* over the column's decode map (one
        vectorized evaluation per distinct value, not per row), the
        predicate head joins the inference task set even when the
        projection excludes it, and at collect time rows are filtered
        on their aux-corrected argmax codes — non-matching rows are
        never decoded.  ``keys_exist`` is accepted for hook parity (the
        fused existence test is already device-cheap here); so is
        ``on_error`` — a single-owner store has no healthy subset to
        degrade to, so the executor owns its partial fallback."""
        keys = np.asarray(keys, dtype=np.int64)
        t0 = time.perf_counter()
        selected, wanted, skipped, preds, ktables = self._plan_lookup(
            columns, predicates
        )
        pending = _PendingLookup(
            keys=keys, wanted=wanted, decode=selected, skipped=skipped,
            preds=preds, tickets=[], next_start=0, dispatch_s=0.0,
            kernel_tables=ktables,
        )
        if keys.shape[0] and wanted:
            while (
                len(pending.tickets) < DISPATCH_WINDOW
                and pending.next_start < keys.shape[0]
            ):
                self._dispatch_next_chunk(pending)
        pending.dispatch_s = time.perf_counter() - t0
        return pending

    def _plan_lookup(
        self, columns: Optional[Tuple[str, ...]], predicates: tuple
    ) -> tuple:
        """Shared planning half of :meth:`_dispatch_lookup`: resolve the
        projection/predicate head sets and compile the predicate code
        tables once.  Returns ``(selected, wanted, skipped, preds,
        kernel_tables)`` where ``kernel_tables`` pairs each predicate
        column with its boolean table for the in-kernel filter path."""
        all_tasks = self.spec.tasks
        selected = tuple(t for t in all_tasks if columns is None or t in columns)
        pred_cols = frozenset(p.column for p in predicates)
        wanted = tuple(
            t for t in all_tasks if t in pred_cols or t in selected
        )
        skipped = tuple(t for t in all_tasks if t not in wanted)
        preds = tuple(
            (wanted.index(p.column), self._pred_table(p), p.describe())
            for p in predicates
        )
        ktables = tuple(
            (p.column, preds[i][1]) for i, p in enumerate(predicates)
        )
        return selected, wanted, skipped, preds, ktables

    def _pred_table(self, pred) -> np.ndarray:
        """Memoized boolean code table for one predicate (see
        ``Predicate.code_table``), resident in the store's
        :class:`~repro.api.cache.PlanCache`: a morselized plan
        dispatches per chunk, but the full-vocabulary predicate
        evaluation is paid once per (predicate, decode map), not per
        morsel, and survives across repeated plans.  Invalidated by the
        mutation version AND decode-map identity (``extend()`` swaps in
        a new array); benign race under the shard fan-out — worst case
        is one duplicate compute."""
        codec = self.codecs[pred.column]
        return self.plan_cache().pred_table(
            pred, codec.decode_map, self.mutation_version()
        )

    def _dispatch_next_chunk(self, pending: _PendingLookup) -> None:
        bs = self.config.inference_batch
        start = pending.next_start
        pending.tickets.append((
            start,
            self.engine.dispatch(
                pending.keys[start : start + bs], pending.wanted,
                want_exists=True,
                pred_tables=pending.kernel_tables or None,
            ),
        ))
        pending.next_start = min(start + bs, pending.keys.shape[0])

    def supports_kernel_filter(self, predicates: tuple = ()) -> bool:
        """True when ``predicates`` would be evaluated in-kernel: every
        predicate column is a model head and the full wanted head set
        fits the resident ``fused`` tier (the streamed and jit tiers
        filter on the host).  Checked per plan by the executor to skip
        its host ``Filter`` stage."""
        if not self.config.use_pallas or not predicates:
            return False
        if any(p.column not in self.spec.tasks for p in predicates):
            return False
        return self.engine.kernel_filter_capable(self.spec.tasks)

    def _dispatch_precomputed(
        self,
        keys: np.ndarray,
        ticket,
        columns: Optional[Tuple[str, ...]] = None,
        predicates: tuple = (),
    ) -> _PendingLookup:
        """Pending lookup whose device inference already happened
        elsewhere — the mesh shard scatter computes codes + exist bits
        for all shards in one ``shard_map`` launch and hands each shard
        store a ready :class:`~repro.core.inference.InferTicket` here.
        The host half of Algorithm 1 (existence fallback, aux merge,
        predicate filter, decode) still runs in this store's
        :meth:`_collect_lookup`, so modification overlays and byte
        contracts are identical to the thread-pool path."""
        keys = np.asarray(keys, dtype=np.int64)
        selected, wanted, skipped, preds, _ = self._plan_lookup(
            columns, predicates
        )
        # The scatter computes every head; narrow the ticket to the
        # wanted subset — collect() selects/permutes via task_order.
        ticket.tasks = wanted
        return _PendingLookup(
            keys=keys, wanted=wanted, decode=selected, skipped=skipped,
            preds=preds, tickets=[(0, ticket)], next_start=keys.shape[0],
            dispatch_s=0.0,
        )

    def _collect_lookup(
        self, pending: _PendingLookup
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray, Optional[np.ndarray], ExplainStats]:
        """Stage 2 of Algorithm 1: per chunk, block on the device
        result, apply the aux-table override, filter on argmax codes
        (value-predicate pushdown), and decode the surviving rows —
        while later chunks keep executing on the device.  Returns
        ``(values, exists, match, stats)``; ``match`` is ``None``
        without predicates."""
        keys, wanted, skipped = pending.keys, pending.wanted, pending.skipped
        decode_cols, preds = pending.decode, pending.preds
        all_tasks = self.spec.tasks
        n_chunks = max(
            1, -(-keys.shape[0] // self.config.inference_batch)
        ) if pending.tickets else 0
        fused = bool(pending.tickets) and pending.tickets[0][1].path == "fused"
        kfilter = (
            fused and bool(preds)
            and pending.tickets[0][1].match_dev is not None
        )
        stats = ExplainStats(
            heads_evaluated=wanted,
            heads_skipped=skipped,
            columns_decoded=decode_cols,
            columns_skipped=tuple(t for t in all_tasks if t not in decode_cols),
            predicates=tuple(d for _, _, d in preds),
            plan=(
                f"infer[{len(wanted)}/{len(all_tasks)} heads,"
                f"{pending.tickets[0][1].path if pending.tickets else 'none'}]",
                "exist[fused]" if fused else "exist",
                "aux_merge",
            )
            + (
                (
                    f"filter[{'kernel,' if kfilter else ''}"
                    f"{','.join(d for _, _, d in preds)}]",
                )
                if preds
                else ()
            )
            + (
                f"decode[{','.join(decode_cols)}]",
                f"pipeline[{max(1, n_chunks)} chunks]",
            ),
        )
        stats.kernel_filtered = kfilter
        stats.infer_s = pending.dispatch_s

        if not pending.tickets:
            # Zero keys or empty projection: typed empty/zero columns,
            # host existence only — never reaches JAX.
            t1 = time.perf_counter()
            exists = self.vexist.test(keys)
            t2 = time.perf_counter()
            values = {
                t: self.codecs[t].decode(np.zeros(keys.shape[0], dtype=np.int32))
                for t in decode_cols
            }
            stats.exist_s = t2 - t1
            stats.decode_s = time.perf_counter() - t2
            return values, exists, exists.copy() if preds else None, stats

        task_idx = [all_tasks.index(t) for t in wanted]
        dec_idx = [wanted.index(t) for t in decode_cols]
        exists_parts, match_parts = [], []
        value_parts = {t: [] for t in decode_cols}
        while pending.tickets:
            start, ticket = pending.tickets.pop(0)
            # keep the device window full before blocking on this chunk
            t0 = time.perf_counter()
            while (
                len(pending.tickets) < DISPATCH_WINDOW - 1
                and pending.next_start < keys.shape[0]
            ):
                self._dispatch_next_chunk(pending)
            t1 = time.perf_counter()
            stats.infer_s += t1 - t0
            pred, exists = self.engine.collect(ticket)      # line 3 (inference)
            t2 = time.perf_counter()
            if exists is None:                               # line 5 (existence)
                exists = self.vexist.test(ticket.keys)
            t3 = time.perf_counter()
            # line 6-8: aux override for existing keys only.  T_aux rows
            # carry codes for ALL tasks; project to the evaluated ones.
            exist_idx = np.flatnonzero(exists)
            found, aux_codes = self.aux.get(ticket.keys[exist_idx])
            pred[exist_idx[found]] = aux_codes[found][:, task_idx]
            t4 = time.perf_counter()
            stats.infer_s += t2 - t1
            stats.exist_s += t3 - t2
            stats.aux_s += t4 - t3
            # Predicate filter on aux-corrected argmax codes: one
            # boolean gather per predicate, BEFORE any decode.
            if preds:
                if ticket.match is not None:
                    # In-kernel filter: the fused kernel already ANDed
                    # the predicate code tables over the model codes and
                    # exist bits; only the (few) aux-overridden rows can
                    # have changed codes, so re-evaluate just those on
                    # their corrected codes via the full host tables.
                    match = ticket.match
                    aux_rows = exist_idx[found]
                    if aux_rows.size:
                        patched = np.ones(aux_rows.shape[0], dtype=bool)
                        for wi, table, _ in preds:
                            patched &= table[pred[aux_rows, wi]]
                        match[aux_rows] = patched
                else:
                    stats.kernel_filtered = False
                    match = exists.copy()
                    for wi, table, _ in preds:
                        codes_w = np.where(exists, pred[:, wi], 0)
                        match &= table[codes_w]
                hit = np.flatnonzero(match)
                t5 = time.perf_counter()
                stats.filter_s += t5 - t4
                stats.rows_matched += int(hit.size)
                # line 13: decode ONLY the matching rows.
                for t, wi in zip(decode_cols, dec_idx):
                    codec = self.codecs[t]
                    out = np.zeros(
                        exists.shape[0], dtype=codec.decode_map.dtype
                    )
                    if hit.size:
                        out[hit] = codec.decode(pred[hit, wi])
                    value_parts[t].append(out)
                stats.rows_decoded += int(hit.size)
                stats.decode_s += time.perf_counter() - t5
                match_parts.append(match)
            else:
                # line 13: decode — selected columns only.
                for t, wi in zip(decode_cols, dec_idx):
                    safe = np.where(exists, pred[:, wi], 0)
                    value_parts[t].append(self.codecs[t].decode(safe))
                stats.rows_decoded += int(exists.shape[0])
                stats.decode_s += time.perf_counter() - t4
            exists_parts.append(exists)

        exists = (
            exists_parts[0]
            if len(exists_parts) == 1
            else np.concatenate(exists_parts)
        )
        match = None
        if preds:
            match = (
                match_parts[0]
                if len(match_parts) == 1
                else np.concatenate(match_parts)
            )
        values = {
            t: (parts[0] if len(parts) == 1 else np.concatenate(parts))
            for t, parts in value_parts.items()
        }
        return values, exists, match, stats

    def _iter_corrected_chunks(self, pending: _PendingLookup, stats: ExplainStats):
        """Yield ``(codes, exists, match)`` per chunk of a pending
        lookup — the shared front half of Algorithm 1 (device collect,
        existence fallback, aux override, predicate code-table filter)
        WITHOUT the decode tail.  ``codes`` are the aux-corrected argmax
        codes ``(rows, len(wanted))``; ``match`` is ``None`` without
        predicates.  The aggregate path consumes these directly: for
        existing rows the corrected codes are exact (the aux table
        overrides every model miss), so any reduction over them equals
        the same reduction over decoded values."""
        keys, preds = pending.keys, pending.preds
        while pending.tickets:
            _, ticket = pending.tickets.pop(0)
            t0 = time.perf_counter()
            while (
                len(pending.tickets) < DISPATCH_WINDOW - 1
                and pending.next_start < keys.shape[0]
            ):
                self._dispatch_next_chunk(pending)
            t1 = time.perf_counter()
            codes, exists = self.engine.collect(ticket)
            t2 = time.perf_counter()
            if exists is None:
                exists = self.vexist.test(ticket.keys)
            t3 = time.perf_counter()
            exist_idx = np.flatnonzero(exists)
            found, aux_codes = self.aux.get(ticket.keys[exist_idx])
            task_idx = [self.spec.tasks.index(t) for t in pending.wanted]
            codes[exist_idx[found]] = aux_codes[found][:, task_idx]
            t4 = time.perf_counter()
            stats.infer_s += (t1 - t0) + (t2 - t1)
            stats.exist_s += t3 - t2
            stats.aux_s += t4 - t3
            match = None
            if preds:
                if ticket.match is not None:
                    match = ticket.match
                    aux_rows = exist_idx[found]
                    if aux_rows.size:
                        patched = np.ones(aux_rows.shape[0], dtype=bool)
                        for wi, table, _ in preds:
                            patched &= table[codes[aux_rows, wi]]
                        match[aux_rows] = patched
                else:
                    stats.kernel_filtered = False
                    match = exists.copy()
                    for wi, table, _ in preds:
                        codes_w = np.where(exists, codes[:, wi], 0)
                        match &= table[codes_w]
                stats.filter_s += time.perf_counter() - t4
                stats.rows_matched += int(match.sum())
            yield codes, exists, match

    def _collect_aggregate(self, pending: _PendingLookup, group_by, aggregates):
        """Code-space ``group_by(...).agg(...)``: consume aux-corrected
        argmax codes, never rows.

        Rows group by their raw code vectors (mixed-radix packed over
        the codec cardinalities); ``count`` is a ``bincount`` over the
        packed codes, ``sum``/``min``/``max`` gather per-row values
        through the cached code→value tables
        (:meth:`~repro.api.cache.PlanCache.agg_table` — the decode map
        cast once per vocabulary, version-fenced like the predicate
        tables).  Only the *distinct group labels* are decoded, so
        ``rows_decoded`` stays 0 no matter how many rows aggregate —
        the below-decode claim the TPC-H harness asserts.  State keys
        are decoded group values, mergeable across shards/members with
        independent codecs."""
        keys, wanted, preds = pending.keys, pending.wanted, pending.preds
        all_tasks = self.spec.tasks
        gidx = [wanted.index(c) for c in group_by]
        gdims = [self.codecs[c].cardinality for c in group_by]
        specs = []
        for spec in aggregates:
            if spec.column is None:
                specs.append((None, None))
            else:
                table = self.plan_cache().agg_table(
                    spec.column,
                    self.codecs[spec.column].decode_map,
                    self.mutation_version(),
                )
                specs.append((wanted.index(spec.column), table))
        n_chunks = max(
            1, -(-keys.shape[0] // self.config.inference_batch)
        ) if pending.tickets else 0
        stats = ExplainStats(
            heads_evaluated=wanted,
            heads_skipped=pending.skipped,
            columns_skipped=tuple(t for t in all_tasks if t not in wanted),
            predicates=tuple(d for _, _, d in preds),
            plan=(
                f"infer[{len(wanted)}/{len(all_tasks)} heads,"
                f"{pending.tickets[0][1].path if pending.tickets else 'none'}]",
                "exist",
                "aux_merge",
            )
            + (
                (f"filter[{','.join(d for _, _, d in preds)}]",) if preds else ()
            )
            + (
                f"aggregate[code,{len(group_by)} keys,{len(aggregates)} aggs]",
                f"pipeline[{max(1, n_chunks)} chunks]",
            ),
        )
        stats.infer_s = pending.dispatch_s
        state: Dict[tuple, list] = {}

        def fold(codes: Optional[np.ndarray], sel: np.ndarray) -> None:
            """Fold one chunk's selected rows (code-space) into state."""
            t5 = time.perf_counter()
            if sel.size:
                if gidx:
                    if len(gidx) > 1:
                        packed = np.ravel_multi_index(
                            [codes[sel, wi] for wi in gidx], gdims
                        )
                    else:
                        packed = codes[sel, gidx[0]]
                    ug, ginv = np.unique(packed, return_inverse=True)
                    coords = np.unravel_index(ug, gdims)
                    # decode per DISTINCT group, not per row: this is
                    # label materialization, not row decode
                    labels = [
                        self.codecs[c].decode(np.asarray(coord)).tolist()
                        for c, coord in zip(group_by, coords)
                    ]
                    group_tuples = list(zip(*labels))
                else:
                    ug = np.zeros(1, dtype=np.int64)
                    ginv = np.zeros(sel.size, dtype=np.int64)
                    group_tuples = [()]
                value_arrays = [
                    None if table is None else table[codes[sel, wi]]
                    for wi, table in specs
                ]
                partials = agg_partials(aggregates, ginv, len(ug), value_arrays)
                fold_agg_partials(state, group_tuples, aggregates, partials)
            stats.agg_s += time.perf_counter() - t5

        if not pending.tickets:
            # Zero keys, or a count-only global aggregate with no
            # predicate heads: host existence test answers everything.
            t1 = time.perf_counter()
            exists = self.vexist.test(keys)
            stats.exist_s = time.perf_counter() - t1
            fold(None, np.flatnonzero(exists))
            return state, stats

        for codes, exists, match in self._iter_corrected_chunks(pending, stats):
            sel = np.flatnonzero(exists if match is None else match)
            fold(codes, sel)
        return state, stats

    def _lookup_with_stats(
        self,
        keys: np.ndarray,
        columns: Optional[Tuple[str, ...]] = None,
        fanout: Optional[bool] = None,
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray, ExplainStats]:
        """Algorithm 1 with projection pushdown and per-call stats —
        the dispatch/collect pair run back-to-back (all chunks' device
        work enqueued up front, host half trailing chunk by chunk)."""
        values, exists, _, stats = self._collect_lookup(
            self._dispatch_lookup(keys, columns, fanout)
        )
        return values, exists, stats

    def lookup(
        self, keys: np.ndarray, columns: Optional[Tuple[str, ...]] = None
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Algorithm 1 — batched exact-match lookup.

        Returns ``(values, exists)``: per-column decoded arrays (rows
        where ``exists`` is False are NULL — filled with the column's
        code-0 value, callers must respect the mask) plus the existence
        mask.  For per-call stats use ``store.query(...).execute().explain``
        (the ``last_stats`` side-channel was removed — the metrics
        registry and ``ExplainStats`` supersede it).
        """
        values, exists, _stats = self._lookup_with_stats(keys, columns)
        return values, exists

    # ------------------------------------------------ modifications (Alg 3-5)
    def _encode_rows(self, columns: Dict[str, np.ndarray]) -> np.ndarray:
        """Encode raw values to codes, extending codecs for unseen values.

        Codes beyond a head's out_card can never be predicted by ``M``,
        so such rows are automatically routed to T_aux — exactly the
        paper's semantics for values the model cannot express.
        """
        cols = []
        for t in self.spec.tasks:
            codec = self.codecs[t]
            codec.extend(columns[t])
            codes, known = codec.encode(columns[t])
            if not known.all():
                raise RuntimeError("extend() must make every value encodable")
            cols.append(codes)
        return np.stack(cols, axis=1)

    def insert(self, keys: np.ndarray, columns: Dict[str, np.ndarray]) -> None:
        """Algorithm 3. Pairs the model already generalizes to are NOT
        stored; the rest land in T_aux."""
        keys = np.asarray(keys, dtype=np.int64)
        if keys.size == 0:
            return
        if np.unique(keys).size != keys.size:
            raise ValueError("duplicate keys in insert batch")
        if self.vexist.test(keys).any():
            raise ValueError("insert of existing key; use update()")
        codes = self._encode_rows(columns)
        self.vexist.set(keys, True)                      # line 4
        pred = self._infer_codes(keys)                   # line 5 (inference check)
        wrong = (pred != codes).any(axis=1) | (keys >= self.encoder.capacity)
        if wrong.any():
            self.aux.add(keys[wrong], codes[wrong])      # line 9
        self.num_rows += keys.shape[0]
        self.raw_bytes += int(keys.shape[0] * self._bytes_per_row)
        self.modified_bytes += int(keys.shape[0] * self._bytes_per_row)
        self._note_mutation()  # invalidate cached plans (and, via the
        # version stamp, code tables over a possibly-extended decode map)

    def delete(self, keys: np.ndarray) -> None:
        """Algorithm 4. Existence bit off; purge from T_aux if present."""
        # unique: a key repeated in one batch deletes one row, not two
        keys = np.unique(np.asarray(keys, dtype=np.int64))
        present = self.vexist.test(keys)
        keys = keys[present]
        if keys.size == 0:
            return
        self.vexist.set(keys, False)                     # line 4
        in_aux = self.aux.contains(keys)                 # line 5
        if in_aux.any():
            self.aux.remove(keys[in_aux])
        self.num_rows -= keys.shape[0]
        self.raw_bytes -= int(keys.shape[0] * self._bytes_per_row)
        self.modified_bytes += int(keys.shape[0] * self._bytes_per_row)
        self._note_mutation()

    def update(self, keys: np.ndarray, columns: Dict[str, np.ndarray]) -> None:
        """Algorithm 5. Correctly-predicted updates drop any aux entry;
        the rest are upserted into T_aux."""
        keys = np.asarray(keys, dtype=np.int64)
        if keys.size == 0:
            return
        if not self.vexist.test(keys).all():
            raise ValueError("update of non-existing key; use insert()")
        codes = self._encode_rows(columns)
        pred = self._infer_codes(keys)
        right = (pred == codes).all(axis=1) & (keys < self.encoder.capacity)
        if right.any():
            in_aux = self.aux.contains(keys[right])      # line 4
            if in_aux.any():
                self.aux.remove(keys[right][in_aux])
        wrong = ~right
        if wrong.any():
            self.aux.update(keys[wrong], codes[wrong])   # lines 7-11
        self.modified_bytes += int(keys.shape[0] * self._bytes_per_row)
        self._note_mutation()

    def _range_keys(self, lo: int, hi: Optional[int]) -> np.ndarray:
        """Existence-index range filter (§IV-E) — key source for the
        protocol's ``range_lookup``/``scan`` and the plan executor."""
        return self.vexist.keys_in_range(lo, hi)

    def should_retrain(self) -> bool:
        thr = self.config.retrain_after_modified_bytes
        return thr is not None and self.modified_bytes >= thr

    def materialize(self) -> Table:
        """Reconstruct the full logical table (used by retrain)."""
        keys, values = self.scan()
        return Table(keys=keys, columns=values)

    def retrain(self, verbose: bool = False) -> "DeepMappingStore":
        """Rebuild model + auxiliary structures on current logical data
        (paper: lazily, offline/background/non-peak)."""
        return DeepMappingStore.build(
            self.materialize(), self.config, pool=self.aux.pool, verbose=verbose
        )

    # ---------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        """Protocol persistence — the ``core.serialize`` directory
        format (atomic tmp+rename)."""
        from repro.core import serialize  # local: serialize imports us

        serialize.save_store(self, path)

    @classmethod
    def load(cls, path: str, pool: Optional[MemoryPool] = None) -> "DeepMappingStore":
        from repro.core import serialize

        return serialize.load_store(path, pool=pool)

    # ------------------------------------------------------------- accounting
    def size_breakdown(self) -> Dict[str, int]:
        """Bytes per component — the paper's Fig. 6 storage breakdown."""
        return {
            "model": model_lib.model_size_bytes(self.params),
            "aux_table": self.aux.size_bytes(),
            "exist_bitvector": self.vexist.size_bytes(),
            "decode_map": sum(c.size_bytes() for c in self.codecs.values())
            + self.encoder.size_bytes(),
        }

    def size_bytes(self) -> int:
        return sum(self.size_breakdown().values())

    def compression_ratio(self) -> float:
        """Paper Eq. 1 — lower is better; 1.0 means no compression."""
        return self.size_bytes() / max(1, self.raw_bytes)

    def memorized_fraction(self) -> float:
        """Fraction of rows answered by ``M`` alone (paper reports 66-81%)."""
        return 1.0 - self.aux.num_rows / max(1, self.num_rows)
