"""Fluent query builder over any :class:`~repro.api.protocol.MappingStore`.

    values, exists = store.query().where_keys(ks).execute()
    res = store.query().select("status").where_range(0, 10**6).execute()
    res = store.query().where("status", "==", "F").scan().execute()
    for morsel in store.query().scan().stream(): ...

A builder compiles to a :class:`~repro.api.plan.QueryPlan` (inspect it
with :meth:`Query.plan`) and executes through the streaming operator
pipeline; the result's ``explain`` field reports the executed
operators, pushdown evidence, and the latency breakdown.  Value
predicates (:meth:`where`) are pushed down by default — DeepMapping
stores evaluate them on per-head argmax codes before any row is
decoded; :meth:`pushdown` ``(False)`` switches to the post-hoc
reference filter (decode everything, filter after), kept for
byte-equality testing.
"""

from __future__ import annotations

from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

from repro.api.plan import AggSpec, JoinSpec, Predicate, QueryPlan


class Query:
    """One query under construction.  Builder methods return ``self``;
    exactly one key source (``where_keys`` / ``where_range`` /
    ``scan``) must be chosen before :meth:`execute`."""

    def __init__(self, store):
        self._store = store
        self._kind: Optional[str] = None
        self._keys: Optional[np.ndarray] = None
        self._lo: Optional[int] = None
        self._hi: Optional[int] = None
        self._columns: Optional[Tuple[str, ...]] = None
        self._predicates: Tuple[Predicate, ...] = ()
        self._pushdown: bool = True
        self._fanout: Optional[bool] = None
        self._morsel: Optional[int] = None
        self._cache: bool = True
        self._on_error: str = "raise"
        self._group_by: Tuple[str, ...] = ()
        self._aggregates: Tuple[AggSpec, ...] = ()
        self._join: Optional[JoinSpec] = None

    # ------------------------------------------------------------ projection
    def select(self, *columns: str) -> "Query":
        """Project to the given columns (pushdown: unselected columns
        are not decoded, and DeepMapping stores skip their private
        model heads).  Accepts names or one iterable of names."""
        if len(columns) == 1 and not isinstance(columns[0], str):
            columns = tuple(columns[0])
        if not columns:
            raise ValueError("select() needs at least one column")
        self._check_columns(columns)
        self._columns = tuple(dict.fromkeys(columns))  # dedup, keep order
        return self

    def _check_columns(self, columns: Sequence[str]) -> None:
        known = set(self._store.columns)
        unknown = [c for c in columns if c not in known]
        if unknown:
            raise ValueError(
                f"unknown column(s) {unknown}; store has {sorted(known)}"
            )

    # ------------------------------------------------------------ predicates
    def where(self, column: str, op: str, value) -> "Query":
        """Add a value predicate ``column <op> value`` (AND-combined
        with earlier ``where`` calls).  Pushed down below decode by
        default: the result contains only matching rows, and on
        DeepMapping stores non-matching rows are never decoded — the
        predicate evaluates on per-head argmax codes (aux-corrected),
        with aux/overlay rows filtered through the same path."""
        self._check_columns((column,))
        self._predicates += (Predicate(column=column, op=op, value=value),)
        return self

    def pushdown(self, enabled: bool) -> "Query":
        """``False`` = post-hoc reference filter: decode every row,
        then filter on decoded values.  Byte-identical results to the
        pushed-down path (the equivalence suite checks this); strictly
        more rows decoded."""
        self._pushdown = bool(enabled)
        return self

    # ----------------------------------------------------------- aggregation
    def group_by(self, *columns: str) -> "Query":
        """Group the result by the given columns (follow with
        :meth:`agg`).  On code-space stores the grouping runs below
        decode: rows group by their aux-corrected argmax codes and only
        the distinct group *labels* are decoded, so a count-only
        group-by reports ``rows_decoded == 0``.  Zero columns (the
        default when only :meth:`agg` is called) is a global aggregate:
        one group."""
        if len(columns) == 1 and not isinstance(columns[0], str):
            columns = tuple(columns[0])
        self._check_columns(columns)
        self._group_by = tuple(dict.fromkeys(columns))
        return self

    def agg(self, *specs) -> "Query":
        """Add aggregates: ``"count"`` or ``(func, column)`` pairs with
        ``func`` in :data:`~repro.api.plan.AGG_FUNCS` (``AggSpec``
        objects also accepted).  ``sum``/``min``/``max`` need a numeric
        column and resolve per-group values through code→value tables
        below decode; :meth:`execute` then returns an
        :class:`~repro.api.plan.AggregateResult`."""
        parsed = []
        for spec in specs:
            if isinstance(spec, AggSpec):
                parsed.append(spec)
            elif isinstance(spec, str):
                parsed.append(AggSpec(func=spec))
            else:
                func, column = spec
                parsed.append(AggSpec(func=func, column=column))
        for spec in parsed:
            if spec.column is not None:
                self._check_columns((spec.column,))
        self._aggregates += tuple(parsed)
        return self

    # ------------------------------------------------------------------ join
    def join(self, store, key=None, columns=None, prefix: str = "r.") -> "Query":
        """Inner key-equi join against another store: each surviving
        left row's key is mapped through ``key`` (``None`` = identity)
        and probed into ``store``'s existence index; matching rows keep
        the right store's ``columns`` (``None`` = all), streamed morsel
        by morsel store-to-store (shard/member scatter included).
        Right columns colliding with left output names are prefixed
        with ``prefix``."""
        if columns is not None:
            if isinstance(columns, str):
                columns = (columns,)
            known = set(store.columns)
            unknown = [c for c in columns if c not in known]
            if unknown:
                raise ValueError(
                    f"unknown join column(s) {unknown}; right store has "
                    f"{sorted(known)}"
                )
            columns = tuple(dict.fromkeys(columns))
        if key is not None and not callable(key):
            raise ValueError("join key must be a callable mapping left keys")
        self._join = JoinSpec(store=store, key=key, columns=columns, prefix=prefix)
        return self

    # ------------------------------------------------------------ key source
    def _set_kind(self, kind: str) -> None:
        if self._kind is not None:
            raise ValueError(
                f"key source already set to {self._kind!r}; a query has "
                f"exactly one of where_keys/where_range/scan"
            )
        self._kind = kind

    def where_keys(self, keys: Sequence[int]) -> "Query":
        """Point lookups for the given keys (request order preserved)."""
        self._set_kind("point")
        self._keys = np.asarray(keys, dtype=np.int64)
        return self

    def where_range(self, lo: int, hi: int) -> "Query":
        """Every existing key in ``[lo, hi)``, ascending."""
        self._set_kind("range")
        self._lo, self._hi = int(lo), int(hi)
        return self

    def scan(self) -> "Query":
        """Every existing key, ascending."""
        self._set_kind("scan")
        return self

    # ------------------------------------------------------------- execution
    def fanout(self, enabled: bool) -> "Query":
        """Override the sharded store's parallel lookup fan-out (the
        plan executor defaults it ON; single stores ignore it)."""
        self._fanout = bool(enabled)
        return self

    def morsel(self, rows: int) -> "Query":
        """Force a FIXED executor morsel size (rows per streamed
        chunk).  Without it the executor sizes morsels adaptively:
        seeded at :data:`~repro.api.plan.DEFAULT_MORSEL` and resized
        between morsels from per-operator timings (bounded,
        power-of-two aligned — see ``executor.next_morsel_rows``)."""
        self._morsel = int(rows)
        return self

    def cached(self, enabled: bool) -> "Query":
        """``False`` bypasses the store's plan cache: key-source
        materializations, projection subsets, and predicate code
        tables are recompiled for this plan (the warm-vs-cold
        reference path; results are byte-identical either way)."""
        self._cache = bool(enabled)
        return self

    def on_error(self, mode: str) -> "Query":
        """Failure semantics when an owner (shard, federation member,
        engine) fails terminally after retries.  ``"raise"`` (default)
        raises :class:`~repro.fault.errors.OwnerFailure`; ``"partial"``
        returns the healthy owners' rows byte-identical to a full run —
        unreachable keys report ``exists=False`` — with the failures
        recorded on ``explain`` (``owners_failed``, ``retries``,
        ``keys_unresolved``) so absent and unreachable stay
        distinguishable."""
        self._on_error = str(mode)
        return self

    def plan(self) -> QueryPlan:
        """Compile to the IR without executing."""
        if self._kind is None:
            raise ValueError(
                "no key source; call where_keys/where_range/scan first"
            )
        return QueryPlan(
            kind=self._kind,
            keys=self._keys,
            lo=self._lo,
            hi=self._hi,
            columns=self._columns,
            predicates=self._predicates,
            pushdown=self._pushdown,
            fanout=self._fanout,
            morsel=self._morsel,
            cache=self._cache,
            on_error=self._on_error,
            group_by=self._group_by,
            aggregates=self._aggregates,
            join=self._join,
        )

    def execute(self):
        """Compile and run the plan through the streaming executor.

        Returns a :class:`~repro.api.plan.QueryResult` — or an
        :class:`~repro.api.plan.AggregateResult` when :meth:`agg`
        aggregates are set.
        """
        from repro.api.executor import execute_plan  # local: keep import light

        return execute_plan(self._store, self.plan())

    def stream(self) -> Iterator:
        """Morsel-at-a-time execution: yields
        :class:`~repro.api.executor.MorselResult` chunks as their host
        halves complete, while later morsels' device work stays in
        flight.  Predicate ``match`` selectors are left on the morsels
        for the caller (use :meth:`execute` for a filtered relation)."""
        from repro.api.executor import stream_plan

        return stream_plan(self._store, self.plan())
