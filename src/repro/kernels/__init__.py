"""Pallas TPU kernels for the DeepMapping lookup hot path.

The paper's Algorithm 1 line 3 — batched inference of the multi-task
memorization MLP — dominates device time.  Three kernels:

* ``fused_mlp``   — the WHOLE multi-task model (one-hot-free first layer,
  shared trunk computed once, every head) in a single VMEM-resident
  kernel; optionally emits argmax codes instead of logits so HBM writes
  are O(tasks) int32 per row instead of O(Σ card) floats.
* ``bitvector``   — packed-word existence test (Algorithm 1 line 5).
* ``ref``         — pure-jnp oracles for both.

``ops`` holds the jit'd public wrappers with MXU-alignment padding and
the VMEM-budget check.  Kernels are validated in ``interpret=True`` on
CPU; the dry-run path never traces them (pure-jnp path is used when
lowering for the virtual-device mesh).
"""

from repro.kernels.ops import bitvector_test, fused_mlp_codes, fused_mlp_logits  # noqa: F401
