"""Dataset substrate: synthetic workload generators mirroring the
paper's evaluation datasets (§V-A1) plus token stores and loaders for
the LM training pipeline."""

from repro.data.datasets import (  # noqa: F401
    cropland_like,
    synthetic_multi_column,
    synthetic_single_column,
)
from repro.data.tpch import lineitem_like, orders_like, part_like  # noqa: F401
from repro.data.tpcds import (  # noqa: F401
    catalog_returns_like,
    catalog_sales_like,
    customer_demographics_like,
)
