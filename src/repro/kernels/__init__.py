"""Pallas TPU kernels for the DeepMapping lookup hot path.

The paper's Algorithm 1 line 3 — batched inference of the multi-task
memorization MLP — dominates device time.  Kernels:

* ``fused_mlp``   — the WHOLE multi-task model (one-hot-free first layer,
  shared trunk computed once, every head) in a single VMEM-resident
  kernel; optionally emits argmax codes instead of logits so HBM writes
  are O(tasks) int32 per row instead of O(Σ card) floats.  Its
  ``fused_lookup_call`` variant takes RAW int32 keys — digit/residue
  decomposition happens in-kernel from SMEM scalars — and fuses the
  existence-bitvector test into the same ``pallas_call``, so Algorithm
  1 lines 3+5 are one device round trip (driven by
  ``repro.core.inference.InferenceEngine``).
* ``bitvector``   — standalone packed-word existence test (line 5).
* ``ref``         — pure-jnp oracles for all of the above.

``ops`` holds the jit'd public wrappers with MXU-alignment padding and
the VMEM-budget check.  Kernels are validated in ``interpret=True`` on
CPU; the dry-run path never traces them (pure-jnp path is used when
lowering for the virtual-device mesh).
"""

from repro.kernels.ops import (  # noqa: F401
    bitvector_test,
    fused_lookup,
    fused_mlp_codes,
    fused_mlp_logits,
    pad_flat_weights,
)
