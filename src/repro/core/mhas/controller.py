"""LSTM controller (paper §IV-C2): samples architecture decisions via
softmax classifiers in an autoregressive fashion — 64 hidden units, as
in ENAS, trained with Adam at lr 3.5e-4 (paper §V-A6) using REINFORCE
on the Eq. 1 reward.

Decision sequence (fixed length): for the trunk and then for each task,
one *depth* decision (0..max_layers) followed by ``max_layers`` *size*
decisions (indices into ``layer_sizes``; sizes beyond the sampled depth
are ignored by the search space but still sampled, keeping the sequence
shape static for jit).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.mhas.search_space import SearchSpace

HIDDEN = 64  # paper: LSTM with 64 hidden units
EMBED = 32


@dataclasses.dataclass(frozen=True)
class ControllerSpec:
    num_decisions: int
    depth_choices: int           # max_layers + 1
    size_choices: int
    kinds: Tuple[int, ...]       # 0=depth, 1=size per step

    @classmethod
    def for_space(cls, space: SearchSpace) -> "ControllerSpec":
        return cls(
            num_decisions=space.num_decisions,
            depth_choices=space.max_layers + 1,
            size_choices=space.num_size_choices,
            kinds=tuple(int(k) for k in space.decision_kinds()),
        )

    @property
    def vocab(self) -> int:
        # start token + depth tokens + size tokens (disjoint id ranges)
        return 1 + self.depth_choices + self.size_choices

    def token_id(self, kind: int, choice: jnp.ndarray) -> jnp.ndarray:
        return jnp.where(kind == 0, 1 + choice, 1 + self.depth_choices + choice)

    @property
    def max_choices(self) -> int:
        return max(self.depth_choices, self.size_choices)


def init_controller(spec: ControllerSpec, seed: int = 0) -> Dict:
    # paper: parameters initialized from N(0, 0.05^2)
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    init = lambda k, shape: 0.05 * jax.random.normal(k, shape, jnp.float32)
    return {
        "embed": init(ks[0], (spec.vocab, EMBED)),
        "wx": init(ks[1], (EMBED, 4 * HIDDEN)),
        "wh": init(ks[2], (HIDDEN, 4 * HIDDEN)),
        "b": jnp.zeros((4 * HIDDEN,), jnp.float32),
        "depth_head": init(ks[3], (HIDDEN, spec.depth_choices)),
        "size_head": init(ks[4], (HIDDEN, spec.size_choices)),
    }


def _lstm_step(params: Dict, h, c, x):
    z = x @ params["wx"] + h @ params["wh"] + params["b"]
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def _step_logits(params: Dict, spec: ControllerSpec, h, kind):
    """Kind-select between heads, padding to max_choices with -inf."""
    mc = spec.max_choices
    dl = h @ params["depth_head"]
    sl = h @ params["size_head"]
    pad = lambda l: jnp.pad(l, (0, mc - l.shape[-1]), constant_values=-1e9)
    return jnp.where(kind == 0, pad(dl), pad(sl))


@functools.partial(jax.jit, static_argnames=("spec",))
def sample_arch(params: Dict, spec: ControllerSpec, rng) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Autoregressively sample one decision sequence.

    Returns (tokens (D,) int32 choice indices, sum logprob, sum entropy).
    """
    kinds = jnp.asarray(spec.kinds, jnp.int32)

    def step(carry, inp):
        h, c, prev_tok, key = carry
        kind = inp
        x = params["embed"][prev_tok]
        h, c = _lstm_step(params, h, c, x)
        logits = _step_logits(params, spec, h, kind)
        key, sub = jax.random.split(key)
        choice = jax.random.categorical(sub, logits)
        logp = jax.nn.log_softmax(logits)[choice]
        probs = jax.nn.softmax(logits)
        entropy = -jnp.sum(probs * jnp.where(probs > 0, jnp.log(probs + 1e-12), 0.0))
        tok = spec.token_id(kind, choice)
        return (h, c, tok, key), (choice, logp, entropy)

    carry = (
        jnp.zeros((HIDDEN,), jnp.float32),
        jnp.zeros((HIDDEN,), jnp.float32),
        jnp.zeros((), jnp.int32),  # start token id 0
        rng,
    )
    _, (choices, logps, ents) = jax.lax.scan(step, carry, kinds)
    return choices.astype(jnp.int32), logps.sum(), ents.sum()


@functools.partial(jax.jit, static_argnames=("spec",))
def logprob_of(params: Dict, spec: ControllerSpec, tokens: jnp.ndarray):
    """Differentiable log-probability (+entropy) of a sampled sequence —
    the REINFORCE score function."""
    kinds = jnp.asarray(spec.kinds, jnp.int32)

    def step(carry, inp):
        h, c, prev_tok = carry
        kind, choice = inp
        x = params["embed"][prev_tok]
        h, c = _lstm_step(params, h, c, x)
        logits = _step_logits(params, spec, h, kind)
        logp = jax.nn.log_softmax(logits)[choice]
        probs = jax.nn.softmax(logits)
        entropy = -jnp.sum(probs * jnp.where(probs > 0, jnp.log(probs + 1e-12), 0.0))
        tok = spec.token_id(kind, choice)
        return (h, c, tok), (logp, entropy)

    carry = (
        jnp.zeros((HIDDEN,), jnp.float32),
        jnp.zeros((HIDDEN,), jnp.float32),
        jnp.zeros((), jnp.int32),
    )
    _, (logps, ents) = jax.lax.scan(step, carry, (kinds, tokens))
    return logps.sum(), ents.sum()
