"""Fault-tolerance tests: the deterministic injection harness, bounded
retry/backoff, health-driven replica failover, and degraded partial
execution (DESIGN.md §Fault tolerance).

Everything here replays identically run to run: faults fire by
matching-event index (a counter-seeded coin only when
``probability < 1``), backoff is computed rather than drawn, and health
scoring is pick-count driven.  CI runs this file under
``PYTHONHASHSEED=0`` (the ``chaos`` job)."""

import time

import numpy as np
import pytest

from conftest import make_periodic_table
from repro import obs
from repro.api import FederatedStore
from repro.api.routing import LazyFanoutPool
from repro.baselines import HashStore
from repro.cluster import ClusterConfig, ShardedDeepMappingStore
from repro.core import DeepMappingConfig
from repro.core.trainer import TrainConfig
from repro.fault import (
    FaultPlan,
    FaultSpec,
    HealthPolicy,
    HealthTracker,
    InjectedFault,
    OwnerFailure,
    RetryPolicy,
    call_guarded,
    injection,
)
from repro.serve import LookupServer

FAST = DeepMappingConfig(
    shared=(64,), private=(16,), train=TrainConfig(epochs=15, batch_size=512)
)

#: No backoff sleeps, two attempts — fault tests stay fast and exact.
TIGHT = RetryPolicy(max_attempts=2, backoff_s=0.0, max_backoff_s=0.0)


def counter_value(name, **labels):
    """Current value of one labelled counter series (0 if never hit)."""
    metric = obs.registry().get(name)
    return 0.0 if metric is None else metric.value(**labels)


# --------------------------------------------------------------- harness
class TestFaultSpec:
    def test_unknown_site_rejected(self):
        with pytest.raises(ValueError, match="unknown fault site"):
            FaultSpec(site="warp_core")

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultSpec(site="shard_collect", kind="explode")

    def test_probability_bounds(self):
        with pytest.raises(ValueError):
            FaultSpec(site="shard_collect", probability=1.5)


class TestFaultPlan:
    def test_inactive_plan_is_noop(self):
        injection.maybe_fail("shard_collect", "shard:0")  # no plan active
        assert injection.active() is None

    def test_times_window(self):
        plan = FaultPlan(
            [FaultSpec(site="shard_collect", kind="raise", times=2)]
        )
        with plan.activate():
            for _ in range(2):
                with pytest.raises(InjectedFault):
                    injection.maybe_fail("shard_collect", "shard:0")
            injection.maybe_fail("shard_collect", "shard:0")  # exhausted
        assert plan.fired == 2
        assert plan.fired_at("shard_collect") == 2
        assert [e.event_index for e in plan.events] == [0, 1]

    def test_after_window(self):
        plan = FaultPlan(
            [FaultSpec(site="member_collect", kind="raise", after=1, times=1)]
        )
        with plan.activate():
            injection.maybe_fail("member_collect", "member:0")  # idx 0 passes
            with pytest.raises(InjectedFault):
                injection.maybe_fail("member_collect", "member:0")

    def test_owner_filter(self):
        plan = FaultPlan(
            [FaultSpec(site="shard_collect", kind="raise", owner="shard:1")]
        )
        with plan.activate():
            injection.maybe_fail("shard_collect", "shard:0")
            with pytest.raises(InjectedFault):
                injection.maybe_fail("shard_collect", "shard:1")
        assert [e.owner for e in plan.events] == ["shard:1"]

    def test_delay_kind_returns(self):
        plan = FaultPlan(
            [FaultSpec(site="engine_dispatch", kind="delay", delay_s=0.0,
                       times=1)]
        )
        with plan.activate():
            injection.maybe_fail("engine_dispatch")  # sleeps 0s, no raise
        assert plan.fired == 1

    def test_probability_is_seed_deterministic(self):
        def run(seed):
            plan = FaultPlan(
                [FaultSpec(site="shard_collect", probability=0.5)], seed=seed
            )
            fired = []
            with plan.activate():
                for _ in range(40):
                    try:
                        injection.maybe_fail("shard_collect", "shard:0")
                        fired.append(False)
                    except InjectedFault:
                        fired.append(True)
            return fired

        assert run(7) == run(7)  # replays identically
        assert 0 < sum(run(7)) < 40  # the coin actually flips
        assert run(7) != run(8)  # and the seed matters

    def test_nesting_disallowed(self):
        plan = FaultPlan([FaultSpec(site="shard_collect")])
        other = FaultPlan([FaultSpec(site="shard_collect")])
        with plan.activate():
            with pytest.raises(RuntimeError, match="already active"):
                with other.activate():
                    pass
        assert injection.active() is None  # fully unwound

    def test_corrupt_flips_exactly_one_byte(self):
        data = bytes(range(64))
        plan = FaultPlan(
            [FaultSpec(site="artifact_read", kind="corrupt", times=1)]
        )
        with plan.activate():
            out = injection.corrupt("artifact_read", "vexist.bin", data)
            again = injection.corrupt("artifact_read", "vexist.bin", data)
        assert len(out) == len(data) and out != data
        assert sum(a != b for a, b in zip(out, data)) == 1
        assert again == data  # times=1 exhausted

    def test_corrupt_passes_empty_payload(self):
        plan = FaultPlan([FaultSpec(site="artifact_read", kind="corrupt")])
        with plan.activate():
            assert injection.corrupt("artifact_read", "meta", b"") == b""

    def test_fired_events_count_into_metrics(self):
        before = counter_value(
            "deepmap_fault_injected_total", site="shard_collect", kind="raise"
        )
        plan = FaultPlan([FaultSpec(site="shard_collect", times=3)])
        with plan.activate():
            for _ in range(3):
                with pytest.raises(InjectedFault):
                    injection.maybe_fail("shard_collect", "shard:0")
        after = counter_value(
            "deepmap_fault_injected_total", site="shard_collect", kind="raise"
        )
        assert after - before == 3 == plan.fired


# ---------------------------------------------------------------- retry
class TestCallGuarded:
    def test_success_first_try(self):
        out = call_guarded(
            lambda i: "ok", owner="o", site="shard_collect", policy=TIGHT
        )
        assert out.ok and out.value == "ok"
        assert out.retries == 0 and out.error is None

    def test_retry_then_success(self):
        before = counter_value(
            "deepmap_fault_retries_total", site="shard_collect"
        )

        def flaky(attempt):
            if attempt == 0:
                raise RuntimeError("transient")
            return attempt

        out = call_guarded(
            flaky, owner="o", site="shard_collect", policy=TIGHT
        )
        assert out.ok and out.value == 1 and out.retries == 1
        after = counter_value(
            "deepmap_fault_retries_total", site="shard_collect"
        )
        assert after - before == 1

    def test_terminal_failure_is_a_value(self):
        before = counter_value(
            "deepmap_fault_owner_errors_total",
            site="member_collect", cause="error",
        )

        def dead(attempt):
            raise KeyError("gone")

        out = call_guarded(
            dead, owner="member:2", site="member_collect", policy=TIGHT
        )
        assert not out.ok and out.value is None
        err = out.error
        assert err.owner == "member:2" and err.site == "member_collect"
        assert err.attempts == 2 and err.error_type == "KeyError"
        assert "member:2@member_collect" in err.describe()
        after = counter_value(
            "deepmap_fault_owner_errors_total",
            site="member_collect", cause="error",
        )
        assert after - before == 1

    def test_slow_owner_blows_deadline(self):
        policy = RetryPolicy(max_attempts=1, deadline_s=0.005)

        def slow(attempt):
            time.sleep(0.02)
            return "late"

        out = call_guarded(
            slow, owner="o", site="member_collect", policy=policy
        )
        assert not out.ok
        assert out.error.deadline_exceeded
        assert out.error.error_type == "DeadlineExceeded"

    def test_backoff_is_computed_not_drawn(self):
        policy = RetryPolicy(
            backoff_s=0.01, backoff_multiplier=2.0, max_backoff_s=0.03
        )
        assert policy.backoff(1) == pytest.approx(0.01)
        assert policy.backoff(2) == pytest.approx(0.02)
        assert policy.backoff(3) == pytest.approx(0.03)  # capped
        assert policy.backoff(9) == pytest.approx(0.03)

    def test_policy_validation(self):
        with pytest.raises(ValueError):
            RetryPolicy(max_attempts=0)
        with pytest.raises(ValueError):
            RetryPolicy(deadline_s=0.0)


# --------------------------------------------------------------- health
class TestHealthTracker:
    def test_quarantine_after_threshold(self):
        t = HealthTracker(HealthPolicy(fail_threshold=2))
        assert t.record_failure("m") is False  # 1 of 2
        assert t.record_failure("m") is True   # threshold crossed
        assert t.record_failure("m") is False  # already quarantined
        assert t.is_quarantined("m")

    def test_success_resets_streak(self):
        t = HealthTracker(HealthPolicy(fail_threshold=2))
        t.record_failure("m")
        t.record_success("m", 0.001)
        t.record_failure("m")
        assert not t.is_quarantined("m")  # streak broken, count restarted

    def test_pick_fails_over_past_quarantined(self):
        t = HealthTracker(HealthPolicy(fail_threshold=1, probe_every=100))
        owners = ("member:0", "member:1", "member:2")
        t.record_failure("member:0")
        assert t.pick(owners, 0) == 1
        assert t.healthy(owners) == ["member:1", "member:2"]

    def test_probe_routes_through_quarantined(self):
        t = HealthTracker(HealthPolicy(fail_threshold=1, probe_every=3))
        owners = ("member:0", "member:1")
        t.record_failure("member:0")
        picks = [t.pick(owners, 0) for _ in range(3)]
        assert picks == [1, 1, 0]  # every 3rd skip becomes a probe

    def test_successful_probe_recovers(self):
        t = HealthTracker(HealthPolicy(fail_threshold=1))
        t.record_failure("m")
        assert t.record_success("m", 0.001) is True  # recovered
        assert not t.is_quarantined("m")

    def test_all_quarantined_returns_preferred(self):
        t = HealthTracker(HealthPolicy(fail_threshold=1, probe_every=100))
        owners = ("a", "b")
        t.record_failure("a")
        t.record_failure("b")
        assert t.pick(owners, 1) == 1  # serve *something*; success recovers

    def test_latency_ewma_and_snapshot(self):
        t = HealthTracker(HealthPolicy(ewma_alpha=0.5))
        t.record_success("m", 0.1)
        t.record_success("m", 0.2)
        assert t.latency("m") == pytest.approx(0.15)
        snap = t.snapshot()
        assert snap["m"]["successes"] == 2
        assert snap["m"]["quarantined"] is False


# ------------------------------------------------- degraded cluster path
@pytest.fixture(scope="module")
def fault_cluster():
    table = make_periodic_table(n=1200)
    cluster = ShardedDeepMappingStore.build(
        table, FAST, ClusterConfig(num_shards=3, policy="range")
    )
    cluster.retry = TIGHT
    return table, cluster


class TestDegradedCluster:
    def test_raise_mode_surfaces_owner_failure(self, fault_cluster):
        table, cluster = fault_cluster
        plan = FaultPlan(
            [FaultSpec(site="shard_collect", owner="shard:1", kind="raise")]
        )
        with plan.activate():
            with pytest.raises(OwnerFailure) as exc_info:
                cluster.query().where_keys(table.keys).execute()
        assert "shard:1@shard_collect" in str(exc_info.value)
        assert exc_info.value.owners[0].attempts == 2  # retried once
        assert plan.fired == 2

    def test_partial_mode_serves_healthy_shards_byte_identical(
        self, fault_cluster
    ):
        table, cluster = fault_cluster
        q = table.keys
        ref_values, ref_exists = cluster.lookup(q)  # healthy reference
        sid = cluster.partitioner.shard_of(q)
        healthy = sid != 1

        plan = FaultPlan(
            [FaultSpec(site="shard_collect", owner="shard:1", kind="raise")]
        )
        with plan.activate():
            res = (
                cluster.query().where_keys(q).on_error("partial").execute()
            )

        # Healthy-shard rows are byte-identical to the fault-free run.
        np.testing.assert_array_equal(res.exists[healthy], ref_exists[healthy])
        for col in ref_values:
            np.testing.assert_array_equal(
                res.values[col][healthy], ref_values[col][healthy]
            )
        # Rows owned by the dead shard are unreachable, not absent.
        assert not res.exists[~healthy].any()
        assert res.explain.keys_unresolved == int((~healthy).sum())
        assert len(res.explain.owners_failed) == 1
        assert "shard:1@shard_collect" in res.explain.owners_failed[0]
        assert any(s.startswith("degraded[") for s in res.explain.plan)

    def test_transient_fault_retried_to_full_result(self, fault_cluster):
        table, cluster = fault_cluster
        ref_values, ref_exists = cluster.lookup(table.keys)
        plan = FaultPlan(
            [FaultSpec(site="shard_collect", owner="shard:1", kind="raise",
                       times=1)]
        )
        with plan.activate():
            res = (
                cluster.query()
                .where_keys(table.keys)
                .on_error("partial")
                .execute()
            )
        # One failure, retry succeeded: complete result, only evidence
        # of the retry remains.
        np.testing.assert_array_equal(res.exists, ref_exists)
        for col in ref_values:
            np.testing.assert_array_equal(res.values[col], ref_values[col])
        assert res.explain.owners_failed == ()
        assert res.explain.retries >= 1
        assert plan.fired == 1

    def test_injected_counter_matches_plan(self, fault_cluster):
        table, cluster = fault_cluster
        before = counter_value(
            "deepmap_fault_injected_total", site="shard_collect", kind="raise"
        )
        plan = FaultPlan(
            [FaultSpec(site="shard_collect", owner="shard:0", kind="raise")]
        )
        with plan.activate():
            cluster.query().where_keys(
                table.keys[:64]
            ).on_error("partial").execute()
        after = counter_value(
            "deepmap_fault_injected_total", site="shard_collect", kind="raise"
        )
        assert after - before == plan.fired > 0

    def test_on_error_validation(self, fault_cluster):
        _, cluster = fault_cluster
        with pytest.raises(ValueError, match="on_error"):
            cluster.query().where_keys([1]).on_error("ignore").plan()


# -------------------------------------------- degraded single-store path
class TestDegradedSingleStore:
    def test_engine_dispatch_fault_degrades_partial_query(self, small_store):
        table, store = small_store
        ref_values, ref_exists = store.lookup(table.keys[:128])
        plan = FaultPlan(
            [FaultSpec(site="engine_dispatch", kind="raise", times=1)]
        )
        with plan.activate():
            res = (
                store.query()
                .where_keys(table.keys[:128])
                .on_error("partial")
                .execute()
            )
        assert plan.fired == 1
        if res.explain.owners_failed:
            # The whole (single-owner) morsel degraded: typed
            # placeholders, nothing claimed to exist.
            assert not res.exists.any()
            assert res.explain.keys_unresolved == 128
            assert set(res.values) == set(ref_values)
            for col, arr in res.values.items():
                assert arr.dtype == ref_values[col].dtype
        else:
            # The executor retried/recovered — result must be complete.
            np.testing.assert_array_equal(res.exists, ref_exists)

    def test_server_on_error_passthrough(self, small_store):
        table, store = small_store
        srv = LookupServer(store, max_batch=512, on_error="partial")
        plan = FaultPlan(
            [FaultSpec(site="engine_dispatch", kind="raise", times=1)]
        )
        with plan.activate():
            values, exists = srv.lookup(table.keys[:32])
        assert plan.fired == 1
        assert exists.shape == (32,)
        assert set(values) == set(table.columns)


# -------------------------------------------------- replicate federation
def build_federation(table, mutation_policy="reject"):
    members = [
        HashStore.build(table, codec="none", partition_bytes=2048)
        for _ in range(3)
    ]
    return FederatedStore(
        members,
        mode="replicate",
        retry=TIGHT,
        health=HealthPolicy(fail_threshold=2, probe_every=4),
        mutation_policy=mutation_policy,
    )


def kill_member_zero():
    """A plan that fails every visit to member:0 at collect time."""
    return FaultPlan(
        [FaultSpec(site="member_collect", owner="member:0", kind="raise")]
    )


class TestReplicateFailover:
    def test_every_lookup_serves_through_failover(self):
        table = make_periodic_table(n=600)
        fed = build_federation(table)
        ref_values, ref_exists = fed.members[1].lookup(table.keys)
        before = counter_value("deepmap_fault_failovers_total", member=1)
        with kill_member_zero().activate() as plan:
            batches = np.array_split(table.keys, 6)
            for batch in batches:
                values, exists = fed.lookup(batch)
                sel = np.isin(table.keys, batch)
                np.testing.assert_array_equal(exists, ref_exists[sel])
                for col in ref_values:
                    np.testing.assert_array_equal(
                        values[col], ref_values[col][sel]
                    )
        # 100% of lookups served; the dead replica went to quarantine.
        assert plan.fired >= 2
        assert fed.health.is_quarantined("member:0")
        assert not fed.health.is_quarantined("member:1")
        after = counter_value("deepmap_fault_failovers_total", member=1)
        assert after - before >= 1

    def test_probe_recovers_member_after_fault_clears(self):
        table = make_periodic_table(n=400)
        fed = build_federation(table)
        with kill_member_zero().activate():
            for batch in np.array_split(table.keys, 4):
                fed.lookup(batch)
        assert fed.health.is_quarantined("member:0")
        # Faults stopped; within probe_every picks a probe routes
        # through member:0, succeeds, and recovers it.
        for _ in range(fed.health.policy.probe_every + 1):
            fed.lookup(table.keys[:16])
            if not fed.health.is_quarantined("member:0"):
                break
        assert not fed.health.is_quarantined("member:0")

    def test_all_replicas_down_raises_owner_failure(self):
        table = make_periodic_table(n=200)
        fed = build_federation(table)
        plan = FaultPlan([FaultSpec(site="member_collect", kind="raise")])
        with plan.activate():
            with pytest.raises(OwnerFailure) as exc_info:
                fed.lookup(table.keys[:16])
        assert len(exc_info.value.owners) == 3  # every replica reported

    def _quarantine_member_zero(self, fed, table):
        with kill_member_zero().activate():
            for batch in np.array_split(table.keys, 4):
                fed.lookup(batch)
        assert fed.health.is_quarantined("member:0")

    def test_mutation_reject_while_quarantined(self):
        table = make_periodic_table(n=400)
        fed = build_federation(table, mutation_policy="reject")
        self._quarantine_member_zero(fed, table)
        before = counter_value(
            "deepmap_fault_mutations_rejected_total", op="insert"
        )
        new_key = np.array([10**7], dtype=np.int64)
        cols = {c: np.zeros(1, dtype=v.dtype) for c, v in table.columns.items()}
        with pytest.raises(RuntimeError, match="member:0"):
            fed.insert(new_key, cols)
        # Nothing mutated anywhere — replicas cannot diverge.
        for m in fed.members:
            assert not m.lookup(new_key)[1].any()
        after = counter_value(
            "deepmap_fault_mutations_rejected_total", op="insert"
        )
        assert after - before == 1

    def test_mutation_queue_flushes_after_recovery(self):
        table = make_periodic_table(n=400)
        fed = build_federation(table, mutation_policy="queue")
        self._quarantine_member_zero(fed, table)
        new_key = np.array([10**7], dtype=np.int64)
        cols = {c: np.zeros(1, dtype=v.dtype) for c, v in table.columns.items()}
        fed.insert(new_key, cols)  # queued, not applied
        assert not fed.lookup(new_key)[1].any()
        assert fed.flush_mutations() == 0  # still quarantined
        # Recover member:0 (faults are gone; probes succeed).
        for _ in range(fed.health.policy.probe_every + 1):
            fed.lookup(table.keys[:8])
            if not fed.health.is_quarantined("member:0"):
                break
        assert fed.flush_mutations() == 1
        for m in fed.members:
            assert m.lookup(new_key)[1].all()  # applied everywhere


# ------------------------------------------------------- pool lifecycle
class TestPoolLifecycle:
    def test_close_is_idempotent_and_reentrant(self):
        pool = LazyFanoutPool(2, "test-pool")
        assert pool.map(lambda x: x * 2, [1, 2, 3], owners=3) == [2, 4, 6]
        pool.close()
        pool.close()  # idempotent
        # A later map lazily re-creates the workers.
        assert pool.map(lambda x: x + 1, [1], owners=1) == [2]
        pool.close()

    def test_context_manager_closes(self):
        with LazyFanoutPool(2, "test-pool") as pool:
            assert pool.map(lambda x: x, [7], owners=1) == [7]
        assert pool._pool is None

    def test_cluster_close_shuts_fanout_down(self, fault_cluster):
        table, cluster = fault_cluster
        cluster.lookup(table.keys[:32])  # may spin the pool up
        cluster.close()
        assert cluster._fanout._pool is None
        # The store stays usable: the pool re-creates lazily.
        _, exists = cluster.lookup(table.keys[:32])
        assert exists.all()

    def test_federation_context_manager(self):
        table = make_periodic_table(n=200)
        with build_federation(table) as fed:
            fed.lookup(table.keys[:16])
        assert fed._fanout._pool is None
