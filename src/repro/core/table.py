"""Tabular container used throughout the DeepMapping stack.

A :class:`Table` is a single-relation, single-key mapping
``R(K, V_1..V_m)`` (paper §III): one integer key column plus ``m``
discrete value columns.  Composite keys are packed into one int64 by the
caller (``pack_composite_key``) — the paper's key "can consist of any
attribute" and does not need to be a unique identifier *per attribute*,
but the packed key must uniquely identify a row.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import numpy as np


@dataclasses.dataclass
class Table:
    """Column-major tabular data: one int64 key column + value columns.

    ``columns`` values may be any 1-D numpy array of discrete data
    (integers, bytes, numpy strings).  Rows are aligned positionally
    with ``keys``; ``keys`` need not be sorted or dense.
    """

    keys: np.ndarray
    columns: Dict[str, np.ndarray]

    def __post_init__(self) -> None:
        self.keys = np.asarray(self.keys, dtype=np.int64)
        if self.keys.ndim != 1:
            raise ValueError(f"keys must be 1-D, got shape {self.keys.shape}")
        if np.any(self.keys < 0):
            raise ValueError("keys must be non-negative")
        for name, col in self.columns.items():
            col = np.asarray(col)
            if col.shape != self.keys.shape:
                raise ValueError(
                    f"column {name!r} shape {col.shape} != keys {self.keys.shape}"
                )
            self.columns[name] = col
        if len(np.unique(self.keys)) != len(self.keys):
            raise ValueError("keys must uniquely identify rows")

    # -- basic accessors ---------------------------------------------------
    @property
    def num_rows(self) -> int:
        return int(self.keys.shape[0])

    @property
    def value_names(self) -> Sequence[str]:
        return list(self.columns.keys())

    @property
    def max_key(self) -> int:
        return int(self.keys.max()) if self.num_rows else 0

    def sorted_by_key(self) -> "Table":
        order = np.argsort(self.keys, kind="stable")
        return Table(
            keys=self.keys[order],
            columns={k: v[order] for k, v in self.columns.items()},
        )

    def row(self, i: int) -> Dict[str, object]:
        return {k: v[i] for k, v in self.columns.items()}

    def raw_size_bytes(self) -> int:
        """Uncompressed size — the denominator of the paper's Eq. 1."""
        total = self.keys.nbytes
        for col in self.columns.values():
            if col.dtype == object:
                total += int(sum(len(x) for x in col))
            else:
                total += col.nbytes
        return total

    def take(self, idx: np.ndarray) -> "Table":
        return Table(
            keys=self.keys[idx],
            columns={k: v[idx] for k, v in self.columns.items()},
        )

    def concat(self, other: "Table") -> "Table":
        if set(other.columns) != set(self.columns):
            raise ValueError("column mismatch in concat")
        return Table(
            keys=np.concatenate([self.keys, other.keys]),
            columns={
                k: np.concatenate([self.columns[k], other.columns[k]])
                for k in self.columns
            },
        )


def pack_composite_key(parts: Sequence[np.ndarray]) -> np.ndarray:
    """Pack several non-negative integer key attributes into one int64.

    Uses mixed-radix packing with per-attribute radix ``max+1``.  Raises
    if the packed domain would overflow int64 — at that point the caller
    should hash or re-map the key domain instead.
    """
    parts = [np.asarray(p, dtype=np.int64) for p in parts]
    if not parts:
        raise ValueError("need at least one key attribute")
    radices = [int(p.max()) + 1 for p in parts]
    total_bits = float(np.sum(np.log2(np.maximum(radices, 2))))
    if total_bits > 62:
        raise ValueError(
            f"composite key domain needs {total_bits:.1f} bits > 62; "
            "re-map key attributes first"
        )
    packed = np.zeros_like(parts[0])
    for p, r in zip(parts, radices):
        if np.any(p < 0):
            raise ValueError("key attributes must be non-negative")
        packed = packed * r + p
    return packed
