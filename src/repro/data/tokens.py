"""DeepMapping-compressed token store — the paper's technique as a
first-class feature of the LM data pipeline (DESIGN.md §4).

A tokenized corpus is exactly a ``position -> token_id`` categorical
mapping.  The store compresses it as a DeepMapping hybrid structure and
the training loader materializes batches by BATCHED NN INFERENCE +
T_aux correction — losslessly, with the same Algorithm-1 path the paper
uses for tabular lookups.  Token streams with local structure (runs,
templates, repeated spans) compress well; worst-case random tokens
degrade gracefully to T_aux ≈ zstd(data)."""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.hybrid import DeepMappingConfig, DeepMappingStore
from repro.core.table import Table
from repro.core.trainer import TrainConfig


class DeepMappingTokenStore:
    """Lossless learned store for one token stream."""

    def __init__(self, store: DeepMappingStore, num_tokens: int):
        self._store = store
        self.num_tokens = int(num_tokens)

    @classmethod
    def build(
        cls,
        tokens: np.ndarray,
        config: Optional[DeepMappingConfig] = None,
        verbose: bool = False,
    ) -> "DeepMappingTokenStore":
        tokens = np.asarray(tokens)
        if tokens.ndim != 1:
            raise ValueError("tokens must be a flat stream")
        table = Table(
            keys=np.arange(tokens.shape[0], dtype=np.int64),
            columns={"token": tokens.astype(np.int32)},
        )
        cfg = config or DeepMappingConfig(
            shared=(256, 256),
            private=(64,),
            train=TrainConfig(epochs=60, batch_size=8192),
        )
        store = DeepMappingStore.build(table, cfg, verbose=verbose)
        return cls(store, tokens.shape[0])

    def get(self, positions: np.ndarray) -> np.ndarray:
        vals, exists = self._store.lookup(np.asarray(positions, dtype=np.int64))
        if not bool(exists.all()):
            raise KeyError("token positions must exist in the backing store")
        return vals["token"]

    def get_batch(self, starts: np.ndarray, seq_len: int) -> np.ndarray:
        """(batch,) window starts -> (batch, seq_len) token block."""
        starts = np.asarray(starts, dtype=np.int64)
        pos = starts[:, None] + np.arange(seq_len, dtype=np.int64)[None, :]
        flat = self.get(pos.reshape(-1))
        return flat.reshape(starts.shape[0], seq_len).astype(np.int32)

    # -- accounting --------------------------------------------------------
    def compression_ratio(self) -> float:
        return self._store.compression_ratio()

    def size_bytes(self) -> int:
        return self._store.size_bytes()

    def memorized_fraction(self) -> float:
        return self._store.memorized_fraction()


def make_structured_tokens(n: int, vocab: int, run_len: int = 8, seed: int = 0) -> np.ndarray:
    """Synthetic corpus with template structure (repeated n-gram runs) —
    the regime where learned mapping compression wins."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, vocab, size=max(2, n // run_len), dtype=np.int32)
    toks = np.repeat(base, run_len)[:n]
    flip = rng.random(n) < 0.02
    toks[flip] = rng.integers(0, vocab, size=int(flip.sum()))
    return toks
