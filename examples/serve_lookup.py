"""End-to-end serving driver (the paper's deployment): build a
DeepMapping store, stand up the batched LookupServer, and push mixed
batched request traffic through it — the paper-kind analogue of
"serve a small model with batched requests".

The server rides the streaming query executor: merged batches become
morselized point plans, so projection pushdown (only the requested
column's model head runs), value-predicate pushdown (``.where``), and
— with ``--shards`` — the sharded thread-pool fan-out apply to served
traffic too.  ``--replica`` federates the DeepMapping primary with a
HashStore replica (round-robin morsel routing) and serves through the
federation.

    PYTHONPATH=src python examples/serve_lookup.py
    PYTHONPATH=src python examples/serve_lookup.py --shards 4 --replica
"""

import argparse

import numpy as np

import repro
from repro.api import FederatedStore
from repro.baselines import HashStore
from repro.core import DeepMappingConfig
from repro.core.trainer import TrainConfig
from repro.data import customer_demographics_like
from repro.serve import LookupServer


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--replica", action="store_true",
                    help="serve through a DM-primary + HashStore-replica "
                         "federation (round-robin morsel routing)")
    args = ap.parse_args()

    table = customer_demographics_like(n=50_000)
    cluster = None
    if args.shards > 1:
        from repro.cluster import ClusterConfig

        cluster = ClusterConfig(num_shards=args.shards)
    store = repro.build(
        table,
        DeepMappingConfig(
            shared=(128, 64), private=(16,), residues=(2, 5, 7),
            train=TrainConfig(epochs=30, batch_size=8192),
        ),
        cluster=cluster,
        verbose=True,
    )
    if args.replica:
        store = FederatedStore(
            [store, HashStore.build(table)],
            mode="replicate",
            policy="round_robin",
        )
        print(f"federated: {len(store.members)} replicas, round-robin routing")
    server = LookupServer(store, max_batch=16384)

    rng = np.random.default_rng(0)
    # 40 concurrent requests of mixed sizes, some probing missing keys.
    requests = []
    for i in range(40):
        size = int(rng.integers(50, 2000))
        ks = rng.choice(table.keys, size=size)
        if i % 5 == 0:
            ks = np.concatenate([ks, table.max_key + rng.integers(1, 100, 10)])
        requests.append(ks)

    results = server.lookup_many(requests, columns=("cd_education_status",))
    hits = sum(int(e.sum()) for _, e in results)
    total = sum(len(r) for r in requests)
    print(f"\nserved {len(requests)} requests, {total:,} keys, {hits:,} hits")
    s = server.stats
    print(f"throughput: {s.qps():,.0f} keys/s "
          f"(infer {s.infer_s:.3f}s, exist {s.exist_s:.3f}s, "
          f"aux {s.aux_s:.3f}s, decode {s.decode_s:.3f}s, "
          f"batches {s.batches})")

    # the same traffic, expressed as one explicit plan
    res = (
        store.query()
        .select("cd_education_status")
        .where_keys(np.unique(np.concatenate(requests)))
        .execute()
    )
    print(f"plan: {' -> '.join(res.explain.plan)}")
    print(f"pushdown: heads skipped = {res.explain.heads_skipped}")

    # value-predicate pushdown: filter on a column the projection
    # doesn't even return — its head is evaluated at code level, and
    # non-matching rows are never decoded.  Query the DM store
    # directly: round-robin federation routing could hand the morsel
    # to the hash replica, whose overlay-view filter decodes all rows.
    dm = store.members[0] if args.replica else store
    res = (
        dm.query()
        .select("cd_purchase_estimate")
        .where("cd_dep_count", ">=", 4)
        .where_keys(np.unique(np.concatenate(requests)))
        .execute()
    )
    print(f"where(cd_dep_count>=4): {res.keys.shape[0]} rows; "
          f"decoded {res.explain.rows_decoded}/{res.explain.num_keys} rows "
          f"(predicate head evaluated: "
          f"{'cd_dep_count' in res.explain.heads_evaluated})")

    # spot-check correctness against the source table
    req0, (vals0, e0) = requests[0], results[0]
    lut = dict(zip(table.keys.tolist(), table.columns["cd_education_status"]))
    for k, v, ex in zip(req0.tolist(), vals0["cd_education_status"], e0):
        if ex:
            assert lut[k] == v, (k, v, lut[k])
    print("correctness spot-check passed")


if __name__ == "__main__":
    main()
