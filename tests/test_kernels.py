"""Per-kernel validation: shape sweeps against the pure-jnp oracles in
``repro.kernels.ref`` (interpret=True on CPU), plus hypothesis property
tests on the packing/padding invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests only — the oracle conformance suite runs without it
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAS_HYPOTHESIS = False

from repro.core.bitvector import BitVector
from repro.core.encoding import KeyEncoder
from repro.core.inference import InferenceEngine
from repro.core.model import MLPSpec, init_params
from repro.kernels import bitvector_test, fused_mlp_codes, fused_mlp_logits
from repro.kernels.ops import check_vmem_budget
from repro.kernels.ref import (
    ref_bitvector_test,
    ref_fused_lookup,
    ref_fused_mlp_codes,
    ref_fused_mlp_logits,
)


def make_model(shared, private, cards, base=10, width=5, seed=0):
    spec = MLPSpec(
        base=base,
        width=width,
        shared=shared,
        private={f"t{i}": private for i in range(len(cards))},
        out_cards={f"t{i}": c for i, c in enumerate(cards)},
    )
    return spec, init_params(spec, seed=seed)


SHAPE_SWEEP = [
    # (shared, private, cards, base, n)
    ((64, 32), (16,), (7,), 10, 300),
    ((48,), (), (5, 3), 10, 64),
    ((), (24,), (9,), 10, 257),       # no shared trunk: head-first embed
    ((), (), (4,), 10, 128),          # degenerate: input -> logits
    ((32, 32), (16, 8), (300,), 10, 100),  # card > 256
    ((16,), (8,), (3, 5, 7), 2, 500),  # binary digit base
    ((128,), (64,), (11,), 16, 1000),  # hex base, larger batch
]


class TestFusedMLP:
    @pytest.mark.parametrize("shared,private,cards,base,n", SHAPE_SWEEP)
    def test_logits_match_oracle(self, shared, private, cards, base, n):
        spec, params = make_model(shared, private, cards, base=base)
        rng = np.random.default_rng(42)
        digits = jnp.asarray(
            rng.integers(0, base, size=(n, spec.width)).astype(np.int32)
        )
        got = fused_mlp_logits(params, spec, digits)
        want = ref_fused_mlp_logits(params, digits, spec)
        for t in spec.tasks:
            assert got[t].shape == want[t].shape
            np.testing.assert_allclose(got[t], want[t], rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("shared,private,cards,base,n", SHAPE_SWEEP)
    def test_codes_match_oracle(self, shared, private, cards, base, n):
        spec, params = make_model(shared, private, cards, base=base)
        rng = np.random.default_rng(7)
        digits = jnp.asarray(
            rng.integers(0, base, size=(n, spec.width)).astype(np.int32)
        )
        got = fused_mlp_codes(params, spec, digits)
        want = ref_fused_mlp_codes(params, digits, spec)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("tile_n", [8, 64, 256])
    def test_tile_size_invariance(self, tile_n):
        spec, params = make_model((32,), (16,), (6,))
        digits = jnp.asarray(
            np.random.default_rng(0).integers(0, 10, (100, 5)).astype(np.int32)
        )
        a = fused_mlp_codes(params, spec, digits, tile_n=tile_n)
        b = ref_fused_mlp_codes(params, digits, spec)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_batch_not_multiple_of_tile(self):
        spec, params = make_model((16,), (), (3,))
        digits = jnp.asarray(
            np.random.default_rng(1).integers(0, 10, (77, 5)).astype(np.int32)
        )
        got = fused_mlp_codes(params, spec, digits, tile_n=64)
        assert got.shape == (77, 1)

    def test_vmem_budget_rejects_oversized(self):
        spec, params = make_model((2048, 2048), (2048,), (1000,))
        with pytest.raises(ValueError, match="VMEM"):
            check_vmem_budget(params, spec, tile_n=256)

    def test_store_with_pallas_path_lossless(self):
        """End-to-end: hybrid store built AND queried via the kernel."""
        from conftest import make_periodic_table
        from repro.core import DeepMappingConfig, DeepMappingStore
        from repro.core.trainer import TrainConfig

        table = make_periodic_table(n=500)
        cfg = DeepMappingConfig(
            shared=(48,),
            private=(16,),
            train=TrainConfig(epochs=10, batch_size=256),
            use_pallas=True,
        )
        store = DeepMappingStore.build(table, cfg)
        vals, exists = store.lookup(table.keys)
        assert exists.all()
        for c, col in table.columns.items():
            np.testing.assert_array_equal(vals[c], col)


def make_lookup_setup(max_key=9999, residues=(), tasks=2, seed=3):
    """Encoder + model + bitvector triple for key-level conformance."""
    enc = KeyEncoder(max_key, base=10, residues=residues)
    cards = tuple(3 + 2 * i for i in range(tasks))
    spec = MLPSpec(
        base=10,
        width=enc.width,
        shared=(32,),
        private={f"t{i}": (16,) for i in range(tasks)},
        out_cards={f"t{i}": c for i, c in enumerate(cards)},
    )
    params = init_params(spec, seed=seed)
    rng = np.random.default_rng(seed)
    present = rng.choice(max_key + 1, size=(max_key + 1) // 3, replace=False)
    bv = BitVector.from_keys(present)
    return enc, spec, params, bv


class TestFusedLookupConformance:
    """The ISSUE-3 acceptance bar: the fused key-encode+exist kernel
    (and every engine fallback path) must be byte-identical to the
    reference staged path — host digits + jnp forward + host
    BitVector.test — on every conformance case.  Runs in interpret
    mode on CPU CI (the ops wrapper auto-selects it off-TPU)."""

    TILE = 64

    def _engine(self, enc, spec, params, bv, use_pallas):
        return InferenceEngine(
            enc, spec, params, bv, use_pallas=use_pallas, tile_n=self.TILE
        )

    def _assert_matches(self, eng, enc, spec, params, bv, keys, tasks=None):
        t = eng.dispatch(keys, tasks=tasks, want_exists=True)
        codes, exists = eng.collect(t)
        if exists is None:  # non-fused paths: host existence fallback
            exists = bv.test(keys)
        else:
            assert t.path == "fused"  # only the kernel returns exist bits
        ref_codes, ref_exists = ref_fused_lookup(params, keys, enc, bv, spec)
        if tasks is not None:
            cols = [spec.tasks.index(x) for x in tasks]
            ref_codes = ref_codes[:, cols]
        np.testing.assert_array_equal(codes, ref_codes)
        np.testing.assert_array_equal(exists, ref_exists)
        return t.path

    @pytest.mark.parametrize("use_pallas", [True, False])
    @pytest.mark.parametrize(
        "n", [1, 63, 64, 65, 127, 128, 129, 500]
    )  # tile_n-1 / tile_n / bucket+1 boundaries for TILE=64
    def test_bucket_boundaries(self, use_pallas, n):
        enc, spec, params, bv = make_lookup_setup()
        eng = self._engine(enc, spec, params, bv, use_pallas)
        keys = np.random.default_rng(n).integers(0, 10000, size=n).astype(np.int64)
        path = self._assert_matches(eng, enc, spec, params, bv, keys)
        assert path == ("fused" if use_pallas else "jit_keys")

    @pytest.mark.parametrize("use_pallas", [True, False])
    def test_keys_beyond_encoder_capacity(self, use_pallas):
        enc, spec, params, bv = make_lookup_setup()
        eng = self._engine(enc, spec, params, bv, use_pallas)
        keys = np.array(
            [0, 1, 9999, 10000, 10001, 123456, 2**31 - 1, 2**31, 2**40, -1, -7],
            dtype=np.int64,
        )
        self._assert_matches(eng, enc, spec, params, bv, keys)

    @pytest.mark.parametrize("use_pallas", [True, False])
    @pytest.mark.parametrize("residues", [(7,), (5, 12)])
    def test_residue_encoders(self, use_pallas, residues):
        enc, spec, params, bv = make_lookup_setup(residues=residues)
        eng = self._engine(enc, spec, params, bv, use_pallas)
        keys = np.random.default_rng(1).integers(0, 10000, size=300).astype(np.int64)
        self._assert_matches(eng, enc, spec, params, bv, keys)

    @pytest.mark.parametrize("use_pallas", [True, False])
    @pytest.mark.parametrize("subset", [("t0",), ("t1",), ("t1", "t0")])
    def test_projection_pushdown_subsets(self, use_pallas, subset):
        enc, spec, params, bv = make_lookup_setup(tasks=2)
        eng = self._engine(enc, spec, params, bv, use_pallas)
        keys = np.random.default_rng(2).integers(0, 10000, size=200).astype(np.int64)
        self._assert_matches(eng, enc, spec, params, bv, keys, tasks=subset)

    def test_exists_tracks_bitvector_mutations(self):
        """Fused existence bits must follow set/clear (device word
        re-upload keyed by the bitvector's version counter)."""
        enc, spec, params, bv = make_lookup_setup()
        eng = self._engine(enc, spec, params, bv, use_pallas=True)
        keys = np.arange(0, 128, dtype=np.int64)
        self._assert_matches(eng, enc, spec, params, bv, keys)
        bv.set(np.array([2, 4, 6]), True)
        bv.set(np.array([1, 3]), False)
        self._assert_matches(eng, enc, spec, params, bv, keys)
        # growth beyond the old word array reshapes the kernel input
        bv.set(np.array([50_000]), True)
        self._assert_matches(eng, enc, spec, params, bv,
                             np.array([49_999, 50_000, 50_001], dtype=np.int64))

    def test_bucketed_compile_count(self):
        """50 distinct batch sizes must compile O(log N) programs."""
        enc, spec, params, bv = make_lookup_setup()
        eng = InferenceEngine(enc, spec, params, bv, use_pallas=False, tile_n=256)
        rng = np.random.default_rng(0)
        sizes = rng.choice(np.arange(1, 16384), size=50, replace=False)
        for n in sizes:
            eng.infer(rng.integers(0, 10000, size=int(n)).astype(np.int64))
        assert eng.stats.compiles <= 8, eng.stats.compiles

    def test_weight_cache_reused_across_calls(self):
        enc, spec, params, bv = make_lookup_setup()
        eng = self._engine(enc, spec, params, bv, use_pallas=True)
        keys = np.arange(200, dtype=np.int64)
        for _ in range(4):
            eng.collect(eng.dispatch(keys, want_exists=True))
        assert eng.stats.weight_cache_misses == 1
        assert eng.stats.dispatches == 4


class TestBitvectorKernel:
    @pytest.mark.parametrize("capacity", [64, 100, 1000, 65536])
    def test_matches_host_bitvector(self, capacity):
        rng = np.random.default_rng(capacity)
        keys = rng.choice(capacity, size=capacity // 3, replace=False)
        bv = BitVector.from_keys(keys, capacity=capacity)
        q = rng.integers(0, capacity, size=500).astype(np.int64)
        got = bitvector_test(bv.words, jnp.asarray(q))
        np.testing.assert_array_equal(np.asarray(got), bv.test(q))

    def test_oracle_agrees_with_kernel(self):
        rng = np.random.default_rng(3)
        keys = rng.choice(4096, size=1000, replace=False)
        bv = BitVector.from_keys(keys, capacity=4096)
        words32 = jnp.asarray(bv.words.view(np.uint32))
        q = jnp.asarray(rng.integers(0, 4096, size=256).astype(np.int32))
        ref = ref_bitvector_test(words32, q)
        got = bitvector_test(bv.words, q).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


if HAS_HYPOTHESIS:

    class TestKernelProperties:
        @settings(max_examples=25, deadline=None)
        @given(
            keys=st.lists(st.integers(0, 99999), min_size=1, max_size=64, unique=True),
            probe=st.lists(st.integers(0, 99999), min_size=1, max_size=64),
        )
        def test_bitvector_membership_property(self, keys, probe):
            bv = BitVector.from_keys(np.array(keys), capacity=100000)
            got = np.asarray(bitvector_test(bv.words, jnp.asarray(np.array(probe))))
            want = np.isin(np.array(probe), np.array(keys))
            np.testing.assert_array_equal(got, want)

        @settings(max_examples=10, deadline=None)
        @given(
            n=st.integers(1, 80),
            base=st.sampled_from([2, 10, 16]),
            card=st.integers(2, 40),
            seed=st.integers(0, 2**16),
        )
        def test_fused_codes_in_range(self, n, base, card, seed):
            spec, params = make_model((16,), (), (card,), base=base, seed=seed)
            rng = np.random.default_rng(seed)
            digits = jnp.asarray(rng.integers(0, base, (n, 5)).astype(np.int32))
            codes = np.asarray(fused_mlp_codes(params, spec, digits))
            assert codes.shape == (n, 1)
            assert (codes >= 0).all() and (codes < card).all()

        @settings(max_examples=10, deadline=None)
        @given(seed=st.integers(0, 2**16))
        def test_padding_is_exact(self, seed):
            """Zero-padding to MXU alignment must not change any logit."""
            spec, params = make_model((40,), (24,), (13,), seed=seed)
            rng = np.random.default_rng(seed)
            digits = jnp.asarray(rng.integers(0, 10, (33, 5)).astype(np.int32))
            got = fused_mlp_logits(params, spec, digits)
            want = ref_fused_mlp_logits(params, digits, spec)
            np.testing.assert_allclose(got["t0"], want["t0"], rtol=1e-5, atol=1e-5)
