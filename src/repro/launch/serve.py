"""Serving launcher: build (or load) a DeepMapping store and run the
batched LookupServer over synthetic request traffic.

    PYTHONPATH=src python -m repro.launch.serve --dataset tpcds_customer_demographics \
        --requests 100 --store-dir /tmp/dm_store
"""

from __future__ import annotations

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="tpcds_customer_demographics")
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--request-size", type=int, default=1000)
    ap.add_argument("--store-dir", default=None)
    ap.add_argument("--variant", default="DM-Z", choices=["DM-Z", "DM-L", "DM-R"])
    args = ap.parse_args()

    import numpy as np

    from benchmarks import common as C
    from repro.core.serialize import load_store, save_store
    from repro.serve import LookupServer

    table = C.DATASETS[args.dataset]()
    if args.store_dir and os.path.isdir(args.store_dir):
        store = load_store(args.store_dir)
        print(f"loaded store from {args.store_dir}")
    else:
        store = C.dm_store(args.dataset, args.variant)
        if args.store_dir:
            save_store(store, args.store_dir)
    print(
        f"store: ratio={store.compression_ratio():.4f} "
        f"memorized={store.memorized_fraction():.1%} "
        f"bytes={store.size_bytes():,}"
    )

    server = LookupServer(store)
    rng = np.random.default_rng(0)
    reqs = [rng.choice(table.keys, size=args.request_size) for _ in range(args.requests)]
    results = server.lookup_many(reqs)
    ok = sum(int(e.all()) for _, e in results)
    s = server.stats
    print(
        f"served {s.requests} requests ({s.keys:,} keys) in {s.total_s:.2f}s "
        f"-> {s.qps():,.0f} keys/s; all-found={ok}/{len(reqs)}; "
        f"infer={s.infer_s:.2f}s exist={s.exist_s:.2f}s "
        f"aux={s.aux_s:.2f}s decode={s.decode_s:.2f}s"
    )


if __name__ == "__main__":
    main()
