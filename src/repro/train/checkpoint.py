"""Sharded, atomic, keep-k checkpointing with resharding restore.

Layout::

    ckpt_dir/
      step_000100/
        manifest.json        — tree structure, shapes, dtypes, step
        arrays.npz           — flattened path -> host array
      step_000200/ ...
      LATEST                 — last durable step (written after rename)

Writes go to ``<dir>.tmp`` then ``os.rename`` (atomic on POSIX), so a
crash mid-write never corrupts the latest durable checkpoint — the
restart path (``restore_latest``) only ever sees complete directories.
``AsyncCheckpointer`` snapshots to host memory synchronously (cheap)
and serializes on a background thread so the train loop never blocks
on disk.  Restore accepts a target sharding tree: arrays are
``device_put`` against it, which implements ELASTIC REMESH — a
checkpoint from a 512-chip mesh restores onto any other mesh.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(
    ckpt_dir: str, step: int, state, keep: int = 3
) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    final = os.path.join(ckpt_dir, name)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    treedef = jax.tree_util.tree_structure(state)
    manifest = {
        "step": step,
        "format": 1,
        "treedef": str(treedef),
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(name)
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"), os.path.join(ckpt_dir, "LATEST"))
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )


def restore_checkpoint(
    ckpt_dir: str,
    step: int,
    like,
    shardings=None,
):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` (same structure) reshards every
    leaf via device_put — elastic remesh on restore."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}

    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out_leaves = []
    for kpath, leaf in leaves_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in kpath)
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        out_leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out_leaves
    )
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings,
            is_leaf=lambda x: isinstance(x, np.ndarray),
        )
    return tree


def restore_latest(ckpt_dir: str, like, shardings=None) -> Tuple[Optional[int], Any]:
    latest = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(latest):
        return None, None
    with open(latest) as f:
        name = f.read().strip()
    step = int(name.split("_")[1])
    return step, restore_checkpoint(ckpt_dir, step, like, shardings)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write-to-disk on a worker thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, state) -> None:
        self.wait()
        host_state = jax.tree.map(np.asarray, state)  # device->host snapshot

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_state, keep=self.keep)
            except BaseException as e:  # noqa: BLE001 — surfaced on wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
