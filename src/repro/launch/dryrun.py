import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run driver (assignment deliverable e).

For every (architecture × input shape × mesh) cell: build shardings,
``jax.jit(step).lower(...).compile()`` against the 16x16 single-pod and
2x16x16 multi-pod virtual meshes, record ``memory_analysis()`` /
``cost_analysis()`` / per-device collective bytes parsed from the
compiled HLO, and append to ``results/dryrun.jsonl`` (idempotent: cells
already present are skipped unless --force).

Run as a module so the XLA device-count pin above precedes any jax
import:  ``PYTHONPATH=src python -m repro.launch.dryrun --arch all``.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import SHAPES, get_arch, list_archs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import specs as specs_lib  # noqa: E402
from repro.serve.serve_step import make_decode_step, make_prefill_step  # noqa: E402
from repro.sharding.partition import (  # noqa: E402
    batch_shardings,
    cache_shardings,
    logits_sharding,
    param_shardings,
    state_shardings,
)
from repro.train.optimizer import adamw  # noqa: E402
from repro.train.train_step import make_train_step  # noqa: E402

_COLLECTIVE_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective traffic by op type, from post-SPMD HLO.

    Result shapes in the partitioned module are per-device shards; the
    ring all-reduce moves ~2x its buffer, others ~1x.
    """
    seen_starts = set()
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_txt, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_txt)
        out[op] += b * (2 if op == "all-reduce" else 1)
    return out


def run_cell(
    arch_id: str, shape_id: str, multi_pod: bool, microbatches: int = 1,
    unroll: bool = False, num_layers: int | None = None,
    overrides: dict | None = None,
) -> dict:
    import dataclasses

    spec = get_arch(arch_id)
    cfg = spec.config
    if unroll:
        cfg = dataclasses.replace(cfg, scan_unroll=True)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    restore_spec = None
    if num_layers is not None:
        cfg = dataclasses.replace(cfg, num_layers=num_layers)
    if num_layers is not None or overrides:
        # patch the registry view so specs/steps see the modified config
        # (param shapes can change, e.g. vocab padding); restored below.
        import repro.configs.base as _base

        restore_spec = _base._REGISTRY[arch_id]
        _base._REGISTRY[arch_id] = dataclasses.replace(spec, config=cfg)
    sh = SHAPES[shape_id]
    kind = sh["kind"]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {
        "arch": arch_id, "shape": shape_id,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(np.prod(list(mesh.shape.values()))),
        "kind": kind, "unrolled": bool(unroll),
    }
    if num_layers is not None:
        rec["num_layers"] = num_layers
    t0 = time.time()
    try:
        rec.update(_lower_and_measure(arch_id, shape_id, cfg, sh, kind, mesh,
                                      microbatches, t0))
    finally:
        if restore_spec is not None:
            import repro.configs.base as _base

            _base._REGISTRY[arch_id] = restore_spec
    return rec


def _lower_and_measure(arch_id, shape_id, cfg, sh, kind, mesh, microbatches, t0) -> dict:
    rec: dict = {}
    with mesh:
        if kind == "train":
            opt = adamw(lr=3e-4, max_grad_norm=1.0)
            state_shapes = specs_lib.state_specs(arch_id, opt)
            state_sh = state_shardings(cfg, mesh, state_shapes)
            batch_shapes = specs_lib.input_specs(arch_id, shape_id)
            batch_sh = batch_shardings(cfg, mesh, batch_shapes)
            step = make_train_step(cfg, opt, microbatches=microbatches)
            lowered = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state_shapes, batch_shapes)
        elif kind == "prefill":
            params_shapes = specs_lib.params_specs(arch_id)
            params_sh = param_shardings(cfg, mesh, params_shapes)
            batch_shapes = specs_lib.input_specs(arch_id, shape_id)
            batch_sh = batch_shardings(cfg, mesh, batch_shapes)
            step = make_prefill_step(cfg)
            out_sh = logits_sharding(cfg, mesh, sh["global_batch"])
            lowered = jax.jit(
                step, in_shardings=(params_sh, batch_sh), out_shardings=out_sh
            ).lower(params_shapes, batch_shapes)
        else:  # decode
            params_shapes = specs_lib.params_specs(arch_id)
            params_sh = param_shardings(cfg, mesh, params_shapes)
            cache_shapes = specs_lib.cache_specs(arch_id, shape_id)
            cache_sh = cache_shardings(cfg, mesh, cache_shapes)
            tok_shapes = specs_lib.input_specs(arch_id, shape_id)["tokens"]
            tok_sh = batch_shardings(cfg, mesh, {"tokens": tok_shapes})["tokens"]
            step = make_decode_step(cfg)
            out_sh = (logits_sharding(cfg, mesh, sh["global_batch"]), cache_sh)
            lowered = jax.jit(
                step,
                in_shardings=(params_sh, cache_sh, tok_sh),
                out_shardings=out_sh,
                donate_argnums=(1,),
            ).lower(params_shapes, cache_shapes, tok_shapes)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        ca = compiled.cost_analysis() or {}
        rec["flops_per_device"] = float(ca.get("flops", 0.0))
        rec["bytes_accessed_per_device"] = float(ca.get("bytes accessed", 0.0))
        rec["transcendentals"] = float(ca.get("transcendentals", 0.0))

        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                v = getattr(ma, k, None)
                if v is not None:
                    rec[k] = int(v)

        hlo = compiled.as_text()
        rec["hlo_bytes"] = len(hlo)
        coll = collective_bytes(hlo)
        rec["collective_bytes"] = coll
        rec["collective_bytes_total"] = int(sum(coll.values()))
    return rec


_LINEAR_KEYS = (
    "flops_per_device", "bytes_accessed_per_device", "transcendentals",
    "argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes",
    "collective_bytes_total",
)


def run_cell_extrapolated(
    arch_id: str, shape_id: str, multi_pod: bool, L1: int, L2: int,
    overrides: dict | None = None,
) -> dict:
    """Loop-accurate metrics for archs whose fully-unrolled compile is
    prohibitively slow (61-layer MoE at 512 partitions): compile two
    REDUCED-depth unrolled variants and extrapolate every per-layer-
    linear metric to the full depth.  Prologue/epilogue (embed, lm head)
    cancel in the finite difference, so the slope is exactly the
    per-layer cost."""
    full_L = get_arch(arch_id).config.num_layers
    r1 = run_cell(arch_id, shape_id, multi_pod, unroll=True, num_layers=L1,
                  overrides=overrides)
    r2 = run_cell(arch_id, shape_id, multi_pod, unroll=True, num_layers=L2,
                  overrides=overrides)
    rec = dict(r2)
    rec["extrapolated_from"] = [L1, L2]
    rec["num_layers"] = full_L
    scale = full_L - L2
    for k in _LINEAR_KEYS:
        if k in r1 and k in r2:
            slope = (r2[k] - r1[k]) / max(1, (L2 - L1))
            rec[k] = r2[k] + slope * scale
    if "collective_bytes" in r1 and "collective_bytes" in r2:
        merged = {}
        for op in r2["collective_bytes"]:
            slope = (r2["collective_bytes"][op] - r1["collective_bytes"][op]) / max(
                1, (L2 - L1)
            )
            merged[op] = int(r2["collective_bytes"][op] + slope * scale)
        rec["collective_bytes"] = merged
        rec["collective_bytes_total"] = int(sum(merged.values()))
    rec["compile_s"] = r1.get("compile_s", 0) + r2.get("compile_s", 0)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument(
        "--unroll", action="store_true",
        help="unroll layer scans so cost_analysis counts every layer "
             "(roofline metrics sweep; slower compiles)",
    )
    ap.add_argument(
        "--extrapolate", default=None, metavar="L1,L2",
        help="compile two reduced-depth unrolled variants and linearly "
             "extrapolate per-layer metrics to full depth (heavy MoE archs)",
    )
    # §Perf variant knobs — tag the record so roofline can diff vs baseline.
    ap.add_argument("--tag", default=None, help="variant tag for the record")
    ap.add_argument("--flash-remat", action="store_true")
    ap.add_argument("--vocab-pad", type=int, default=0)
    ap.add_argument("--moe-constraints", action="store_true")
    ap.add_argument("--cache-seq-shard", action="store_true")
    ap.add_argument("--param-mode", default=None, choices=["fsdp_tp", "tp_only"])
    ap.add_argument("--moe-block-dispatch", type=int, default=0)
    ap.add_argument("--embed-unsharded-d", action="store_true")
    ap.add_argument("--attn-replicated", action="store_true")
    args = ap.parse_args()

    overrides = {}
    if args.flash_remat:
        overrides["flash_remat"] = True
    if args.vocab_pad:
        overrides["vocab_pad_multiple"] = args.vocab_pad
    if args.moe_constraints:
        overrides["moe_shard_constraints"] = True
    if args.cache_seq_shard:
        overrides["cache_seq_shard_tp"] = True
    if args.param_mode:
        overrides["param_sharding_mode"] = args.param_mode
    if args.moe_block_dispatch:
        overrides["moe_block_dispatch"] = args.moe_block_dispatch
    if args.embed_unsharded_d:
        overrides["embed_unsharded_d"] = True
    if args.attn_replicated:
        overrides["attn_replicated"] = True

    archs = list_archs() if args.arch == "all" else [args.arch]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("ok"):
                        done.add((r["arch"], r["shape"], r["mesh"], r.get("variant")))
                except json.JSONDecodeError:
                    # half-written tail from a crashed run: that combo
                    # is simply not "done" and will be re-run below.
                    print(
                        f"WARN {args.out}: skipping malformed journal line",
                        flush=True,
                    )

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for arch_id in archs:
        spec = get_arch(arch_id)
        shapes = spec.shapes if args.shape == "all" else [args.shape]
        for shape_id in shapes:
            if shape_id not in spec.shapes:
                print(f"SKIP {arch_id} x {shape_id}: {spec.notes}", flush=True)
                continue
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                if (arch_id, shape_id, mesh_name, args.tag) in done:
                    print(f"CACHED {arch_id} x {shape_id} x {mesh_name}", flush=True)
                    continue
                print(f"RUN {arch_id} x {shape_id} x {mesh_name} ...", flush=True)
                try:
                    if args.extrapolate:
                        L1, L2 = (int(x) for x in args.extrapolate.split(","))
                        rec = run_cell_extrapolated(arch_id, shape_id, mp, L1, L2,
                                                    overrides=overrides)
                    else:
                        rec = run_cell(arch_id, shape_id, mp, args.microbatches,
                                       unroll=args.unroll, overrides=overrides)
                    if args.tag:
                        rec["variant"] = args.tag
                    rec["ok"] = True
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {
                        "arch": arch_id, "shape": shape_id, "mesh": mesh_name,
                        "ok": False, "error": f"{type(e).__name__}: {e}",
                        "trace": traceback.format_exc()[-2000:],
                    }
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
                status = "OK" if rec.get("ok") else "FAIL"
                print(
                    f"{status} {arch_id} x {shape_id} x {mesh_name} "
                    f"compile={rec.get('compile_s', '-')}s "
                    f"flops/dev={rec.get('flops_per_device', 0):.3e} "
                    f"coll={rec.get('collective_bytes_total', 0):.3e}B",
                    flush=True,
                )


if __name__ == "__main__":
    main()
