"""Mesh factories (assignment-mandated shapes).

``make_production_mesh`` is a FUNCTION (never module-level state) so
importing this module touches no jax device state.  Single-pod: 16x16
(data, model) = 256 chips.  Multi-pod: 2x16x16 (pod, data, model) = 512
chips; the ``pod`` axis composes with ``data`` for batch/FSDP sharding
and carries the hierarchical (DCN) gradient reduction.
"""

from __future__ import annotations

from typing import Tuple

import jax


def _make_mesh(shape, axes):
    """Auto-typed mesh on any jax version: older releases predate
    ``jax.sharding.AxisType`` and treat every axis as Auto already."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return _make_mesh((data, model), ("data", "model"))


def make_shard_mesh(n: int | None = None):
    """1-D ``("shard",)`` mesh over the host's devices — the cluster
    scatter (``repro.cluster.mesh_scatter``) lays K store shards out
    along this axis, ``ceil(K / n)`` per device.  ``n`` caps the device
    count (default: all devices, including
    ``xla_force_host_platform_device_count``-virtualized ones)."""
    n_dev = len(jax.devices())
    n = n_dev if n is None else max(1, min(int(n), n_dev))
    return _make_mesh((n,), ("shard",))


def mesh_axes(mesh) -> Tuple[Tuple[str, ...], str]:
    """Returns (batch/FSDP axes, tensor axis) for a mesh from this module."""
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data"), "model"
    return ("data",), "model"
