"""Tests for the deeplint static-analysis suite (tools/deeplint).

Per rule: a violating fixture, a clean fixture, a suppressed variant, and
(for the engine-level mechanisms) baselined variants — plus seeded-bug
checks against copies of the real sources and an end-to-end run over
``src/repro`` asserting zero non-baselined findings.
"""

import json
import sys
import textwrap
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from tools.deeplint import engine  # noqa: E402
from tools.deeplint.__main__ import main as deeplint_main  # noqa: E402
from tools.deeplint.rules import (  # noqa: E402
    ALL_RULES,
    RULE_IDS,
    device_sync,
    kernel_purity,
    layering,
    lock_discipline,
    metric_naming,
    mutation_version,
    stripped_assert,
    swallowed_exception,
)


def lint(tmp_path: Path, source: str, rules, rel: str = "mod.py"):
    """Write one fixture file and run the given rules over it."""
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source), encoding="utf-8")
    findings, suppressed, errors = engine.run([path], tmp_path, rules)
    assert not errors, errors
    return findings, suppressed


def rule_ids(findings):
    return [f.rule for f in findings]


# --------------------------------------------------------------- registry
class TestRegistry:
    def test_at_least_five_rules(self):
        assert len(ALL_RULES) >= 5

    def test_ids_are_kebab_and_unique(self):
        assert len(RULE_IDS) == len(ALL_RULES)
        for rid in RULE_IDS:
            assert rid == rid.lower() and " " not in rid

    def test_every_rule_has_summary_and_check(self):
        for mod in ALL_RULES:
            assert isinstance(mod.SUMMARY, str) and mod.SUMMARY
            assert callable(mod.check)


# --------------------------------------------------------- stripped-assert
class TestStrippedAssert:
    def test_violating(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            def f(n):
                assert n > 0, "bad n"
                return n
            """,
            [stripped_assert],
        )
        assert rule_ids(findings) == ["stripped-assert"]

    def test_clean_raise(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            def f(n):
                if n <= 0:
                    raise ValueError("bad n")
                return n
            """,
            [stripped_assert],
        )
        assert findings == []

    def test_suppressed_inline(self, tmp_path):
        findings, suppressed = lint(
            tmp_path,
            """
            def f(n):
                assert n > 0  # deeplint: ignore[stripped-assert]
                return n
            """,
            [stripped_assert],
        )
        assert findings == []
        assert rule_ids(suppressed) == ["stripped-assert"]

    def test_suppressed_comment_above(self, tmp_path):
        findings, suppressed = lint(
            tmp_path,
            """
            def f(n):
                # deeplint: ignore[stripped-assert]
                assert n > 0
                return n
            """,
            [stripped_assert],
        )
        assert findings == []
        assert len(suppressed) == 1

    def test_wrong_rule_suppression_does_not_apply(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            def f(n):
                assert n > 0  # deeplint: ignore[lock-discipline]
                return n
            """,
            [stripped_assert],
        )
        assert rule_ids(findings) == ["stripped-assert"]


# ----------------------------------------------------- swallowed-exception
class TestSwallowedException:
    def test_pass_only_body(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            def f():
                try:
                    risky()
                except ValueError:
                    pass
            """,
            [swallowed_exception],
        )
        assert rule_ids(findings) == ["swallowed-exception"]

    def test_pass_with_binding(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            def f():
                try:
                    risky()
                except Exception as exc:
                    pass
            """,
            [swallowed_exception],
        )
        assert rule_ids(findings) == ["swallowed-exception"]

    def test_docstring_only_body_still_flagged(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            def f():
                try:
                    risky()
                except ValueError:
                    "best effort"
            """,
            [swallowed_exception],
        )
        assert rule_ids(findings) == ["swallowed-exception"]

    def test_bare_except_flagged_even_with_real_body(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            def f(log):
                try:
                    risky()
                except:
                    log.warning("failed")
            """,
            [swallowed_exception],
        )
        assert rule_ids(findings) == ["swallowed-exception"]

    def test_clean_handler_that_records(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            def f(log):
                try:
                    risky()
                except ValueError as exc:
                    log.warning("failed: %s", exc)
                    return None
            """,
            [swallowed_exception],
        )
        assert findings == []

    def test_clean_reraise(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            def f():
                try:
                    risky()
                except ValueError:
                    raise RuntimeError("context")
            """,
            [swallowed_exception],
        )
        assert findings == []

    def test_suppressed(self, tmp_path):
        findings, suppressed = lint(
            tmp_path,
            """
            def f():
                try:
                    risky()
                except OSError:  # deeplint: ignore[swallowed-exception]
                    pass
            """,
            [swallowed_exception],
        )
        assert findings == []
        assert rule_ids(suppressed) == ["swallowed-exception"]


# --------------------------------------------------------- lock-discipline
LOCKED_CLASS = """
    import threading

    class Box:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0  # guarded-by: _lock
            self._items = []  # guarded-by: _lock

        def bump(self):
            with self._lock:
                self.count += 1

        def put(self, x):
            with self._lock:
                self._items.append(x)
"""


class TestLockDiscipline:
    def test_clean(self, tmp_path):
        findings, _ = lint(tmp_path, LOCKED_CLASS, [lock_discipline])
        assert findings == []

    def test_unlocked_augassign(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            LOCKED_CLASS
            + """
        def racy(self):
            self.count += 1
""",
            [lock_discipline],
        )
        assert rule_ids(findings) == ["lock-discipline"]
        assert "count" in findings[0].message

    def test_unlocked_container_mutation(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            LOCKED_CLASS
            + """
        def racy(self, x):
            self._items.append(x)
""",
            [lock_discipline],
        )
        assert rule_ids(findings) == ["lock-discipline"]

    def test_item_store_outside_lock(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._map = {}  # guarded-by: _lock

                def racy(self, k, v):
                    self._map[k] = v
            """,
            [lock_discipline],
        )
        assert rule_ids(findings) == ["lock-discipline"]

    def test_wrong_lock_held(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._other = threading.Lock()
                    self.count = 0  # guarded-by: _lock

                def racy(self):
                    with self._other:
                        self.count += 1
            """,
            [lock_discipline],
        )
        assert rule_ids(findings) == ["lock-discipline"]

    def test_holds_lock_helper(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            LOCKED_CLASS
            + """
        def _evict(self):  # holds-lock: _lock
            self._items.pop()
""",
            [lock_discipline],
        )
        assert findings == []

    def test_closure_does_not_inherit_with(self, tmp_path):
        # The PR 6 bug class: a with-block spawns a closure that runs on
        # a pool thread later — the closure must NOT count as locked.
        findings, _ = lint(
            tmp_path,
            LOCKED_CLASS
            + """
        def fan_out(self, pool):
            with self._lock:
                def work():
                    self.count += 1
                pool.submit(work)
""",
            [lock_discipline],
        )
        assert rule_ids(findings) == ["lock-discipline"]

    def test_init_exempt(self, tmp_path):
        findings, _ = lint(tmp_path, LOCKED_CLASS, [lock_discipline])
        assert findings == []  # __init__ assigns guarded attrs lock-free

    def test_guards_inherited_by_subclass(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            LOCKED_CLASS
            + """

    class SubBox(Box):
        def racy(self):
            self.count += 1
""",
            [lock_discipline],
        )
        assert rule_ids(findings) == ["lock-discipline"]
        assert "SubBox" in findings[0].message

    def test_suppressed(self, tmp_path):
        findings, suppressed = lint(
            tmp_path,
            LOCKED_CLASS
            + """
        def racy(self):
            self.count += 1  # deeplint: ignore[lock-discipline]
""",
            [lock_discipline],
        )
        assert findings == []
        assert len(suppressed) == 1


# ----------------------------------------------------------- kernel-purity
class TestKernelPurity:
    def test_clean_kernel(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            import jax.numpy as jnp
            from jax.experimental import pallas as pl

            def make(width):
                def kernel(x_ref, o_ref):
                    x = x_ref[...]
                    acc = x * 0
                    for p in range(width):
                        acc = acc + x
                    o_ref[...] = jnp.where(acc > 0, acc, 0)
                return pl.pallas_call(kernel, out_shape=None)
            """,
            [kernel_purity],
        )
        assert findings == []

    def test_branch_on_traced_value(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            from jax.experimental import pallas as pl

            def make():
                def kernel(x_ref, o_ref):
                    x = x_ref[...]
                    if x > 0:
                        o_ref[...] = x
                return pl.pallas_call(kernel, out_shape=None)
            """,
            [kernel_purity],
        )
        assert rule_ids(findings) == ["kernel-purity"]
        assert "branches on a traced value" in findings[0].message

    def test_host_numpy_in_kernel(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            import numpy as np
            from jax.experimental import pallas as pl

            def make():
                def kernel(x_ref, o_ref):
                    o_ref[...] = np.asarray(x_ref[...])
                return pl.pallas_call(kernel, out_shape=None)
            """,
            [kernel_purity],
        )
        assert rule_ids(findings) == ["kernel-purity"]
        assert "host numpy" in findings[0].message

    def test_global_statement(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            from jax.experimental import pallas as pl

            CALLS = 0

            def make():
                def kernel(x_ref, o_ref):
                    global CALLS
                    CALLS = CALLS + 1
                    o_ref[...] = x_ref[...]
                return pl.pallas_call(kernel, out_shape=None)
            """,
            [kernel_purity],
        )
        assert "kernel-purity" in rule_ids(findings)

    def test_closure_over_mutable_literal(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            from jax.experimental import pallas as pl

            def make():
                table = [1, 2, 3]
                def kernel(x_ref, o_ref):
                    o_ref[...] = x_ref[...] * table[0]
                return pl.pallas_call(kernel, out_shape=None)
            """,
            [kernel_purity],
        )
        assert rule_ids(findings) == ["kernel-purity"]
        assert "mutable container" in findings[0].message

    def test_closure_over_reassigned_var(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            from jax.experimental import pallas as pl

            def make(n):
                scale = 1
                scale = n + 1
                def kernel(x_ref, o_ref):
                    o_ref[...] = x_ref[...] * scale
                return pl.pallas_call(kernel, out_shape=None)
            """,
            [kernel_purity],
        )
        assert rule_ids(findings) == ["kernel-purity"]
        assert "reassigned" in findings[0].message

    def test_static_closure_allowed(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            from jax.experimental import pallas as pl

            def make(spec, width):
                plan = build_plan(spec)
                def kernel(x_ref, o_ref):
                    o_ref[...] = x_ref[...] * width + plan[0]
                return pl.pallas_call(kernel, out_shape=None)

            def build_plan(spec):
                return (1,)
            """,
            [kernel_purity],
        )
        assert findings == []

    def test_real_kernels_are_pure(self):
        findings, _, errors = engine.run(
            [REPO_ROOT / "src" / "repro" / "kernels"], REPO_ROOT, [kernel_purity]
        )
        assert not errors
        assert findings == []


# ------------------------------------------------------------- device-sync
class TestDeviceSync:
    def test_np_call_in_jit(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            import jax
            import numpy as np

            @jax.jit
            def f(x):
                return np.asarray(x) + 1
            """,
            [device_sync],
        )
        assert rule_ids(findings) == ["device-sync"]

    def test_item_in_jit(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            import jax

            @jax.jit
            def f(x):
                return x.sum().item()
            """,
            [device_sync],
        )
        assert rule_ids(findings) == ["device-sync"]

    def test_partial_jit_decorator(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            import functools
            import jax

            @functools.partial(jax.jit, static_argnums=(1,))
            def f(x, n):
                return x.tolist()
            """,
            [device_sync],
        )
        assert rule_ids(findings) == ["device-sync"]

    def test_host_function_unchecked(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            import numpy as np

            def collect(x):
                return np.asarray(x)
            """,
            [device_sync],
        )
        assert findings == []

    def test_collect_point_exemption(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            import jax
            import numpy as np

            @jax.jit
            def f(x):  # deeplint: collect-point
                return np.asarray(x)
            """,
            [device_sync],
        )
        assert findings == []


# -------------------------------------------------------- mutation-version
STORE_BASE = """
    class MappingStore:
        def mutation_version(self):
            return getattr(self, "_mutation_version", 0)

        def _note_mutation(self):
            self._mutation_version = getattr(self, "_mutation_version", 0) + 1
"""


class TestMutationVersion:
    def test_insert_without_bump(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            STORE_BASE
            + """

    class MyStore(MappingStore):
        def insert(self, keys, columns):
            self.rows[0] = columns
""",
            [mutation_version],
        )
        assert rule_ids(findings) == ["mutation-version"]
        assert "insert" in findings[0].message

    def test_insert_with_bump(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            STORE_BASE
            + """

    class MyStore(MappingStore):
        def insert(self, keys, columns):
            self.rows[0] = columns
            self._note_mutation()
""",
            [mutation_version],
        )
        assert findings == []

    def test_transitive_bump_through_helper(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            STORE_BASE
            + """

    class MyStore(MappingStore):
        def insert(self, keys, columns):
            self.rows[0] = columns
            self._finish()

        def _finish(self):
            self._note_mutation()
""",
            [mutation_version],
        )
        assert findings == []

    def test_covered_helper(self, tmp_path):
        # A state-writing helper whose only callers bump is covered.
        findings, _ = lint(
            tmp_path,
            STORE_BASE
            + """

    class MyStore(MappingStore):
        def insert(self, keys, columns):
            self._encode(columns)
            self._note_mutation()

        def _encode(self, columns):
            self.codec.extend(columns)
""",
            [mutation_version],
        )
        assert findings == []

    def test_uncovered_state_writing_helper(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            STORE_BASE
            + """

    class MyStore(MappingStore):
        def grow(self, columns):
            self.codec.extend(columns)
""",
            [mutation_version],
        )
        assert rule_ids(findings) == ["mutation-version"]

    def test_delegating_store_with_own_fence(self, tmp_path):
        # Federation shape: verbs forward to members; the class overrides
        # mutation_version, so member bumps are its fence.
        findings, _ = lint(
            tmp_path,
            STORE_BASE
            + """

    class Federated(MappingStore):
        def mutation_version(self):
            return tuple(m.mutation_version() for m in self.members)

        def insert(self, keys, columns):
            self.members[0].insert(keys, columns)
""",
            [mutation_version],
        )
        assert findings == []

    def test_abstract_verb_exempt(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            STORE_BASE
            + """

    class Facade(MappingStore):
        def insert(self, keys, columns):
            raise NotImplementedError("read-only facade")
""",
            [mutation_version],
        )
        assert findings == []

    def test_suppressed(self, tmp_path):
        findings, suppressed = lint(
            tmp_path,
            STORE_BASE
            + """

    class MyStore(MappingStore):
        # deeplint: ignore[mutation-version]
        def insert(self, keys, columns):
            self.rows[0] = columns
""",
            [mutation_version],
        )
        assert findings == []
        assert len(suppressed) == 1


# ----------------------------------------------------------------- layering
class TestLayering:
    def test_obs_must_not_import_repro(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            from repro.api import cache
            """,
            [layering],
            rel="repro/obs/bad.py",
        )
        assert rule_ids(findings) == ["layering"]
        assert "repro.obs" in findings[0].message

    def test_kernels_must_not_import_api(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            from repro.api.executor import run_plan
            """,
            [layering],
            rel="repro/kernels/bad.py",
        )
        assert rule_ids(findings) == ["layering"]

    def test_core_may_import_protocol_slice(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            from repro.api.protocol import MappingStore
            from repro.api.plan import ExplainStats
            """,
            [layering],
            rel="repro/core/good.py",
        )
        assert findings == []

    def test_core_must_not_import_executor(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            from repro.api import executor
            """,
            [layering],
            rel="repro/core/bad.py",
        )
        assert rule_ids(findings) == ["layering"]

    def test_function_local_import_allowed(self, tmp_path):
        # Function-local imports are the sanctioned cycle-breaker.
        findings, _ = lint(
            tmp_path,
            """
            def late():
                from repro.api import executor
                return executor
            """,
            [layering],
            rel="repro/core/good.py",
        )
        assert findings == []

    def test_non_repro_file_skipped(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            from repro.api import executor
            """,
            [layering],
            rel="scratch.py",
        )
        assert findings == []


# ------------------------------------------------------------ metric-naming
class TestMetricNaming:
    def test_bad_prefix(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            def f(obs):
                obs.counter("lookups_total").inc()
            """,
            [metric_naming],
        )
        assert rule_ids(findings) == ["metric-naming"]

    def test_counter_requires_total(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            def f(obs):
                obs.counter("deepmap_lookups").inc()
            """,
            [metric_naming],
        )
        assert rule_ids(findings) == ["metric-naming"]

    def test_histogram_requires_unit(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            def f(obs):
                obs.histogram("deepmap_latency").observe(1.0)
            """,
            [metric_naming],
        )
        assert rule_ids(findings) == ["metric-naming"]

    def test_gauge_must_not_end_total(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            def f(obs):
                obs.gauge("deepmap_queue_total").set(1)
            """,
            [metric_naming],
        )
        assert rule_ids(findings) == ["metric-naming"]

    def test_good_names(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            def f(obs):
                obs.counter("deepmap_lookups_total").inc()
                obs.gauge("deepmap_queue_depth").set(3)
                obs.histogram("deepmap_latency_seconds").observe(0.1)
            """,
            [metric_naming],
        )
        assert findings == []

    def test_unbounded_label(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            def f(obs, key):
                obs.counter("deepmap_lookups_total").inc(key=f"k{key}")
            """,
            [metric_naming],
        )
        assert rule_ids(findings) == ["metric-naming"]
        assert "unbounded" in findings[0].message

    def test_bounded_label(self, tmp_path):
        findings, _ = lint(
            tmp_path,
            """
            def f(obs, shard_id):
                obs.counter("deepmap_lookups_total").inc(shard=shard_id)
            """,
            [metric_naming],
        )
        assert findings == []


# ----------------------------------------------------------------- baseline
class TestBaseline:
    def test_baselined_finding_does_not_fail(self, tmp_path, capsys):
        target = tmp_path / "mod.py"
        target.write_text("def f(n):\n    assert n\n", encoding="utf-8")
        baseline = tmp_path / "baseline.json"

        rc = deeplint_main(
            [str(target), "--baseline", str(baseline), "--write-baseline"]
        )
        assert rc == 0
        data = json.loads(baseline.read_text())
        assert len(data["findings"]) == 1
        assert data["findings"][0]["rule"] == "stripped-assert"

        rc = deeplint_main([str(target), "--baseline", str(baseline)])
        assert rc == 0
        out = capsys.readouterr().out
        assert "1 baselined" in out

    def test_new_finding_still_fails_with_baseline(self, tmp_path):
        target = tmp_path / "mod.py"
        target.write_text("def f(n):\n    assert n\n", encoding="utf-8")
        baseline = tmp_path / "baseline.json"
        deeplint_main([str(target), "--baseline", str(baseline), "--write-baseline"])

        target.write_text(
            "def f(n):\n    assert n\n\ndef g(n):\n    assert not n\n",
            encoding="utf-8",
        )
        rc = deeplint_main([str(target), "--baseline", str(baseline)])
        assert rc == 1

    def test_no_baseline_flag(self, tmp_path):
        target = tmp_path / "mod.py"
        target.write_text("def f(n):\n    assert n\n", encoding="utf-8")
        baseline = tmp_path / "baseline.json"
        deeplint_main([str(target), "--baseline", str(baseline), "--write-baseline"])
        rc = deeplint_main(
            [str(target), "--baseline", str(baseline), "--no-baseline"]
        )
        assert rc == 1

    def test_shipped_baseline_is_empty(self):
        data = json.loads(
            (REPO_ROOT / "tools" / "deeplint" / "baseline.json").read_text()
        )
        assert data["findings"] == []


# ---------------------------------------------------------------- seeded bugs
class TestSeededBugs:
    """Acceptance checks: reintroducing two historical bugs into copies
    of the real sources produces exactly one finding each."""

    def _copy(self, tmp_path, rel):
        src = (REPO_ROOT / rel).read_text(encoding="utf-8")
        dst = tmp_path / Path(rel).name
        return src, dst

    def test_unlocked_cache_hit_counter(self, tmp_path):
        src, dst = self._copy(tmp_path, "src/repro/api/cache.py")
        needle = "        with self._lock:\n            entry = self._plans.get(fingerprint)"
        assert needle in src
        dst.write_text(
            src.replace(needle, "        self.hits += 1\n" + needle, 1),
            encoding="utf-8",
        )
        findings, _, errors = engine.run([dst], tmp_path, None)
        assert not errors
        assert rule_ids(findings) == ["lock-discipline"]
        assert "hits" in findings[0].message

    def test_bare_assert_in_executor(self, tmp_path):
        src, dst = self._copy(tmp_path, "src/repro/api/executor.py")
        marker = "\nclass "
        assert marker in src
        dst.write_text(
            src.replace(
                marker,
                '\ndef _seeded(n):\n    assert n > 0\n    return n\n\nclass ',
                1,
            ),
            encoding="utf-8",
        )
        findings, _, errors = engine.run([dst], tmp_path, None)
        assert not errors
        assert rule_ids(findings) == ["stripped-assert"]


# ------------------------------------------------------------------ e2e + CLI
class TestEndToEnd:
    def test_src_repro_is_clean(self):
        rc = deeplint_main(
            [
                str(REPO_ROOT / "src" / "repro"),
                "--baseline",
                str(REPO_ROOT / "tools" / "deeplint" / "baseline.json"),
            ]
        )
        assert rc == 0

    def test_json_report_shape(self, tmp_path):
        target = tmp_path / "mod.py"
        target.write_text("def f(n):\n    assert n\n", encoding="utf-8")
        out = tmp_path / "report.json"
        rc = deeplint_main(
            [str(target), "--format", "json", "--output", str(out),
             "--baseline", str(tmp_path / "nope.json")]
        )
        assert rc == 1
        data = json.loads(out.read_text())
        assert data["tool"] == "deeplint"
        assert data["summary"]["findings"] == 1
        assert set(data["rules"]) == set(RULE_IDS)
        f = data["findings"][0]
        assert {"rule", "path", "line", "col", "message"} <= set(f)

    def test_unknown_rule_is_usage_error(self, tmp_path):
        target = tmp_path / "mod.py"
        target.write_text("x = 1\n", encoding="utf-8")
        assert deeplint_main([str(target), "--rules", "no-such-rule"]) == 2

    def test_parse_error_exits_2(self, tmp_path):
        target = tmp_path / "broken.py"
        target.write_text("def f(:\n", encoding="utf-8")
        assert deeplint_main([str(target)]) == 2

    def test_rules_filter(self, tmp_path):
        target = tmp_path / "mod.py"
        target.write_text("def f(n):\n    assert n\n", encoding="utf-8")
        rc = deeplint_main(
            [str(target), "--rules", "layering",
             "--baseline", str(tmp_path / "nope.json")]
        )
        assert rc == 0  # assert finding not reported when rule filtered out
