"""The paper's comparison baselines (§V-A3):

* **AB**    — array-based, uncompressed (serialized numpy partitions);
* **ABC-D/G/Z/L** — array-based + Dictionary/Gzip/Z-Standard/LZMA;
* **HB**    — hash-based, uncompressed (pickled dict partitions);
* **HBC-Z/L** — hash-based + Z-Standard/LZMA.

All stores implement the full :class:`~repro.api.protocol.MappingStore`
protocol (lookup / insert / delete / update / range_lookup / scan /
save / load / ``query()``) — modifications go through an overlay over
the immutable partitions (`repro.baselines.partitioned`) — and charge
decompressed partitions to the same
:class:`~repro.storage.pool.MemoryPool`, so the benchmark comparisons
see identical memory pressure (§V-A5 partition-size tuning applies).
"""

from repro.baselines.array_store import ArrayStore  # noqa: F401
from repro.baselines.hash_store import HashStore  # noqa: F401
from repro.baselines.partitioned import PartitionedBaselineStore  # noqa: F401

BASELINE_FACTORIES = {
    "AB": lambda table, pool=None, **kw: ArrayStore.build(table, codec="none", pool=pool, **kw),
    "ABC-D": lambda table, pool=None, **kw: ArrayStore.build(
        table, codec="none", dictionary=True, pool=pool, **kw
    ),
    "ABC-G": lambda table, pool=None, **kw: ArrayStore.build(table, codec="gzip", pool=pool, **kw),
    "ABC-Z": lambda table, pool=None, **kw: ArrayStore.build(table, codec="zstd", pool=pool, **kw),
    "ABC-L": lambda table, pool=None, **kw: ArrayStore.build(table, codec="lzma", pool=pool, **kw),
    "HB": lambda table, pool=None, **kw: HashStore.build(table, codec="none", pool=pool, **kw),
    "HBC-Z": lambda table, pool=None, **kw: HashStore.build(table, codec="zstd", pool=pool, **kw),
    "HBC-L": lambda table, pool=None, **kw: HashStore.build(table, codec="lzma", pool=pool, **kw),
}
