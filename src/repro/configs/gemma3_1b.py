"""gemma3-1b — 5:1 local:global attention, 128k-capable
[hf:google/gemma-3-1b-pt; unverified].  26L d_model=1152 4H (kv=1,
head 256) d_ff=6912 vocab=262144, sliding window 512 on local layers.
Local layers bound the KV working set, so ``long_500k`` applies (the
lone global layer class holds full-context KV; decode stays O(seq))."""

from repro.configs.base import ArchSpec, register
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    window_pattern=(512, 512, 512, 512, 512, 0),  # 5 local : 1 global
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    family="dense",
    num_layers=8,  # 1 full 6-pattern group + 2 remainder
    d_model=48,
    num_heads=2,
    num_kv_heads=1,
    head_dim=24,
    d_ff=96,
    vocab_size=256,
    window_pattern=(8, 8, 8, 8, 8, 0),
    tie_embeddings=True,
    dtype="float32",
    remat="none",
)

SPEC = register(
    ArchSpec(
        arch_id="gemma3-1b",
        config=CONFIG,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
        notes="5:1 local:global; long_500k runs (see DESIGN.md §5).",
    )
)
