"""Export sinks: Prometheus text exposition, JSON snapshot, Chrome
trace events.

All three render the same underlying state (a
:class:`~repro.obs.metrics.MetricsRegistry` and/or a
:class:`~repro.obs.tracing.Tracer`) so one process can serve a
``/metrics`` scrape, embed a snapshot into a ``BENCH_*.json``, and
drop a ``trace.json`` for Perfetto — without three bookkeeping paths.

Chrome trace format notes: each span becomes one complete ("X") event
with ``ts``/``dur`` in microseconds; each logical track (see
:mod:`repro.obs.tracing`) becomes a tid under one pid, named via "M"
(metadata) ``thread_name`` events so Perfetto shows "device" and
"host" as labeled rows.  Open ``trace.json`` at https://ui.perfetto.dev
(or chrome://tracing) — the dispatch/collect pipeline overlap shows up
as device-track spans covering the host-track spans beneath them.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.obs.metrics import Histogram, MetricsRegistry, _LabelKey
from repro.obs.metrics import registry as default_registry
from repro.obs.tracing import Tracer
from repro.obs.tracing import tracer as default_tracer


def _fmt_value(v: float) -> str:
    """Prometheus-style number: integers bare, floats via repr."""
    if v == int(v) and abs(v) < 2**53:
        return str(int(v))
    return repr(v)


def _fmt_labels(key: _LabelKey, extra: Optional[List] = None) -> str:
    pairs = list(key) + (extra or [])
    if not pairs:
        return ""
    inner = ",".join(
        '{}="{}"'.format(k, str(v).replace("\\", "\\\\").replace('"', '\\"'))
        for k, v in pairs
    )
    return "{" + inner + "}"


def to_prometheus(reg: Optional[MetricsRegistry] = None) -> str:
    """Render the registry in Prometheus text exposition format 0.0.4
    (``# HELP``/``# TYPE`` headers; histograms as cumulative
    ``_bucket{le=...}`` series plus ``_sum``/``_count``)."""
    reg = reg if reg is not None else default_registry()
    lines: List[str] = []
    for metric in reg.collect():
        if metric.help:
            lines.append(f"# HELP {metric.name} {metric.help}")
        lines.append(f"# TYPE {metric.name} {metric.kind}")
        if isinstance(metric, Histogram):
            for key, st in metric.items():
                cum = 0
                for bound, count in zip(metric.buckets, st.counts):
                    cum += count
                    le = _fmt_labels(key, [("le", _fmt_value(bound))])
                    lines.append(f"{metric.name}_bucket{le} {cum}")
                cum += st.counts[-1]
                le = _fmt_labels(key, [("le", "+Inf")])
                lines.append(f"{metric.name}_bucket{le} {cum}")
                lines.append(f"{metric.name}_sum{_fmt_labels(key)} {repr(st.sum)}")
                lines.append(f"{metric.name}_count{_fmt_labels(key)} {st.count}")
        else:
            for key, value in metric.items():
                lines.append(f"{metric.name}{_fmt_labels(key)} {_fmt_value(value)}")
    return "\n".join(lines) + "\n"


def to_json_snapshot(
    reg: Optional[MetricsRegistry] = None, indent: Optional[int] = 2
) -> str:
    """The registry's :meth:`~repro.obs.metrics.MetricsRegistry.snapshot`
    serialized to a JSON string."""
    reg = reg if reg is not None else default_registry()
    return json.dumps(reg.snapshot(), indent=indent, sort_keys=True)


def to_chrome_trace(
    trc: Optional[Tracer] = None,
    process_name: str = "deepmapping",
) -> Dict:
    """Render the tracer's spans as a Chrome trace-event object
    (``{"traceEvents": [...], "displayTimeUnit": "ms"}``).

    Timestamps are rebased so the oldest recorded span starts at 0 µs
    (perf_counter's epoch is arbitrary).  Track → tid assignment is
    first-seen order, with "device" pinned to tid 0 when present so
    the async device row renders above the host rows in Perfetto.
    """
    trc = trc if trc is not None else default_tracer()
    spans = trc.spans()
    events: List[Dict] = [
        {
            "name": "process_name",
            "ph": "M",
            "pid": 1,
            "tid": 0,
            "args": {"name": process_name},
        }
    ]
    if not spans:
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    t0 = min(s.start for s in spans)
    tracks: Dict[str, int] = {}
    if any(s.track == "device" for s in spans):
        tracks["device"] = 0
    for s in spans:
        if s.track not in tracks:
            tracks[s.track] = len(tracks)
    for track, tid in tracks.items():
        events.append(
            {
                "name": "thread_name",
                "ph": "M",
                "pid": 1,
                "tid": tid,
                "args": {"name": track},
            }
        )
    for s in spans:
        events.append(
            {
                "name": s.name,
                "cat": s.track,
                "ph": "X",
                "pid": 1,
                "tid": tracks[s.track],
                "ts": (s.start - t0) * 1e6,
                "dur": s.duration * 1e6,
                "args": {k: str(v) for k, v in s.args.items()},
            }
        )
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def _open_for_write(path: str):
    # Sinks are usually pointed at a fresh --telemetry-dir; create it.
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    return open(path, "w")


def write_prometheus(path: str, reg: Optional[MetricsRegistry] = None) -> str:
    """Write :func:`to_prometheus` output to ``path``; returns the path."""
    with _open_for_write(path) as f:
        f.write(to_prometheus(reg))
    return path


def write_json_snapshot(path: str, reg: Optional[MetricsRegistry] = None) -> str:
    """Write :func:`to_json_snapshot` output to ``path``; returns the path."""
    with _open_for_write(path) as f:
        f.write(to_json_snapshot(reg))
    return path


def write_chrome_trace(path: str, trc: Optional[Tracer] = None) -> str:
    """Write :func:`to_chrome_trace` output (JSON) to ``path``;
    returns the path.  Load it at https://ui.perfetto.dev."""
    with _open_for_write(path) as f:
        json.dump(to_chrome_trace(trc), f)
    return path
