"""Multi-device shard scatter: K store shards on an N-device mesh.

The thread-pool fan-out in ``sharded_store`` overlaps per-shard *host*
halves, but every shard's device inference still runs through one
device queue.  When real devices exist (or virtual ones, via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``), this module
maps the K shards onto a 1-D ``("shard",)`` mesh
(:func:`repro.launch.mesh.make_shard_mesh`) and answers a scattered
lookup batch in ONE ``shard_map`` launch: each device runs the model
forward + packed-word existence test for its ``ceil(K/N)`` shards
(``vmap`` over the local shard block), and an ``all_gather`` collects
every shard's codes + exist bits back to each host view.

Stacking contract (what makes one program serve K heterogeneous
shards): all shards share one architecture (same base / shared /
private dims / task set — guaranteed when the cluster was built from
one ``DeepMappingConfig``), while per-shard *sizes* differ and are
padded to fleet maxima:

* digit width   — extra positions get ``(mod=1, div=1)`` ops (digit 0)
  and zero first-layer weight rows, contributing nothing;
* head cardinality — extra logit columns are masked to ``-inf`` before
  the argmax, so a padded column can never win;
* existence words — zero-padded; in-domain keys never index the pad.

The host half of Algorithm 1 (existence fallback, aux merge, predicate
filter, decode) still runs per shard through the store's ordinary
collect path: the runner only replaces *device inference*, handing each
shard a precomputed :class:`~repro.core.inference.InferTicket`
(``path="mesh"``).  Retries after a failure re-dispatch through the
thread-pool path, so fault semantics are unchanged.  Byte-identity of
the full lookup vs the thread-pool path is enforced by the cluster
conformance suite (``tests/test_mesh_scatter.py``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.inference import INT32_MAX, InferTicket
from repro.kernels import bitvector as bv_kernel
from repro.launch import mesh as mesh_lib

try:  # jax>=0.4.35 canonical location
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    _SHARD_MAP = True
except Exception:  # pragma: no cover - toolchain without shard_map
    _SHARD_MAP = False

#: Minimum padded batch length — one lane-ish tile, keeps tiny batches
#: from compiling one program per length.
MIN_BATCH_PAD = 128


@dataclasses.dataclass(frozen=True)
class _Layout:
    """Static structure of the stacked parameter list: how many
    ``(w, b)`` pairs belong to the trunk and to each head (spec task
    order).  Hashable so it can close into the jitted scatter fn."""

    base: int
    n_shared: int
    hidden: Tuple[int, ...]      # private layers per task, spec order
    n_tasks: int


def _apply_stacked(w, b, x, digits):
    """One dense layer, mirroring ``model._apply`` exactly (the gather
    path for rank-3 first-from-input layers, matmul otherwise) so the
    per-shard forward stays numerically aligned with the jit ladder."""
    if w.ndim == 3:
        gathered = jax.vmap(lambda wp, dp: wp[dp], in_axes=(0, 1))(w, digits)
        return gathered.sum(axis=0) + b
    return x @ w + b


def _one_shard(keys, mods, divs, cap, vcap, words, cards, flat, layout):
    """Fused key->codes->exists for ONE shard (vmapped over the local
    shard block inside the shard_map body).

    ``keys`` (B,) int32 with -1 sentinels; returns ``codes`` (B, m)
    int32 (out-of-capacity rows 0 — the ``_infer_codes`` contract) and
    ``exists`` (B,) int32 0/1 (the host ``BitVector.test`` contract).
    """
    in_cap = (keys >= 0) & (keys < cap)
    safe = jnp.where(in_cap, keys, 0)
    digits = (
        ((safe[:, None] % mods[None, :]) // divs[None, :]) % layout.base
    ).astype(jnp.int32)

    it = iter(flat)
    x = None
    for _ in range(layout.n_shared):
        w, b = next(it), next(it)
        x = jax.nn.relu(_apply_stacked(w, b, x, digits))
    codes_cols = []
    neg_inf = jnp.asarray(-jnp.inf, dtype=jnp.float32)
    for ti in range(layout.n_tasks):
        h = x
        for _ in range(layout.hidden[ti]):
            w, b = next(it), next(it)
            h = jax.nn.relu(_apply_stacked(w, b, h, digits))
        w, b = next(it), next(it)
        logits = _apply_stacked(w, b, h, digits)
        # Mask the cardinality pad: a zero-weight padded column must
        # never beat a real (possibly negative) logit.
        col = jnp.arange(logits.shape[-1], dtype=jnp.int32)
        logits = jnp.where(col[None, :] < cards[ti], logits, neg_inf)
        code = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        codes_cols.append(jnp.where(in_cap, code, 0))
    codes = jnp.stack(codes_cols, axis=1)

    # vcap is the *inclusive* top key (capacity - 1, int32-safe even
    # for the 2^31-slot edge the fused tier also supports).
    in_dom = (keys >= 0) & (keys <= vcap)
    safe2 = jnp.where(in_dom, keys, 0)
    word = jnp.take(words, jax.lax.shift_right_logical(safe2, 5), axis=0)
    bit = jnp.bitwise_and(
        jax.lax.shift_right_logical(
            word, jnp.bitwise_and(safe2, 31).astype(jnp.uint32)
        ),
        jnp.uint32(1),
    )
    exists = jnp.where(in_dom, bit.astype(jnp.int32), 0)
    return codes, exists


def _build_scatter_fn(mesh, layout: _Layout, n_flat: int):
    """jitted ``shard_map`` program: shard-axis-stacked inputs in,
    all-gathered (replicated) codes + exists out."""

    def body(keys, mods, divs, cap, vcap, words, cards, *flat):
        def per_shard(k, m, d, c, v, w, cd, *fl):
            return _one_shard(k, m, d, c, v, w, cd, fl, layout)

        codes, exists = jax.vmap(per_shard)(
            keys, mods, divs, cap, vcap, words, cards, *flat
        )
        codes = jax.lax.all_gather(codes, "shard", axis=0, tiled=True)
        exists = jax.lax.all_gather(exists, "shard", axis=0, tiled=True)
        return codes, exists

    in_specs = (P("shard"),) * (7 + n_flat)
    # check_rep=False: this jax version's replication checker cannot
    # statically infer that a tiled all_gather output is replicated,
    # and rejects the (correct) P() out_specs without it.
    return jax.jit(
        shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=(P(), P()),
            check_rep=False,
        )
    )


def _pow2_at_least(n: int, floor: int) -> int:
    b = floor
    while b < n:
        b <<= 1
    return b


class MeshShardRunner:
    """Device-parallel inference for a shard fleet.

    Build via :meth:`maybe_build` (returns None when the mesh path
    cannot apply); per lookup call :meth:`run` with the router's
    scattered batches — it returns per-shard ``(codes, exists)`` host
    arrays, or None when the fleet drifted out of eligibility (retrain
    changed a shard's architecture, a shard was quarantined) and the
    caller should fall back to the thread-pool path.
    """

    def __init__(self, shards: Sequence, mesh, n_dev: int):
        self.shards = list(shards)
        self.mesh = mesh
        self.n_dev = int(n_dev)
        self.k = len(self.shards)
        self.k_pad = -(-self.k // self.n_dev) * self.n_dev
        self._stacked = None          # (version, layout, dict of arrays)
        self._fn = None               # jitted scatter fn (per layout)

    # ------------------------------------------------------------ build
    @classmethod
    def maybe_build(cls, shards: Sequence) -> Optional["MeshShardRunner"]:
        if not _SHARD_MAP:
            return None
        try:
            n_dev = len(jax.devices())
        except Exception:  # pragma: no cover - backend init failure
            return None
        if n_dev < 2 or len(shards) < 2:
            return None
        if not all(cls._shard_eligible(s) for s in shards):
            return None
        first = shards[0].spec
        for s in shards[1:]:
            sp = s.spec
            if (
                sp.tasks != first.tasks
                or sp.base != first.base
                or sp.shared != first.shared
                or sp.private != first.private
                or sp.dtype != first.dtype
            ):
                return None
        mesh = mesh_lib.make_shard_mesh()
        return cls(shards, mesh, n_dev)

    @staticmethod
    def _shard_eligible(s) -> bool:
        return (
            getattr(s, "vexist", None) is not None
            and getattr(s, "params", None) is not None
            and hasattr(s, "engine")
            and s.encoder.capacity <= INT32_MAX
            and s.vexist.capacity <= INT32_MAX + 1
            and s.spec.dtype == "float32"
        )

    # ---------------------------------------------------------- stacking
    def _version(self) -> tuple:
        return tuple((id(s.params), s.vexist.version) for s in self.shards)

    def _stack(self):
        """(Re)build the stacked device arrays when any shard's params
        or bitvector moved.  Returns ``(layout, arrays)`` or None when
        the fleet is no longer stackable (fall back upstream)."""
        version = self._version()
        if self._stacked is not None and self._stacked[0] == version:
            return self._stacked[1], self._stacked[2]
        shards = self.shards
        if not all(self._shard_eligible(s) for s in shards):
            return None
        first = shards[0].spec
        for s in shards[1:]:
            sp = s.spec
            if (
                sp.tasks != first.tasks
                or sp.base != first.base
                or sp.shared != first.shared
                or sp.private != first.private
            ):
                return None

        pos_ops = [tuple(s.engine._pos_ops) for s in shards]
        w_max = max(len(p) for p in pos_ops)
        mods = np.ones((self.k_pad, w_max), dtype=np.int32)
        divs = np.ones((self.k_pad, w_max), dtype=np.int32)
        for i, ops in enumerate(pos_ops):
            for j, (mod, div) in enumerate(ops):
                if mod > INT32_MAX or div > INT32_MAX:
                    return None  # top digit op overflows int32 math
                mods[i, j], divs[i, j] = mod, div
        cap = np.zeros(self.k_pad, dtype=np.int32)
        # inclusive top existing key: capacity - 1 fits int32 even at
        # the 2^31-slot edge (x64 is disabled, so no int64 in-graph)
        vcap = np.full(self.k_pad, -1, dtype=np.int32)
        cap[: self.k] = [s.encoder.capacity for s in shards]
        vcap[: self.k] = [s.vexist.capacity - 1 for s in shards]

        tasks = first.tasks
        cards_max = {
            t: max(s.spec.card_map[t] for s in shards) for t in tasks
        }
        cards = np.zeros((self.k_pad, len(tasks)), dtype=np.int32)
        for i, s in enumerate(shards):
            cards[i] = [s.spec.card_map[t] for t in tasks]

        words_list = [bv_kernel.pack_words32(s.vexist.words) for s in shards]
        nw_max = max(w.shape[0] for w in words_list)
        words = np.zeros((self.k_pad, nw_max), dtype=np.uint32)
        for i, w in enumerate(words_list):
            words[i, : w.shape[0]] = w

        def stack_layer(select, pad_axis=None, pad_to=0):
            """Stack one (w, b) across shards, zero-padding ``w`` along
            ``pad_axis`` (0 = width rows, -1 = cardinality columns)."""
            ws = [np.asarray(select(s)["w"], dtype=np.float32) for s in shards]
            bs = [np.asarray(select(s)["b"], dtype=np.float32) for s in shards]
            if pad_axis is not None:
                padded = []
                for w in ws:
                    if w.shape[pad_axis] < pad_to:
                        pad = [(0, 0)] * w.ndim
                        pad[pad_axis] = (0, pad_to - w.shape[pad_axis])
                        w = np.pad(w, pad)
                    padded.append(w)
                ws = padded
                if pad_axis in (-1, ws[0].ndim - 1):
                    bs = [
                        np.pad(b, (0, pad_to - b.shape[0]))
                        if b.shape[0] < pad_to else b
                        for b in bs
                    ]
            shapes = {w.shape for w in ws}
            if len(shapes) != 1:
                return None
            w_stack = np.stack(ws + [ws[0]] * (self.k_pad - self.k))
            b_stack = np.stack(bs + [bs[0]] * (self.k_pad - self.k))
            return w_stack, b_stack

        flat: List[np.ndarray] = []
        n_shared = len(first.shared)
        for li in range(n_shared):
            pair = stack_layer(
                lambda s, li=li: s.params["shared"][li],
                pad_axis=0 if li == 0 else None, pad_to=w_max,
            )
            if pair is None:
                return None
            flat.extend(pair)
        hidden = []
        for t in tasks:
            n_hidden = len(first.private_map[t])
            hidden.append(n_hidden)
            for li in range(n_hidden):
                pair = stack_layer(
                    lambda s, t=t, li=li: s.params["heads"][t]["hidden"][li],
                    pad_axis=0 if n_shared == 0 and li == 0 else None,
                    pad_to=w_max,
                )
                if pair is None:
                    return None
                flat.extend(pair)
            first_from_input = n_shared == 0 and n_hidden == 0
            pair = stack_layer(
                lambda s, t=t: s.params["heads"][t]["out"],
                pad_axis=0 if first_from_input else -1,
                pad_to=w_max if first_from_input else cards_max[t],
            )
            if pair is None:
                return None
            if first_from_input:
                # rank-3 out layer also needs its cardinality padded
                w_stack, b_stack = pair
                cpad = cards_max[t] - w_stack.shape[-1]
                if cpad:
                    w_stack = np.pad(
                        w_stack, [(0, 0)] * (w_stack.ndim - 1) + [(0, cpad)]
                    )
                    b_stack = np.pad(b_stack, [(0, 0), (0, cpad)])
                pair = (w_stack, b_stack)
            flat.extend(pair)

        layout = _Layout(
            base=first.base, n_shared=n_shared,
            hidden=tuple(hidden), n_tasks=len(tasks),
        )
        arrays = {
            "mods": jnp.asarray(mods),
            "divs": jnp.asarray(divs),
            "cap": jnp.asarray(cap),
            "vcap": jnp.asarray(vcap),
            "words": jnp.asarray(words),
            "cards": jnp.asarray(cards),
            "flat": tuple(jnp.asarray(a) for a in flat),
        }
        if self._stacked is None or self._stacked[1] != layout:
            self._fn = None  # layout changed: rebuild the scatter program
        self._stacked = (version, layout, arrays)
        obs.counter(
            "deepmap_mesh_stack_total",
            "Mesh scatter weight/word (re)stackings.",
        ).inc()
        return layout, arrays

    # -------------------------------------------------------------- run
    def run(
        self, batches: Sequence
    ) -> Optional[Dict[int, Tuple[np.ndarray, np.ndarray]]]:
        """One scattered lookup: ``batches`` are the router's per-shard
        key batches.  Returns ``{shard_id: (codes (n_pad, m) int32,
        exists (n_pad,) int32)}`` host-visible arrays (callers slice to
        the true batch length), or None on ineligibility."""
        stacked = self._stack()
        if stacked is None:
            return None
        layout, arrays = stacked
        if self._fn is None:
            self._fn = _build_scatter_fn(
                self.mesh, layout, len(arrays["flat"])
            )
        b_pad = _pow2_at_least(
            max(int(b.keys.shape[0]) for b in batches), MIN_BATCH_PAD
        )
        keys_blk = np.full((self.k_pad, b_pad), -1, dtype=np.int32)
        for b in batches:
            k = np.asarray(b.keys, dtype=np.int64)
            valid = (k >= 0) & (k <= INT32_MAX)
            keys_blk[b.shard_id, : k.shape[0]] = np.where(
                valid, k, -1
            ).astype(np.int32)
        codes, exists = self._fn(
            jnp.asarray(keys_blk), arrays["mods"], arrays["divs"],
            arrays["cap"], arrays["vcap"], arrays["words"],
            arrays["cards"], *arrays["flat"],
        )
        obs.counter(
            "deepmap_mesh_scatter_total",
            "Scattered lookup batches answered via the device mesh.",
        ).inc()
        codes_np = np.asarray(codes)
        exists_np = np.asarray(exists)
        return {
            int(b.shard_id): (
                codes_np[b.shard_id], exists_np[b.shard_id]
            )
            for b in batches
        }

    def tickets(
        self, batches: Sequence
    ) -> Optional[Dict[int, InferTicket]]:
        """Run one scatter and wrap each shard's result as a ready
        :class:`InferTicket` (``path="mesh"``) for
        ``DeepMappingStore._dispatch_precomputed``."""
        results = self.run(batches)
        if results is None:
            return None
        out: Dict[int, InferTicket] = {}
        for b in batches:
            codes, exists = results[int(b.shard_id)]
            keys = np.asarray(b.keys, dtype=np.int64)
            out[int(b.shard_id)] = InferTicket(
                n=keys.shape[0],
                tasks=self.shards[b.shard_id].spec.tasks,
                path="mesh",
                keys=keys,
                want_exists=True,
                codes_dev=codes,
                exists_dev=exists,
                task_order=self.shards[b.shard_id].spec.tasks,
            )
        return out
