"""Rule ``swallowed-exception``: error paths that erase the error.

The fault-tolerance layer (``repro.fault``) exists so failures become
STRUCTURED evidence — ``OwnerError`` on explain stats, retry/quarantine
counters, typed raises.  A handler that swallows an exception defeats
all of it: the failure neither surfaces nor counts.  Two shapes are
flagged:

* ``except ...: pass`` (with or without a binding) — the caught
  exception vanishes without a trace;
* bare ``except:`` — catches ``SystemExit``/``KeyboardInterrupt`` too,
  regardless of body.

A handler that re-raises, logs, records an outcome, or returns a
degraded value is fine — only a body that is nothing but ``pass``
(docstrings included) counts as swallowing.  A deliberate best-effort
cleanup can carry ``# deeplint: ignore[swallowed-exception]``.
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from tools.deeplint.engine import Finding, Project

RULE_ID = "swallowed-exception"
SUMMARY = (
    "except handler swallows the exception (bare except, or a body of "
    "only pass) — failures must surface or be recorded"
)


def _only_passes(body: List[ast.stmt]) -> bool:
    """True when the handler body does nothing: ``pass`` statements
    and/or a lone docstring/constant expression."""
    for stmt in body:
        if isinstance(stmt, ast.Pass):
            continue
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant):
            continue  # docstring-style constant, still does nothing
        return False
    return True


def check(project: Project) -> Iterable[Finding]:
    findings: List[Finding] = []
    for src in project.modules:
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                findings.append(
                    src.finding(
                        RULE_ID,
                        node,
                        "bare except: catches SystemExit/KeyboardInterrupt "
                        "and hides the failure type; name the exception "
                        "class(es)",
                    )
                )
            elif _only_passes(node.body):
                findings.append(
                    src.finding(
                        RULE_ID,
                        node,
                        "except body is only pass — the failure neither "
                        "surfaces nor counts; re-raise, record an "
                        "OwnerError/metric, or return a degraded value",
                    )
                )
    return findings
