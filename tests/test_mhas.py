import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.encoding import KeyEncoder, onehot_digits
from repro.core.mhas import MHASConfig, SearchSpace, run_mhas
from repro.core.mhas import controller as ctrl
from repro.core.model import forward_onehot
from repro.data import synthetic_multi_column


@pytest.fixture(scope="module")
def space():
    return SearchSpace(
        base=10, width=4, tasks=("a", "b"), out_cards=(5, 3),
        layer_sizes=(8, 16, 32), max_layers=2,
    )


class TestSearchSpace:
    def test_bank_shapes(self, space):
        bank = space.init_bank(seed=0)
        assert bank["trunk"][0]["w"].shape == (space.max_width, space.max_width)
        assert bank["heads"]["a"]["out"]["w"].shape == (space.max_width, 5)

    def test_tokens_to_arch_bounds(self, space):
        tokens = np.array([2, 0, 1, 1, 2, 2, 0, 0, 0])
        arch = space.tokens_to_arch(tokens)
        assert arch["trunk_depth"] == 2
        assert list(arch["trunk_sizes"]) == [8, 16]
        assert arch["heads"]["a"]["depth"] == 1

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_masked_equals_sliced_child(self, space, seed):
        """THE core MHAS invariant: the weight-shared masked forward must
        equal the standalone sliced child model exactly."""
        rng = np.random.default_rng(seed)
        bank = space.init_bank(seed=seed)
        tokens = rng.integers(0, 3, size=space.num_decisions)
        arch = space.tokens_to_arch(tokens)
        aa = space.arch_arrays(arch)

        enc = KeyEncoder(max_key=9999, base=10)
        keys = rng.integers(0, 10000, size=17).astype(np.int64)
        oh = onehot_digits(jnp.asarray(enc.digits(keys)), 10)
        oh_pad = jnp.pad(oh, ((0, 0), (0, space.max_width - oh.shape[-1])))

        masked = space.forward(bank, oh_pad, aa)
        child_params = space.extract_child_params(bank, arch)
        spec = space.child_spec(arch)
        sliced = forward_onehot(child_params, oh, spec)
        for t in space.tasks:
            np.testing.assert_allclose(masked[t], sliced[t], rtol=2e-5, atol=2e-5)

    def test_child_num_params_matches_spec(self, space):
        tokens = np.array([1, 2, 0, 2, 1, 1, 0, 0, 0])
        arch = space.tokens_to_arch(tokens)
        assert space.child_num_params(arch) == space.child_spec(arch).num_params()

    def test_search_space_size_formula(self, space):
        """Paper: |space| = N^{2M} * prod terms; here just sanity that the
        decision sequence covers the space."""
        assert space.num_decisions == (1 + 2) * (1 + 2)


class TestController:
    def test_sample_shapes_and_ranges(self, space):
        cspec = ctrl.ControllerSpec.for_space(space)
        params = ctrl.init_controller(cspec, seed=0)
        tokens, logp, ent = ctrl.sample_arch(params, cspec, jax.random.PRNGKey(0))
        assert tokens.shape == (space.num_decisions,)
        kinds = space.decision_kinds()
        for k, t in zip(kinds, np.asarray(tokens)):
            limit = cspec.depth_choices if k == 0 else cspec.size_choices
            assert 0 <= t < limit
        assert jnp.isfinite(logp) and ent > 0

    def test_logprob_matches_sample(self, space):
        cspec = ctrl.ControllerSpec.for_space(space)
        params = ctrl.init_controller(cspec, seed=0)
        tokens, logp_s, _ = ctrl.sample_arch(params, cspec, jax.random.PRNGKey(1))
        logp_r, _ = ctrl.logprob_of(params, cspec, tokens)
        np.testing.assert_allclose(float(logp_s), float(logp_r), rtol=1e-5)

    def test_logprob_differentiable(self, space):
        cspec = ctrl.ControllerSpec.for_space(space)
        params = ctrl.init_controller(cspec, seed=0)
        tokens = jnp.zeros((space.num_decisions,), jnp.int32)
        g = jax.grad(lambda p: ctrl.logprob_of(p, cspec, tokens)[0])(params)
        assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))

    def test_different_rng_different_samples(self, space):
        cspec = ctrl.ControllerSpec.for_space(space)
        params = ctrl.init_controller(cspec, seed=0)
        t1, _, _ = ctrl.sample_arch(params, cspec, jax.random.PRNGKey(0))
        outs = [
            np.asarray(ctrl.sample_arch(params, cspec, jax.random.PRNGKey(i))[0])
            for i in range(8)
        ]
        assert any(not np.array_equal(outs[0], o) for o in outs[1:])


class TestRunMHAS:
    def test_end_to_end_small(self):
        table = synthetic_multi_column(
            n=1500, correlation="high", cardinalities=(3, 4), seed=0
        )
        cfg = MHASConfig(
            layer_sizes=(8, 16),
            total_iters=8,
            model_iters=8,
            controller_iters=2,
            model_epochs_per_iter=1,
            model_batch=512,
            controller_batch=512,
            controller_samples=2,
            finetune_epochs=3,
        )
        res = run_mhas(table, cfg)
        assert res.best_ratio < float("inf")
        assert len(res.history) > 0
        assert res.spec.tasks == ("v0", "v1")
        # result is usable by the hybrid store
        from repro.core import DeepMappingConfig, DeepMappingStore

        store = DeepMappingStore.build(
            table, DeepMappingConfig(), spec=res.spec, params=res.params
        )
        vals, exists = store.lookup(table.keys[:100])
        assert exists.all()
        np.testing.assert_array_equal(vals["v0"], table.columns["v0"][:100])

    def test_history_records_ratio_progress(self):
        table = synthetic_multi_column(n=1000, correlation="high", seed=1)
        cfg = MHASConfig(
            layer_sizes=(8,),
            total_iters=4, model_iters=4, controller_iters=1,
            model_epochs_per_iter=1, model_batch=256, controller_batch=256,
            controller_samples=2, finetune_epochs=2,
        )
        res = run_mhas(table, cfg)
        assert all("ratio" in h and "iter" in h for h in res.history)
