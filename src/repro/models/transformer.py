"""Decoder-only LM assembly for every assigned family.

Depth is organized into SEGMENTS of repeated block-pattern GROUPS:

* dense/moe/ssm archs: one segment, pattern ``("attn",)`` or ``("rwkv",)``;
* gemma3: pattern = six layers (5 × window-1024 local + 1 global) —
  static per-position windows inside the group keep banded-vs-flash
  selection static under scan;
* recurrentgemma: pattern ``("rglru","rglru","attn")``;
* deepseek: a 3-layer dense-FFN prefix segment + a 58-layer MoE segment.

Each segment's groups run under ``lax.scan`` over stacked params (one
compile per segment regardless of depth); remainder layers that don't
fill a group are unrolled.  KV/recurrent caches are stacked per group
and threaded through the scan as xs/ys.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.config import ModelConfig


# --------------------------------------------------------------------------
# depth plan
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    pattern: Tuple[str, ...]       # block kinds within one group
    windows: Tuple[int, ...]       # per-position window (attn blocks)
    moe: Tuple[bool, ...]          # per-position: MoE FFN?
    groups: int                    # number of scanned groups
    remainder: Tuple[str, ...]     # trailing unrolled block kinds
    rem_windows: Tuple[int, ...]
    rem_moe: Tuple[bool, ...]


def plan_segments(cfg: ModelConfig) -> List[Segment]:
    L_ = cfg.num_layers
    blocks = cfg.layer_blocks
    windows = cfg.layer_window
    moe_flags = tuple(
        cfg.is_moe and i >= cfg.first_dense_layers and blocks[i] == "attn"
        for i in range(L_)
    )
    segs: List[Segment] = []
    if cfg.is_moe and cfg.first_dense_layers:
        fd = cfg.first_dense_layers
        segs.append(
            Segment(
                pattern=blocks[:1] * 1, windows=windows[:1], moe=(False,),
                groups=0, remainder=blocks[:fd], rem_windows=windows[:fd],
                rem_moe=(False,) * fd,
            )
        )
        blocks, windows, moe_flags = blocks[fd:], windows[fd:], moe_flags[fd:]
    # pattern period = lcm of block and window patterns
    import math

    P = math.lcm(len(cfg.block_pattern), len(cfg.window_pattern))
    n = len(blocks)
    groups = n // P
    rem = n - groups * P
    segs.append(
        Segment(
            pattern=blocks[:P],
            windows=windows[:P],
            moe=moe_flags[:P],
            groups=groups,
            remainder=blocks[groups * P :],
            rem_windows=windows[groups * P :],
            rem_moe=moe_flags[groups * P :],
        )
    )
    return segs


# --------------------------------------------------------------------------
# per-block init / apply / cache
# --------------------------------------------------------------------------


def _block_init(rng, cfg: ModelConfig, kind: str, moe: bool) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    r = jax.random.split(rng, 2)
    p: Dict = {"ln1": L.rmsnorm_init(cfg.d_model, dt), "ln2": L.rmsnorm_init(cfg.d_model, dt)}
    if kind == "attn":
        p["attn"] = A.mla_init(r[0], cfg) if cfg.use_mla else A.gqa_init(r[0], cfg)
        p["ffn"] = M.moe_init(r[1], cfg) if moe else L.mlp_init(r[1], cfg.d_model, cfg.d_ff, dt)
    elif kind == "rwkv":
        p["attn"] = S.rwkv_init(r[0], cfg)
        p["ffn"] = S.rwkv_channel_init(r[1], cfg)
    elif kind == "rglru":
        p["attn"] = S.rglru_init(r[0], cfg)
        p["ffn"] = L.mlp_init(r[1], cfg.d_model, cfg.d_ff, dt)
    else:
        raise ValueError(kind)
    return p


def _block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int) -> Dict:
    if kind == "attn":
        if cfg.use_mla:
            c = A.mla_init_cache(cfg, batch, max_len)
        else:
            c = A.gqa_init_cache(cfg, batch, max_len)
        c.pop("len")
        return c
    if kind == "rwkv":
        s = S.rwkv_init_state(cfg, batch)
        return s
    if kind == "rglru":
        return S.rglru_init_state(cfg, batch)
    raise ValueError(kind)


def _block_apply(
    p: Dict,
    cfg: ModelConfig,
    kind: str,
    moe: bool,
    window: int,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cache: Optional[Dict],
    cache_len,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    h_in = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    new_cache = None
    if kind == "attn":
        c = dict(cache, len=cache_len) if cache is not None else None
        if cfg.use_mla:
            h, c2 = A.mla_apply(p["attn"], cfg, h_in, positions, cache=c)
        else:
            h, c2 = A.gqa_apply(p["attn"], cfg, h_in, positions, window=window, cache=c)
        if c2 is not None:
            c2.pop("len")
            new_cache = c2
        x = x + h
        f_in = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        f = M.moe_apply(p["ffn"], cfg, f_in) if moe else L.mlp(p["ffn"], f_in)
        x = x + f
    elif kind == "rwkv":
        st = (
            {"wkv": cache["wkv"], "x_prev": cache["x_prev"]}
            if cache is not None
            else None
        )
        h, st2 = S.rwkv_apply(p["attn"], cfg, h_in, state=st)
        x = x + h
        f_in = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        xp = cache["x_prev_ffn"] if cache is not None else None
        f, xp2 = S.rwkv_channel_apply(p["ffn"], cfg, f_in, x_prev=xp)
        x = x + f
        if cache is not None:
            new_cache = {"wkv": st2["wkv"], "x_prev": st2["x_prev"], "x_prev_ffn": xp2}
    elif kind == "rglru":
        st = cache
        h, st2 = S.rglru_apply(p["attn"], cfg, h_in, state=st)
        x = x + h
        f = L.mlp(p["ffn"], L.rmsnorm(p["ln2"], x, cfg.norm_eps))
        x = x + f
        new_cache = st2
    else:
        raise ValueError(kind)
    return x, new_cache


# --------------------------------------------------------------------------
# the decoder
# --------------------------------------------------------------------------


class DecoderLM:
    """Functional decoder: ``init`` -> params, ``apply`` -> logits,
    ``init_cache``/``decode_step`` for serving."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.segments = plan_segments(cfg)

    @property
    def padded_vocab(self) -> int:
        m = self.cfg.vocab_pad_multiple
        v = self.cfg.vocab_size
        return v if m <= 0 else ((v + m - 1) // m) * m

    # ------------------------------------------------------------- params
    def init(self, seed: int = 0) -> Dict:
        cfg = self.cfg
        rng = jax.random.PRNGKey(seed)
        r_embed, r_head = jax.random.split(jax.random.fold_in(rng, 17), 2)
        dt = jnp.dtype(cfg.dtype)
        vp = self.padded_vocab
        params: Dict = {
            "embed": L.embedding_init(r_embed, vp, cfg.d_model, dt),
            "final_norm": L.rmsnorm_init(cfg.d_model, dt),
            "segments": [],
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(r_head, cfg.d_model, vp, dt)
        for si, seg in enumerate(self.segments):
            seg_params: Dict = {"groups": None, "remainder": []}
            if seg.groups > 0:
                def group_init(r):
                    rs = jax.random.split(r, len(seg.pattern))
                    return [
                        _block_init(rs[i], cfg, seg.pattern[i], seg.moe[i])
                        for i in range(len(seg.pattern))
                    ]

                rngs = jax.random.split(jax.random.fold_in(rng, 100 + si), seg.groups)
                seg_params["groups"] = jax.vmap(group_init)(rngs)
            for ri, kind in enumerate(seg.remainder):
                seg_params["remainder"].append(
                    _block_init(
                        jax.random.fold_in(rng, 1000 + 31 * si + ri),
                        cfg, kind, seg.rem_moe[ri],
                    )
                )
            params["segments"].append(seg_params)
        return params

    # ------------------------------------------------------------- forward
    def apply(
        self,
        params: Dict,
        tokens: jnp.ndarray,
        prefix_embeds: Optional[jnp.ndarray] = None,
        remat: Optional[bool] = None,
    ) -> jnp.ndarray:
        """tokens (B,S) -> logits (B,S,V).  ``prefix_embeds`` (B,P,d)
        replaces the first P token embeddings (modality-frontend stub:
        vision patches / audio frames)."""
        cfg = self.cfg
        x = L.embed(params["embed"], tokens)
        if prefix_embeds is not None:
            P = prefix_embeds.shape[1]
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x[:, P:, :]], axis=1)
        B, S_len, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S_len, dtype=jnp.int32)[None], (B, S_len))
        use_remat = cfg.remat != "none" if remat is None else remat

        x = self._run_blocks(params, x, positions, caches=None, cache_len=None,
                             use_remat=use_remat)[0]
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return self._logits(params, x)

    def _logits(self, params: Dict, x: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.tie_embeddings:
            logits = x @ params["embed"]["table"].T
        else:
            logits = L.dense(params["lm_head"], x)
        logits = L.softcap(logits, cfg.logit_softcap)
        if self.padded_vocab != cfg.vocab_size:
            # mask padded classes (keeps the vocab dim shardable)
            col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
            logits = jnp.where(col < cfg.vocab_size, logits, -1e9)
        return logits

    def _run_blocks(self, params, x, positions, caches, cache_len, use_remat):
        """Shared depth walk for full-sequence and decode paths."""
        cfg = self.cfg
        new_caches: List = []
        for si, seg in enumerate(self.segments):
            seg_params = params["segments"][si]
            seg_cache = caches[si] if caches is not None else None
            new_seg_cache = {"groups": None, "remainder": []}

            if seg.groups > 0:
                def group_body(x, xs):
                    gp, gc = xs
                    outs = []
                    for bi, kind in enumerate(seg.pattern):
                        c = gc[bi] if gc is not None else None
                        x, nc = _block_apply(
                            gp[bi], cfg, kind, seg.moe[bi], seg.windows[bi],
                            x, positions, c, cache_len,
                        )
                        outs.append(nc)
                    return x, outs

                if use_remat:
                    group_body = jax.checkpoint(group_body)

                def scan_fn(x, xs):
                    return group_body(x, xs)

                xs = (
                    (seg_params["groups"], seg_cache["groups"])
                    if seg_cache is not None
                    else (seg_params["groups"], None)
                )
                unroll = seg.groups if cfg.scan_unroll else 1
                if seg_cache is not None:
                    x, group_caches = jax.lax.scan(scan_fn, x, xs, unroll=unroll)
                    new_seg_cache["groups"] = group_caches
                else:
                    def scan_nocache(x, gp):
                        out, _ = group_body(x, (gp, None))
                        return out, None

                    x, _ = jax.lax.scan(
                        scan_nocache, x, seg_params["groups"], unroll=unroll
                    )

            for ri, kind in enumerate(seg.remainder):
                c = seg_cache["remainder"][ri] if seg_cache is not None else None
                x, nc = _block_apply(
                    seg_params["remainder"][ri], cfg, kind, seg.rem_moe[ri],
                    seg.rem_windows[ri], x, positions, c, cache_len,
                )
                new_seg_cache["remainder"].append(nc)
            new_caches.append(new_seg_cache)
        return x, new_caches

    # ------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_len: int) -> Dict:
        cfg = self.cfg
        caches = []
        for seg in self.segments:
            seg_cache: Dict = {"groups": None, "remainder": []}
            if seg.groups > 0:
                def one_group():
                    return [
                        _block_cache(cfg, kind, batch, max_len) for kind in seg.pattern
                    ]

                # stack over groups
                proto = one_group()
                seg_cache["groups"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (seg.groups,) + a.shape), proto
                )
            for kind in seg.remainder:
                seg_cache["remainder"].append(_block_cache(cfg, kind, batch, max_len))
            caches.append(seg_cache)
        return {"layers": caches, "len": jnp.zeros((), jnp.int32)}

    def decode_step(
        self, params: Dict, cache: Dict, tokens: jnp.ndarray
    ) -> Tuple[jnp.ndarray, Dict]:
        """tokens (B,1) one new token per sequence -> (logits (B,1,V), cache)."""
        cfg = self.cfg
        x = L.embed(params["embed"], tokens)
        B = x.shape[0]
        idx = cache["len"]
        positions = jnp.broadcast_to(idx[None, None], (B, 1)).astype(jnp.int32)
        x, new_caches = self._run_blocks(
            params, x, positions, caches=cache["layers"], cache_len=idx,
            use_remat=False,
        )
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return self._logits(params, x), {"layers": new_caches, "len": idx + 1}
