"""Training substrate: optimizers, schedules, checkpointing, fault
tolerance, gradient compression.  Built from scratch (no optax/orbax) —
shared by the DeepMapping mapping-model trainer and the LM train steps.
"""

from repro.train.optimizer import (  # noqa: F401
    OptState,
    adam_init,
    adam_update,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
    exponential_decay,
    warmup_cosine,
)
