"""Hypothesis property tests on system-level invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st
from tpch_reference import assert_aggregate_equal, ref_group_aggregate, ref_join_mask

from repro.baselines import HashStore
from repro.core import DeepMappingConfig, DeepMappingStore, Table
from repro.core.aux_table import AuxTable
from repro.core.bitvector import BitVector
from repro.core.encoding import KeyEncoder, ValueCodec
from repro.core.trainer import TrainConfig
from repro.storage import MemoryPool, get_codec

SET = settings(
    max_examples=30, deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)

#: Store builds inside — far fewer examples, same no-deadline rules.
SET_STORE = settings(
    max_examples=10, deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)

#: Per-example MLP training: keep the example count tight.
SET_MODEL = settings(
    max_examples=5, deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)

TINY_DM = DeepMappingConfig(
    shared=(16,), private=(4,), train=TrainConfig(epochs=2, batch_size=512)
)


@st.composite
def agg_query(draw, columns=("a", "b")):
    """Random group-by/aggregate combo: a (possibly empty) group-key
    subset plus 1-3 aggregates over the value columns."""
    group_by = tuple(draw(st.sets(st.sampled_from(columns), max_size=2)))
    n_aggs = draw(st.integers(1, 3))
    specs, ref = [], []
    for _ in range(n_aggs):
        func = draw(st.sampled_from(["count", "sum", "min", "max"]))
        if func == "count":
            if ("count", None) in ref:
                continue
            specs.append("count")
            ref.append(("count", None))
        else:
            col = draw(st.sampled_from(columns))
            if (func, col) in ref:
                continue
            specs.append((func, col))
            ref.append((func, col))
    return group_by, tuple(specs), tuple(ref)


@st.composite
def int_table(draw, min_rows=4, max_rows=60):
    """Random small table: unique int64 keys, two int32 value columns
    with small domains (negatives included — sum/min/max sign paths)."""
    n = draw(st.integers(min_rows, max_rows))
    keys = draw(st.lists(
        st.integers(0, 3000), min_size=n, max_size=n, unique=True
    ))
    a = draw(st.lists(st.integers(0, 4), min_size=n, max_size=n))
    b = draw(st.lists(st.integers(-3, 3), min_size=n, max_size=n))
    return Table(
        keys=np.asarray(sorted(keys), dtype=np.int64),
        columns={
            "a": np.asarray(a, dtype=np.int32),
            "b": np.asarray(b, dtype=np.int32),
        },
    )


class TestEncodingProperties:
    @SET
    @given(
        keys=st.lists(st.integers(0, 10**12), min_size=1, max_size=50, unique=True),
        base=st.sampled_from([2, 8, 10, 16]),
    )
    def test_digit_decomposition_bijective(self, keys, base):
        keys = np.asarray(keys, dtype=np.int64)
        enc = KeyEncoder(int(keys.max()), base=base)
        d = enc.digits(keys)
        recon = (d[:, : enc._digit_width].astype(np.int64) * enc._divisors).sum(axis=1)
        np.testing.assert_array_equal(recon, keys)
        # distinct keys -> distinct encodings
        assert len(np.unique(d[:, : enc._digit_width], axis=0)) == len(keys)

    @SET
    @given(
        vals=st.lists(
            st.one_of(st.integers(-100, 100), st.text(max_size=5)),
            min_size=1, max_size=60,
        )
    )
    def test_value_codec_roundtrip(self, vals):
        arr = np.asarray([str(v) for v in vals])
        c = ValueCodec("x", arr)
        np.testing.assert_array_equal(c.decode(c.codes), arr)
        assert c.cardinality == len(set(arr.tolist()))


class TestBitvectorProperties:
    @SET
    @given(
        present=st.sets(st.integers(0, 5000), min_size=0, max_size=200),
        probes=st.lists(st.integers(-10, 6000), min_size=1, max_size=100),
    )
    def test_membership_equals_set(self, present, probes):
        bv = BitVector.from_keys(np.fromiter(present, np.int64, len(present)),
                                 capacity=5001)
        got = bv.test(np.asarray(probes, dtype=np.int64))
        want = np.asarray([p in present for p in probes])
        np.testing.assert_array_equal(got, want)

    @SET
    @given(present=st.sets(st.integers(0, 2000), min_size=1, max_size=100))
    def test_serialization_identity(self, present):
        bv = BitVector.from_keys(np.fromiter(present, np.int64, len(present)))
        bv2 = BitVector.from_bytes(bv.to_bytes())
        assert bv.count() == bv2.count()


class TestAuxTableProperties:
    @SET
    @given(
        rows=st.dictionaries(
            st.integers(0, 10**6),
            st.tuples(st.integers(0, 99), st.integers(0, 99)),
            min_size=1, max_size=80,
        ),
        codec=st.sampled_from(["zstd", "none", "gzip"]),
        part=st.sampled_from([64, 256, 4096]),
    )
    def test_aux_is_exact_map(self, rows, codec, part):
        keys = np.fromiter(rows.keys(), np.int64, len(rows))
        codes = np.asarray([rows[int(k)] for k in keys], dtype=np.int32)
        aux = AuxTable.build(keys, codes, codec=codec, partition_bytes=part)
        found, got = aux.get(keys)
        assert found.all()
        np.testing.assert_array_equal(got, codes)
        absent = np.asarray([10**6 + 1, 10**6 + 2], dtype=np.int64)
        f2, _ = aux.get(absent)
        assert not f2.any()

    @SET
    @given(
        rows=st.dictionaries(
            st.integers(0, 10**4), st.integers(0, 9), min_size=2, max_size=50
        ),
        ops=st.lists(st.integers(0, 2), min_size=1, max_size=10),
    )
    def test_mutations_then_compact_is_identity(self, rows, ops):
        keys = np.fromiter(rows.keys(), np.int64, len(rows))
        codes = np.asarray([[rows[int(k)]] for k in keys], dtype=np.int32)
        aux = AuxTable.build(keys, codes)
        model = {int(k): int(v[0]) for k, v in zip(keys, codes)}
        rng = np.random.default_rng(len(rows))
        for op in ops:
            k = int(rng.choice(keys))
            if op == 0:
                nk = int(rng.integers(10**5, 10**6))
                aux.add(np.asarray([nk]), np.asarray([[7]], dtype=np.int32))
                model[nk] = 7
            elif op == 1 and k in model:
                aux.remove(np.asarray([k]))
                model.pop(k, None)
            else:
                aux.update(np.asarray([k]), np.asarray([[3]], dtype=np.int32))
                model[k] = 3
        before = {k: None for k in model}
        probe = np.fromiter(model.keys(), np.int64, len(model))
        f, got = aux.get(probe)
        assert f.all()
        np.testing.assert_array_equal(got[:, 0], [model[int(k)] for k in probe])
        aux.compact()
        f2, got2 = aux.get(probe)
        np.testing.assert_array_equal(got, got2)
        assert f2.all()


class TestCodecProperties:
    @SET
    @given(
        data=st.binary(min_size=0, max_size=5000),
        name=st.sampled_from(["zstd", "zstd1", "gzip", "lzma", "zlib", "none"]),
    )
    def test_codec_roundtrip(self, data, name):
        c = get_codec(name)
        assert c.decompress(c.compress(data)) == data


class TestAggregateJoinProperties:
    """ISSUE 10: random tables x random group/agg/join/predicate
    combos, every executor answer ≡ the naive reference."""

    @SET_STORE
    @given(table=int_table(), data=st.data())
    def test_rowspace_aggregate_matches_oracle(self, table, data):
        """Store-hook aggregation (baseline decode-then-aggregate path)
        over a random table/query combo ≡ the oracle, pushdown on+off."""
        store = HashStore.build(table, codec="none", partition_bytes=512)
        group_by, specs, ref = data.draw(agg_query())
        sel = None
        q = store.query().group_by(*group_by).agg(*specs)
        if data.draw(st.booleans()):
            cut = data.draw(st.integers(-3, 4))
            q = q.where("b", "<", cut)
            sel = table.columns["b"] < cut
        if data.draw(st.booleans()):
            q = q.pushdown(False)
        groups, aggs = ref_group_aggregate(table.columns, group_by, ref, sel)
        assert_aggregate_equal(q.scan().execute(), groups, aggs)

    @SET_MODEL
    @given(table=int_table(min_rows=24, max_rows=48), data=st.data())
    def test_codespace_equals_reference_after_mutations(self, table, data):
        """Code-space aggregation on the model-backed store stays
        value-identical to decode-then-aggregate after interleaved
        insert/delete/update (stale code→value tables would diverge)."""
        store = DeepMappingStore.build(table, TINY_DM)
        model = {
            int(k): {c: int(table.columns[c][i]) for c in table.columns}
            for i, k in enumerate(table.keys)
        }
        n_ops = data.draw(st.integers(1, 4))
        for _ in range(n_ops):
            op = data.draw(st.sampled_from(["insert", "update", "delete"]))
            if op == "insert":
                k = data.draw(st.integers(5000, 6000))
                va = data.draw(st.integers(0, 9))
                vb = data.draw(st.integers(-5, 5))
                store.insert(
                    np.asarray([k], dtype=np.int64),
                    {"a": np.asarray([va], np.int32),
                     "b": np.asarray([vb], np.int32)},
                )
                model[k] = {"a": va, "b": vb}
            elif op == "update" and model:
                k = data.draw(st.sampled_from(sorted(model)))
                va = data.draw(st.integers(0, 9))
                store.update(
                    np.asarray([k], dtype=np.int64),
                    {"a": np.asarray([va], np.int32),
                     "b": np.asarray([model[k]["b"]], np.int32)},
                )
                model[k]["a"] = va
            elif op == "delete" and len(model) > 2:
                k = data.draw(st.sampled_from(sorted(model)))
                store.delete(np.asarray([k], dtype=np.int64))
                del model[k]
        live = sorted(model)
        logical = {
            c: np.asarray([model[k][c] for k in live], dtype=np.int32)
            for c in ("a", "b")
        }
        group_by, specs, ref = data.draw(agg_query())
        code = store.query().group_by(*group_by).agg(*specs).scan().execute()
        rows = (
            store.query().group_by(*group_by).agg(*specs)
            .pushdown(False).scan().execute()
        )
        groups, aggs = ref_group_aggregate(logical, group_by, ref)
        assert_aggregate_equal(code, groups, aggs)
        assert_aggregate_equal(rows, groups, aggs)
        assert code.explain.rows_decoded <= rows.explain.rows_decoded

    @SET_STORE
    @given(
        left=int_table(), right_keys=st.sets(
            st.integers(0, 500), min_size=1, max_size=80
        ),
        div=st.integers(1, 7),
    )
    def test_join_matches_set_oracle(self, left, right_keys, div):
        """Key-equi join survivors ≡ the python-set membership oracle
        for a random left table, right key set, and key map."""
        rkeys = np.asarray(sorted(right_keys), dtype=np.int64)
        right = HashStore.build(
            Table(keys=rkeys, columns={
                "r": (rkeys % 5).astype(np.int32),
            }),
            codec="none", partition_bytes=512,
        )
        lstore = HashStore.build(left, codec="none", partition_bytes=512)
        key_fn = lambda k: k // div  # noqa: E731
        res = lstore.query().join(right, key=key_fn).scan().execute()
        mask = ref_join_mask(left.keys, key_fn, rkeys)
        np.testing.assert_array_equal(res.keys, left.keys[mask])
        np.testing.assert_array_equal(
            np.asarray(res.values["r"]),
            ((left.keys[mask] // div) % 5).astype(np.int32),
        )


class TestMemoryPoolProperties:
    @SET
    @given(
        sizes=st.lists(st.integers(1, 500), min_size=1, max_size=30),
        budget=st.integers(100, 2000),
    )
    def test_budget_never_exceeded(self, sizes, budget):
        pool = MemoryPool(budget)
        for i, s in enumerate(sizes):
            pool.get(i, lambda s=s: (bytes(s), s))
            assert pool.used_bytes <= budget
