import os

import numpy as np
import pytest

from conftest import make_periodic_table, make_random_table
from repro.core import DeepMappingConfig, DeepMappingStore
from repro.core.serialize import load_store, save_store
from repro.core.trainer import TrainConfig

FAST = DeepMappingConfig(
    shared=(64, 64), private=(16,), train=TrainConfig(epochs=25, batch_size=512)
)


class TestBuildAndLookup:
    def test_lossless_on_all_keys(self, small_store):
        """Desideratum #1: 100% accuracy regardless of model quality."""
        table, store = small_store
        vals, exists = store.lookup(table.keys)
        assert exists.all()
        for name, col in table.columns.items():
            np.testing.assert_array_equal(vals[name], col)

    def test_no_spurious_results(self, small_store):
        """Non-existing keys must return NULL (no hallucination)."""
        table, store = small_store
        missing = table.keys[:64] + 1  # stride-2 keys -> odd keys absent
        _, exists = store.lookup(missing)
        assert not exists.any()

    def test_low_correlation_data_still_lossless(self):
        table = make_random_table(n=400)
        store = DeepMappingStore.build(table, FAST)
        vals, exists = store.lookup(table.keys)
        assert exists.all()
        np.testing.assert_array_equal(vals["col0"], table.columns["col0"])

    def test_column_projection(self, small_store):
        table, store = small_store
        vals, _ = store.lookup(table.keys[:10], columns=("col1",))
        assert set(vals) == {"col1"}

    def test_eq1_accounting(self, small_store):
        _, store = small_store
        bd = store.size_breakdown()
        assert set(bd) == {"model", "aux_table", "exist_bitvector", "decode_map"}
        assert store.size_bytes() == sum(bd.values())
        assert store.compression_ratio() == store.size_bytes() / store.raw_bytes

    def test_stats_breakdown_populated(self, small_store):
        table, store = small_store
        res = store.query().where_keys(table.keys[:100]).execute()
        s = res.explain
        assert s.total_s > 0 and s.infer_s >= 0 and s.aux_s >= 0

    def test_last_stats_side_channel_removed(self, small_store):
        """The mutable ``last_stats`` side-channel is gone; ExplainStats
        (and the metrics registry) are the only stats surfaces."""
        table, store = small_store
        store.lookup(table.keys[:10])
        assert not hasattr(store, "last_stats")


class TestModifications:
    @pytest.fixture()
    def store(self):
        return DeepMappingStore.build(make_periodic_table(n=600), FAST)

    def test_insert_lookup(self, store):
        cap = store.vexist.capacity
        keys = np.array([cap + 5, cap + 6], dtype=np.int64)
        cols = {"col0": np.array([1, 2], np.int32), "col1": np.array([0, 1], np.int32)}
        store.insert(keys, cols)
        vals, exists = store.lookup(keys)
        assert exists.all()
        np.testing.assert_array_equal(vals["col0"], cols["col0"])

    def test_insert_existing_raises(self, store):
        k = np.array([0], dtype=np.int64)
        with pytest.raises(ValueError):
            store.insert(k, {"col0": np.array([1]), "col1": np.array([1])})

    def test_insert_unseen_category(self, store):
        keys = np.array([10**6], dtype=np.int64)
        store.insert(keys, {"col0": np.array([99], np.int32), "col1": np.array([0], np.int32)})
        vals, exists = store.lookup(keys)
        assert exists.all() and vals["col0"][0] == 99

    def test_delete(self, store):
        k = np.array([0, 2], dtype=np.int64)
        n0 = store.num_rows
        store.delete(k)
        _, exists = store.lookup(k)
        assert not exists.any()
        assert store.num_rows == n0 - 2
        store.delete(k)  # idempotent
        assert store.num_rows == n0 - 2

    def test_update(self, store):
        k = np.array([0], dtype=np.int64)
        store.update(k, {"col0": np.array([3], np.int32), "col1": np.array([2], np.int32)})
        vals, exists = store.lookup(k)
        assert exists.all() and vals["col0"][0] == 3 and vals["col1"][0] == 2

    def test_update_nonexistent_raises(self, store):
        with pytest.raises(ValueError):
            store.update(
                np.array([10**7]), {"col0": np.array([1]), "col1": np.array([1])}
            )

    def test_retrain_trigger_and_rebuild(self):
        cfg = DeepMappingConfig(
            shared=(64,),
            private=(),
            train=TrainConfig(epochs=15, batch_size=512),
            retrain_after_modified_bytes=1,
        )
        store = DeepMappingStore.build(make_periodic_table(n=400), cfg)
        assert not store.should_retrain()
        cap = store.vexist.capacity
        store.insert(
            np.array([cap + 1], dtype=np.int64),
            {"col0": np.array([0], np.int32), "col1": np.array([0], np.int32)},
        )
        assert store.should_retrain()
        new = store.retrain()
        _, exists = new.lookup(np.array([cap + 1], dtype=np.int64))
        assert exists.all()
        assert new.num_rows == store.num_rows

    def test_mixed_workload_consistency(self, store):
        """Insert+update+delete interleaved; final state must be exact."""
        rng = np.random.default_rng(3)
        cap = store.vexist.capacity
        ins = np.arange(cap + 10, cap + 60, dtype=np.int64)
        store.insert(
            ins,
            {
                "col0": rng.integers(0, 5, 50).astype(np.int32),
                "col1": rng.integers(0, 3, 50).astype(np.int32),
            },
        )
        upd_vals = {
            "col0": rng.integers(0, 5, 25).astype(np.int32),
            "col1": rng.integers(0, 3, 25).astype(np.int32),
        }
        store.update(ins[:25], upd_vals)
        store.delete(ins[25:40])
        vals, exists = store.lookup(ins)
        assert exists[:25].all() and not exists[25:40].any() and exists[40:].all()
        np.testing.assert_array_equal(vals["col0"][:25], upd_vals["col0"])


class TestPipelinedLookupConformance:
    """The engine pipeline (cached weights, bucketing, dispatch/collect,
    fused kernel) must be invisible: lookup results byte-identical to
    the reference staged composition, including after interleaved
    modifications, on both the Pallas and jit paths."""

    @staticmethod
    def _reference_lookup(store, keys):
        """The seed repo's staged path, recomposed from primitives:
        host digits + jnp forward + host exist + aux merge + decode."""
        from repro.kernels.ref import ref_fused_lookup

        keys = np.asarray(keys, dtype=np.int64)
        pred, exists = ref_fused_lookup(
            store.params, keys, store.encoder, store.vexist, store.spec
        )
        exist_idx = np.flatnonzero(exists)
        found, aux_codes = store.aux.get(keys[exist_idx])
        pred[exist_idx[found]] = aux_codes[found]
        values = {
            t: store.codecs[t].decode(np.where(exists, pred[:, i], 0))
            for i, t in enumerate(store.spec.tasks)
        }
        return values, exists

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_byte_identical_after_interleaved_mods(self, use_pallas):
        table = make_periodic_table(n=700)
        cfg = DeepMappingConfig(
            shared=(48,), private=(16,),
            train=TrainConfig(epochs=10, batch_size=256),
            use_pallas=use_pallas,
            inference_batch=256,  # several pipeline chunks per lookup
        )
        store = DeepMappingStore.build(table, cfg)
        rng = np.random.default_rng(0)
        cap = store.vexist.capacity
        ins = np.arange(cap + 3, cap + 40, dtype=np.int64)
        store.insert(ins, {
            "col0": rng.integers(0, 5, ins.size).astype(np.int32),
            "col1": rng.integers(0, 3, ins.size).astype(np.int32),
        })
        store.update(np.concatenate([table.keys[:20], ins[:5]]), {
            "col0": rng.integers(0, 5, 25).astype(np.int32),
            "col1": rng.integers(0, 3, 25).astype(np.int32),
        })
        store.delete(np.concatenate([table.keys[30:40], ins[30:]]))

        probe = np.concatenate([
            table.keys, ins, ins + 1, np.array([cap + 10**6, 2**40], np.int64)
        ])
        got_vals, got_exists = store.lookup(probe)
        want_vals, want_exists = self._reference_lookup(store, probe)
        np.testing.assert_array_equal(got_exists, want_exists)
        for c in want_vals:
            np.testing.assert_array_equal(got_vals[c], want_vals[c])

    def test_pallas_and_jit_paths_agree(self):
        table = make_periodic_table(n=500)
        kw = dict(shared=(48,), private=(16,),
                  train=TrainConfig(epochs=10, batch_size=256))
        a = DeepMappingStore.build(table, DeepMappingConfig(use_pallas=True, **kw))
        b = DeepMappingStore.build(table, DeepMappingConfig(use_pallas=False, **kw))
        keys = np.concatenate([table.keys, table.keys[:50] + 1])
        va, ea = a.lookup(keys)
        vb, eb = b.lookup(keys)
        np.testing.assert_array_equal(ea, eb)
        for c in va:
            np.testing.assert_array_equal(va[c], vb[c])

    def test_engine_weight_cache_warm_from_build(self):
        table = make_periodic_table(n=400)
        store = DeepMappingStore.build(table, FAST)
        # build's misclassification evaluation already populated the
        # all-tasks entry; lookups must not re-pad
        misses0 = store.engine.stats.weight_cache_misses
        store.lookup(table.keys[:100])
        store.lookup(table.keys[:200])
        assert store.engine.stats.weight_cache_misses == misses0

    def test_bucketed_compiles_across_batch_sizes(self):
        table = make_periodic_table(n=600)
        store = DeepMappingStore.build(table, FAST)
        for n in (1, 3, 17, 40, 77, 130, 200, 311, 400, 555):
            store.lookup(table.keys[:n])
        assert store.engine.stats.compiles <= 6


class TestSerialization:
    def test_roundtrip(self, small_store, tmp_path):
        table, store = small_store
        p = os.path.join(tmp_path, "store")
        save_store(store, p)
        s2 = load_store(p)
        v1, e1 = store.lookup(table.keys[:200])
        v2, e2 = s2.lookup(table.keys[:200])
        np.testing.assert_array_equal(e1, e2)
        for c in v1:
            np.testing.assert_array_equal(v1[c], v2[c])

    def test_atomicity_tmp_cleanup(self, small_store, tmp_path):
        _, store = small_store
        p = os.path.join(tmp_path, "store")
        save_store(store, p)
        save_store(store, p)  # overwrite is atomic
        assert not os.path.exists(p + ".tmp")
