"""Optimizers and LR schedules, pure JAX on pytrees.

Written from scratch (optax is not a dependency).  The API is a pair of
``init``/``update`` functions over arbitrary pytrees plus a tiny
``GradientTransform`` combinator so train steps can compose clipping,
weight decay and the base rule — enough surface for both the paper's
mapping-model trainer (Adam, lr 1e-3, decay 0.999 — §V-A6) and the LM
substrate (AdamW + warmup-cosine).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray  # scalar int32
    mu: object  # first-moment pytree
    nu: object  # second-moment pytree


def _zeros_like_tree(params):
    return jax.tree.map(jnp.zeros_like, params)


def adam_init(params) -> OptState:
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=_zeros_like_tree(params),
        nu=_zeros_like_tree(params),
    )


def adam_update(
    grads,
    state: OptState,
    params,
    lr: jnp.ndarray | float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
):
    """One AdamW step. Returns (new_params, new_state).

    ``weight_decay`` is decoupled (AdamW); 0 recovers plain Adam, which
    is what the paper's §V-A6 training uses.
    """
    step = state.step + 1
    stepf = step.astype(jnp.float32)
    bc1 = 1.0 - jnp.power(b1, stepf)
    bc2 = 1.0 - jnp.power(b2, stepf)

    mu = jax.tree.map(lambda m, g: b1 * m + (1.0 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1.0 - b2) * (g * g), state.nu, grads)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p
        return (p - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(step=step, mu=mu, nu=nu)


@dataclasses.dataclass(frozen=True)
class adamw:  # noqa: N801 — factory with function-like name
    """Bound AdamW rule: ``opt = adamw(lr=...); opt.init / opt.update``."""

    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    max_grad_norm: Optional[float] = None

    def init(self, params) -> OptState:
        return adam_init(params)

    def update(self, grads, state: OptState, params):
        if self.max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, self.max_grad_norm)
        lr = self.lr(state.step) if callable(self.lr) else self.lr
        return adam_update(
            grads,
            state,
            params,
            lr=lr,
            b1=self.b1,
            b2=self.b2,
            eps=self.eps,
            weight_decay=self.weight_decay,
        )


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


# -- schedules ---------------------------------------------------------------


def exponential_decay(base_lr: float, decay: float) -> Callable:
    """Paper §V-A6: model lr 0.001 decayed by 0.999 per iteration."""

    def sched(step):
        return base_lr * jnp.power(decay, step.astype(jnp.float32))

    return sched


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1) -> Callable:
    def sched(step):
        frac = jnp.clip(step.astype(jnp.float32) / max(1, total_steps), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return base_lr * (final_frac + (1.0 - final_frac) * cos)

    return sched


def warmup_cosine(
    base_lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1
) -> Callable:
    cos = cosine_schedule(base_lr, max(1, total_steps - warmup_steps), final_frac)

    def sched(step):
        stepf = step.astype(jnp.float32)
        warm = base_lr * stepf / max(1, warmup_steps)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))

    return sched
