import numpy as np
import pytest

from repro.baselines import BASELINE_FACTORIES, ArrayStore, HashStore
from repro.data import synthetic_multi_column
from repro.data.tpch import orders_like
from repro.storage import MemoryPool


@pytest.fixture(scope="module")
def table():
    return synthetic_multi_column(n=5000, correlation="high", seed=1)


@pytest.fixture(scope="module")
def string_table():
    return orders_like(n=2000)


class TestBaselineStores:
    @pytest.mark.parametrize("name", sorted(BASELINE_FACTORIES))
    def test_exact_lookup_all(self, name, table):
        store = BASELINE_FACTORIES[name](table, partition_bytes=4096)
        q = table.keys[:: max(1, table.num_rows // 500)]
        vals, exists = store.lookup(q)
        assert exists.all()
        for col in table.columns:
            np.testing.assert_array_equal(vals[col], table.columns[col][:: max(1, table.num_rows // 500)])

    @pytest.mark.parametrize("name", ["AB", "ABC-Z", "HB", "HBC-Z"])
    def test_missing_keys(self, name, table):
        store = BASELINE_FACTORIES[name](table, partition_bytes=4096)
        missing = np.array([table.max_key + 10, table.max_key + 11], dtype=np.int64)
        _, exists = store.lookup(missing)
        assert not exists.any()

    @pytest.mark.parametrize("name", ["ABC-Z", "ABC-L", "ABC-G", "ABC-D"])
    def test_compression_shrinks(self, name, table):
        ab = BASELINE_FACTORIES["AB"](table, partition_bytes=65536)
        abc = BASELINE_FACTORIES[name](table, partition_bytes=65536)
        assert abc.size_bytes() < ab.size_bytes()

    def test_string_columns(self, string_table):
        for name in ["AB", "ABC-Z", "HB"]:
            store = BASELINE_FACTORIES[name](string_table, partition_bytes=8192)
            q = string_table.keys[:100]
            vals, exists = store.lookup(q)
            assert exists.all()
            got = vals["o_orderstatus"].astype(str)
            np.testing.assert_array_equal(
                got, string_table.columns["o_orderstatus"][:100].astype(str)
            )

    def test_shared_pool_pressure(self, table):
        pool = MemoryPool(budget_bytes=16 * 1024)
        store = ArrayStore.build(table, codec="zstd", partition_bytes=4096, pool=pool)
        vals, exists = store.lookup(table.keys)
        assert exists.all()
        assert pool.evictions > 0

    def test_hash_store_partition_count(self, table):
        hs = HashStore.build(table, codec="none", partition_bytes=2048)
        assert len(hs._partitions) > 1

    def test_column_projection(self, table):
        store = ArrayStore.build(table, codec="zstd")
        vals, _ = store.lookup(table.keys[:10], columns=["v0"])
        assert set(vals) == {"v0"}
