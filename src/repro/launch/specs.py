"""ShapeDtypeStruct input factories for every (arch × shape) dry-run
cell — weak-type-correct, shardable, zero allocation.

Shape semantics (DESIGN.md §5): ``train_4k``/``prefill_32k`` lower the
full forward; ``decode_32k``/``long_500k`` lower ``decode_step`` with a
cache of ``seq_len``.  Enc-dec splits: train 2048/2048, prefill
32768 frames + 1024 dec, decode vs dec-KV ``seq_len`` + 4096 cross-KV.
VLM cells prepend 576 stub patch embeddings.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_arch
from repro.serve.serve_step import make_cache_factory
from repro.train.optimizer import adamw
from repro.train.train_step import init_state

NUM_PATCHES = 576
ENCDEC_DECODE_ENC_LEN = 4096


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(arch_id: str, shape_id: str) -> Dict:
    """Batch input ShapeDtypeStructs for one cell (tokens/frames/embeds
    for train/prefill; tokens for decode — the cache comes from
    :func:`cache_specs`)."""
    cfg = get_arch(arch_id).config
    sh = SHAPES[shape_id]
    S, B, kind = sh["seq_len"], sh["global_batch"], sh["kind"]
    act_dt = cfg.dtype

    if cfg.is_encoder_decoder:
        if kind == "train":
            return {
                "frames": _sds((B, S // 2, cfg.d_model), act_dt),
                "tokens": _sds((B, S // 2), jnp.int32),
            }
        if kind == "prefill":
            return {
                "frames": _sds((B, S, cfg.d_model), act_dt),
                "tokens": _sds((B, 1024), jnp.int32),
            }
        return {"tokens": _sds((B, 1), jnp.int32)}

    if kind in ("train", "prefill"):
        spec = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.modality == "vision":
            spec["patch_embeds"] = _sds((B, NUM_PATCHES, cfg.d_model), act_dt)
        return spec
    return {"tokens": _sds((B, 1), jnp.int32)}


def cache_specs(arch_id: str, shape_id: str) -> Dict:
    """Decode-cell cache ShapeDtypeStructs via eval_shape (no alloc)."""
    cfg = get_arch(arch_id).config
    sh = SHAPES[shape_id]
    S, B = sh["seq_len"], sh["global_batch"]
    factory = make_cache_factory(cfg)
    if cfg.is_encoder_decoder:
        return jax.eval_shape(
            lambda: factory(B, max_len=S, enc_len=ENCDEC_DECODE_ENC_LEN)
        )
    return jax.eval_shape(lambda: factory(batch=B, max_len=S))


def state_specs(arch_id: str, optimizer: adamw):
    """TrainState ShapeDtypeStructs via eval_shape (no alloc)."""
    cfg = get_arch(arch_id).config
    return jax.eval_shape(lambda: init_state(cfg, optimizer, seed=0))


def params_specs(arch_id: str):
    cfg = get_arch(arch_id).config
    from repro.models import DecoderLM, EncDecLM

    model = EncDecLM(cfg) if cfg.is_encoder_decoder else DecoderLM(cfg)
    return jax.eval_shape(lambda: model.init(0))
