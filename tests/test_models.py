"""Model substrate correctness: decode-with-cache must reproduce the
full teacher-forced forward, banded window attention must equal flash
with a window mask, and MoE must match a per-token dense reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import DecoderLM, EncDecLM, ModelConfig
from repro.models import attention as A
from repro.models import moe as M


def f32(**kw):
    kw.setdefault("dtype", "float32")
    kw.setdefault("remat", "none")
    return ModelConfig(**kw)


DECODE_EQUIV_CONFIGS = [
    f32(name="dense", family="dense", num_layers=3, d_model=32, num_heads=4,
        num_kv_heads=2, d_ff=64, vocab_size=50),
    f32(name="windowed", family="dense", num_layers=4, d_model=32, num_heads=2,
        num_kv_heads=1, d_ff=64, vocab_size=50, window_pattern=(4, 0)),
    f32(name="rwkv", family="ssm", num_layers=2, d_model=24, num_heads=3,
        num_kv_heads=3, d_ff=48, vocab_size=50, block_pattern=("rwkv",)),
    f32(name="rglru", family="hybrid", num_layers=3, d_model=24, num_heads=2,
        num_kv_heads=1, d_ff=48, vocab_size=50,
        block_pattern=("rglru", "rglru", "attn"), window_pattern=(0, 0, 4)),
    f32(name="mla", family="moe", num_layers=2, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=50, use_mla=True, q_lora_rank=16,
        kv_lora_rank=8, qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8,
        num_experts=4, experts_per_token=2, moe_d_ff=16, first_dense_layers=1,
        capacity_factor=8.0),
]


class TestDecodeEquivalence:
    @pytest.mark.parametrize("cfg", DECODE_EQUIV_CONFIGS, ids=lambda c: c.name)
    def test_decode_matches_forward(self, cfg):
        m = DecoderLM(cfg)
        params = m.init(seed=0)
        B, S = 2, 8
        toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size, (B, S)))
        full = m.apply(params, toks, remat=False)

        cache = m.init_cache(batch=B, max_len=S)
        outs = []
        for t in range(S):
            lg, cache = m.decode_step(params, cache, toks[:, t : t + 1])
            outs.append(lg[:, 0])
        dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-3, atol=2e-3)

    def test_encdec_decode_matches_forward(self):
        cfg = f32(name="ed", family="encdec", num_layers=4, d_model=24, num_heads=2,
                  num_kv_heads=2, d_ff=48, vocab_size=40, is_encoder_decoder=True,
                  enc_layers=2, dec_layers=2)
        m = EncDecLM(cfg)
        params = m.init(0)
        B, Se, Sd = 2, 6, 5
        rng = np.random.default_rng(1)
        frames = jnp.asarray(rng.normal(size=(B, Se, cfg.d_model)).astype(np.float32))
        toks = jnp.asarray(rng.integers(0, 40, (B, Sd)))
        full = m.apply(params, frames, toks, remat=False)
        cache = m.prime_cache(params, m.init_cache(B, Sd, Se), frames)
        outs = []
        for t in range(Sd):
            lg, cache = m.decode_step(params, cache, toks[:, t : t + 1])
            outs.append(lg[:, 0])
        dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-3, atol=2e-3)


class TestAttentionVariants:
    def test_banded_equals_flash_window(self):
        cfg = f32(name="w", family="dense", num_layers=1, d_model=32, num_heads=4,
                  num_kv_heads=2, d_ff=64, vocab_size=10)
        p = A.gqa_init(jax.random.PRNGKey(0), cfg)
        B, S, w = 2, 16, 4
        x = jnp.asarray(np.random.default_rng(2).normal(size=(B, S, 32)).astype(np.float32))
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
        # banded path triggers when S % window == 0 and S > window
        out_banded, _ = A.gqa_apply(p, cfg, x, pos, window=w)
        # force flash path with tiny kv_chunk
        q = None
        out_flash, _ = A.gqa_apply(p, cfg, x, pos, window=w, kv_chunk=3)
        np.testing.assert_allclose(
            np.asarray(out_banded), np.asarray(out_flash), rtol=1e-4, atol=1e-4
        )

    def test_flash_chunk_invariance(self):
        cfg = f32(name="f", family="dense", num_layers=1, d_model=16, num_heads=2,
                  num_kv_heads=2, d_ff=32, vocab_size=10)
        p = A.gqa_init(jax.random.PRNGKey(1), cfg)
        B, S = 1, 13
        x = jnp.asarray(np.random.default_rng(3).normal(size=(B, S, 16)).astype(np.float32))
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
        a, _ = A.gqa_apply(p, cfg, x, pos, kv_chunk=2)
        b, _ = A.gqa_apply(p, cfg, x, pos, kv_chunk=64)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)

    def test_bidirectional_differs_from_causal(self):
        cfg = f32(name="b", family="dense", num_layers=1, d_model=16, num_heads=2,
                  num_kv_heads=2, d_ff=32, vocab_size=10)
        p = A.gqa_init(jax.random.PRNGKey(2), cfg)
        x = jnp.asarray(np.random.default_rng(4).normal(size=(1, 6, 16)).astype(np.float32))
        pos = jnp.arange(6)[None].astype(jnp.int32)
        causal, _ = A.gqa_apply(p, cfg, x, pos)
        bidir, _ = A.gqa_apply(p, cfg, x, pos, causal=False)
        assert not np.allclose(np.asarray(causal[:, 0]), np.asarray(bidir[:, 0]))
        # last position sees everything in both
        np.testing.assert_allclose(
            np.asarray(causal[:, -1]), np.asarray(bidir[:, -1]), rtol=1e-4, atol=1e-4
        )


class TestMoE:
    def test_matches_dense_reference_when_capacity_ample(self):
        cfg = f32(name="m", family="moe", num_layers=1, d_model=16, num_heads=2,
                  num_kv_heads=2, d_ff=32, vocab_size=10, num_experts=4,
                  experts_per_token=2, moe_d_ff=24, capacity_factor=16.0)
        p = M.moe_init(jax.random.PRNGKey(3), cfg)
        B, S = 2, 5
        x = jnp.asarray(np.random.default_rng(5).normal(size=(B, S, 16)).astype(np.float32))
        got = np.asarray(M.moe_apply(p, cfg, x))

        # per-token dense reference
        xf = np.asarray(x).reshape(-1, 16)
        logits = xf @ np.asarray(p["router"]["w"], dtype=np.float32)
        probs = np.exp(logits - logits.max(-1, keepdims=True))
        probs /= probs.sum(-1, keepdims=True)
        want = np.zeros_like(xf)
        wg = np.asarray(p["w_gate"], np.float32)
        wu = np.asarray(p["w_up"], np.float32)
        wd = np.asarray(p["w_down"], np.float32)
        for t in range(xf.shape[0]):
            topk = np.argsort(probs[t])[::-1][:2]
            w = probs[t][topk] / probs[t][topk].sum()
            for e, wt in zip(topk, w):
                h = xf[t] @ wg[e]
                h = (h / (1 + np.exp(-h))) * (xf[t] @ wu[e])
                want[t] += wt * (h @ wd[e])
        np.testing.assert_allclose(got.reshape(-1, 16), want, rtol=2e-3, atol=2e-3)

    def test_capacity_drops_overflow(self):
        cfg = f32(name="m2", family="moe", num_layers=1, d_model=8, num_heads=2,
                  num_kv_heads=2, d_ff=16, vocab_size=10, num_experts=2,
                  experts_per_token=1, moe_d_ff=8, capacity_factor=0.25)
        p = M.moe_init(jax.random.PRNGKey(4), cfg)
        x = jnp.ones((1, 64, 8), jnp.float32)
        out = M.moe_apply(p, cfg, x)  # must not error; some tokens dropped
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_blocked_dispatch_matches_global(self):
        """§Perf block-local dispatch must equal the global path when
        per-block capacity is ample."""
        import dataclasses

        cfg = f32(name="mb", family="moe", num_layers=1, d_model=16, num_heads=2,
                  num_kv_heads=2, d_ff=32, vocab_size=10, num_experts=4,
                  experts_per_token=2, moe_d_ff=24, capacity_factor=16.0)
        p = M.moe_init(jax.random.PRNGKey(3), cfg)
        x = jnp.asarray(np.random.default_rng(5).normal(size=(2, 8, 16)).astype(np.float32))
        global_out = M.moe_apply(p, cfg, x)
        blocked_out = M.moe_apply(p, dataclasses.replace(cfg, moe_block_dispatch=4), x)
        np.testing.assert_allclose(
            np.asarray(global_out), np.asarray(blocked_out), rtol=2e-4, atol=2e-4
        )
        g = jax.grad(
            lambda pp: float(0) + jnp.sum(
                M.moe_apply(pp, dataclasses.replace(cfg, moe_block_dispatch=4), x) ** 2
            )
        )(p)
        assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))

    def test_load_balance_loss_finite(self):
        cfg = f32(name="m3", family="moe", num_layers=1, d_model=8, num_heads=2,
                  num_kv_heads=2, d_ff=16, vocab_size=10, num_experts=4,
                  experts_per_token=2, moe_d_ff=8)
        p = M.moe_init(jax.random.PRNGKey(5), cfg)
        x = jnp.asarray(np.random.default_rng(6).normal(size=(2, 8, 8)).astype(np.float32))
        loss = M.aux_load_balance_loss(p, cfg, x)
        assert bool(jnp.isfinite(loss)) and float(loss) > 0


class TestConfigAccounting:
    def test_param_estimate_close_to_actual(self):
        cfg = f32(name="acc", family="dense", num_layers=3, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=500)
        m = DecoderLM(cfg)
        params = m.init(0)
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        est = cfg.param_count_estimate()
        assert abs(actual - est) / actual < 0.05  # norms/bias slack

    def test_moe_active_params_smaller(self):
        cfg = f32(name="am", family="moe", num_layers=4, d_model=32, num_heads=2,
                  num_kv_heads=2, d_ff=64, vocab_size=100, num_experts=8,
                  experts_per_token=2, moe_d_ff=64)
        assert cfg.active_param_count_estimate() < cfg.param_count_estimate()
