"""Paper Fig. 9 (compression ratio over the MHAS search) and Fig. 10
(ratio/latency trade-off of sampled architectures): runs a scaled MHAS
search and dumps the sampled-architecture history."""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

from benchmarks import common as C
from repro.configs.deepmapping_paper import BENCH_MHAS
from repro.core.mhas import run_mhas


def run(dataset="synth_multi_high", iters=None) -> Dict:
    import dataclasses

    table = C.DATASETS[dataset]()
    cfg = BENCH_MHAS
    if iters:
        cfg = dataclasses.replace(cfg, total_iters=iters, model_iters=iters,
                                  controller_iters=max(1, iters // 20))
    t0 = time.perf_counter()
    res = run_mhas(table, cfg)
    search_s = time.perf_counter() - t0

    os.makedirs("results", exist_ok=True)
    out = {
        "dataset": dataset,
        "search_s": search_s,
        "best_ratio_estimate": res.best_ratio,
        "best_arch": {
            "trunk_depth": res.best_arch["trunk_depth"],
            "trunk_sizes": [int(s) for s in res.best_arch["trunk_sizes"]],
            "heads": {
                t: {"depth": h["depth"], "sizes": [int(s) for s in h["sizes"]]}
                for t, h in res.best_arch["heads"].items()
            },
        },
        "history": res.history,
    }
    with open(f"results/mhas_{dataset}.json", "w") as f:
        json.dump(out, f, indent=1)

    # convergence summary: mean ratio of first vs last quartile of samples
    hist = [h["ratio"] for h in res.history]
    q = max(1, len(hist) // 4)
    first, last = sum(hist[:q]) / q, sum(hist[-q:]) / q
    C.emit(
        f"mhas/{dataset}",
        search_s * 1e6,
        f"first_quartile_ratio={first:.4f};last_quartile_ratio={last:.4f};"
        f"best={res.best_ratio:.4f};samples={len(hist)}",
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synth_multi_high")
    ap.add_argument("--iters", type=int, default=None)
    args = ap.parse_args()
    run(args.dataset, args.iters)


if __name__ == "__main__":
    main()
