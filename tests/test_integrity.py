"""Checksummed-persistence tests: per-artifact crc32 verification,
atomic-save hygiene (fsync + rename, stale-tmp cleanup, interrupted-save
detection), and corrupt-shard quarantine (DESIGN.md §Fault tolerance).

The invariant under test: a corrupt or truncated artifact must fail
loudly at load time — :class:`~repro.fault.errors.IntegrityError` —
never decode into wrong values."""

import os
import shutil

import msgpack
import numpy as np
import pytest

import repro
from conftest import make_periodic_table
from repro import obs
from repro.baselines import ArrayStore, HashStore
from repro.cluster import (
    ClusterConfig,
    ShardedDeepMappingStore,
    load_sharded_store,
    save_sharded_store,
)
from repro.core import DeepMappingConfig
from repro.core.serialize import (
    clean_stale_tmp,
    crc32,
    load_store,
    read_artifact,
    save_store,
    unpack_meta,
)
from repro.core.trainer import TrainConfig
from repro.fault import FaultPlan, FaultSpec, IntegrityError, OwnerFailure

FAST = DeepMappingConfig(
    shared=(64,), private=(16,), train=TrainConfig(epochs=15, batch_size=512)
)


def flip_byte(path, offset=None):
    """Flip one bit of one byte in ``path`` (middle byte by default)."""
    with open(path, "rb") as f:
        data = bytearray(f.read())
    i = len(data) // 2 if offset is None else offset
    data[i] ^= 0x01
    with open(path, "wb") as f:
        f.write(bytes(data))


def counter_value(name, **labels):
    metric = obs.registry().get(name)
    return 0.0 if metric is None else metric.value(**labels)


def assert_same_lookup(expected, actual, keys):
    ev, ee = expected.lookup(keys)
    av, ae = actual.lookup(keys)
    np.testing.assert_array_equal(ee, ae)
    assert set(ev) == set(av)
    for col in ev:
        np.testing.assert_array_equal(ev[col][ee], av[col][ee])


@pytest.fixture(scope="module")
def saved_single(small_store, tmp_path_factory):
    """One saved single-store directory; corruption tests copy it."""
    table, store = small_store
    path = str(tmp_path_factory.mktemp("single") / "store")
    store.save(path)
    return table, store, path


@pytest.fixture(scope="module")
def saved_cluster(tmp_path_factory):
    table = make_periodic_table(n=800)
    cluster = ShardedDeepMappingStore.build(
        table, FAST, ClusterConfig(num_shards=2, policy="range")
    )
    path = str(tmp_path_factory.mktemp("cluster") / "cluster")
    save_sharded_store(cluster, path)
    return table, cluster, path


def copy_of(saved_path, tmp_path):
    dst = str(tmp_path / os.path.basename(saved_path))
    shutil.copytree(saved_path, dst)
    return dst


# ------------------------------------------------------ checksum round-trip
class TestChecksumRoundTrip:
    def test_single_store(self, saved_single, tmp_path):
        table, store, path = saved_single
        loaded = repro.open(path)
        probe = np.concatenate([table.keys, table.keys[:50] + 1])
        assert_same_lookup(store, loaded, probe)

    def test_sharded_store(self, saved_cluster):
        table, cluster, path = saved_cluster
        loaded = repro.open(path)
        assert loaded.num_shards == 2
        assert_same_lookup(cluster, loaded, table.keys)

    @pytest.mark.parametrize("cls", [ArrayStore, HashStore])
    def test_baseline_stores(self, cls, tmp_path):
        table = make_periodic_table(n=500)
        store = cls.build(table, codec="none", partition_bytes=2048)
        path = str(tmp_path / "baseline.msgpack")
        store.save(path)
        loaded = repro.open(path)
        assert_same_lookup(store, loaded, table.keys)

    def test_meta_records_a_checksum_per_artifact(self, saved_single):
        _, _, path = saved_single
        meta = unpack_meta(
            read_artifact(path, "meta.msgpack", None), "meta.msgpack"
        )
        checksums = meta["checksums"]
        artifacts = {
            f for f in os.listdir(path) if f != "meta.msgpack"
        }
        assert set(checksums) == artifacts
        for name, stored in checksums.items():
            with open(os.path.join(path, name), "rb") as f:
                assert crc32(f.read()) == stored

    def test_v1_layout_without_checksums_still_loads(
        self, saved_single, tmp_path
    ):
        # Back-compat: strip the envelope + checksums map to mimic a
        # pre-v2 directory; verification is skipped, the data loads.
        table, store, path = saved_single
        dst = copy_of(path, tmp_path)
        meta = unpack_meta(
            read_artifact(dst, "meta.msgpack", None), "meta.msgpack"
        )
        meta.pop("checksums")
        meta["version"] = 1
        with open(os.path.join(dst, "meta.msgpack"), "wb") as f:
            f.write(msgpack.packb(meta))  # flat, no crc envelope
        assert_same_lookup(store, load_store(dst), table.keys[:100])


# ---------------------------------------------------- corruption detection
class TestCorruptionDetection:
    def test_bit_flipped_vexist_detected(self, saved_single, tmp_path):
        _, _, path = saved_single
        dst = copy_of(path, tmp_path)
        flip_byte(os.path.join(dst, "vexist.bin"))
        with pytest.raises(IntegrityError, match="vexist.bin"):
            load_store(dst)

    def test_truncated_params_detected(self, saved_single, tmp_path):
        _, _, path = saved_single
        dst = copy_of(path, tmp_path)
        params = os.path.join(dst, "params.npz")
        size = os.path.getsize(params)
        with open(params, "rb+") as f:
            f.truncate(size // 2)
        with pytest.raises(IntegrityError, match="params.npz"):
            load_store(dst)

    def test_missing_decode_map_fails_loudly(self, saved_single, tmp_path):
        _, _, path = saved_single
        dst = copy_of(path, tmp_path)
        victim = next(f for f in os.listdir(dst) if f.startswith("decode_"))
        os.remove(os.path.join(dst, victim))
        with pytest.raises(FileNotFoundError):
            load_store(dst)

    def test_bit_flipped_meta_detected(self, saved_single, tmp_path):
        _, _, path = saved_single
        dst = copy_of(path, tmp_path)
        flip_byte(os.path.join(dst, "meta.msgpack"))
        with pytest.raises((IntegrityError, ValueError)):
            load_store(dst)

    def test_injected_corruption_detected(self, saved_single, tmp_path):
        # The artifact_read corrupt site flips a payload byte between
        # the disk and the checksum check — which must catch it.
        _, _, path = saved_single
        plan = FaultPlan(
            [FaultSpec(site="artifact_read", kind="corrupt",
                       owner="vexist.bin")]
        )
        with plan.activate():
            with pytest.raises(IntegrityError, match="vexist.bin"):
                load_store(path)
        assert plan.fired == 1

    def test_bit_flipped_baseline_detected(self, tmp_path):
        table = make_periodic_table(n=300)
        store = HashStore.build(table, codec="none", partition_bytes=2048)
        path = str(tmp_path / "hash.msgpack")
        store.save(path)
        flip_byte(path)
        with pytest.raises((IntegrityError, ValueError)) as exc_info:
            repro.open(path)
        if isinstance(exc_info.value, IntegrityError):
            # Corruption must be reported as corruption, not wrapped in
            # the "unrecognized format" error.
            assert "supported formats" not in str(exc_info.value)


# ------------------------------------------------------ atomic-save hygiene
class TestAtomicSaveHygiene:
    def test_stale_tmp_cleaned_on_load_with_warning(
        self, saved_single, tmp_path
    ):
        table, store, path = saved_single
        dst = copy_of(path, tmp_path)
        os.makedirs(dst + ".tmp")
        with open(os.path.join(dst + ".tmp", "junk"), "wb") as f:
            f.write(b"half-written")
        with pytest.warns(RuntimeWarning, match="stale"):
            loaded = load_store(dst)
        assert not os.path.exists(dst + ".tmp")
        assert_same_lookup(store, loaded, table.keys[:50])

    def test_interrupted_save_detected_by_open(self, tmp_path):
        path = str(tmp_path / "store")
        os.makedirs(path + ".tmp")
        with pytest.raises(ValueError, match="interrupted save"):
            repro.open(path)

    def test_clean_stale_tmp_reports(self, tmp_path):
        path = str(tmp_path / "x")
        assert clean_stale_tmp(path) is False  # nothing to do
        os.makedirs(path + ".tmp")
        with pytest.warns(RuntimeWarning):
            assert clean_stale_tmp(path) is True
        assert not os.path.exists(path + ".tmp")

    def test_save_is_atomic_over_existing(self, small_store, tmp_path):
        # Re-saving over an existing directory leaves no .tmp behind
        # and the result loads clean.
        table, store = small_store
        path = str(tmp_path / "store")
        save_store(store, path)
        save_store(store, path)
        assert not os.path.exists(path + ".tmp")
        assert_same_lookup(store, load_store(path), table.keys[:50])


# ------------------------------------------------------ shard quarantine
def corrupt_shard(path, shard=1, artifact="aux.msgpack"):
    flip_byte(os.path.join(path, f"shard_{shard:05d}", artifact))


class TestShardQuarantine:
    def test_raise_mode_propagates(self, saved_cluster, tmp_path):
        _, _, path = saved_cluster
        dst = copy_of(path, tmp_path)
        corrupt_shard(dst)
        with pytest.raises(IntegrityError, match="aux.msgpack"):
            load_sharded_store(dst)

    def test_invalid_on_corrupt_rejected(self, saved_cluster):
        _, _, path = saved_cluster
        with pytest.raises(ValueError, match="on_corrupt"):
            load_sharded_store(path, on_corrupt="bogus")

    @pytest.fixture()
    def quarantined(self, saved_cluster, tmp_path):
        table, cluster, path = saved_cluster
        dst = copy_of(path, tmp_path)
        corrupt_shard(dst)
        before = counter_value(
            "deepmap_fault_quarantines_total", owner="shard:1"
        )
        with pytest.warns(RuntimeWarning, match="quarantining shard 1"):
            loaded = repro.open(dst, on_corrupt="quarantine")
        assert (
            counter_value("deepmap_fault_quarantines_total", owner="shard:1")
            - before
            == 1
        )
        return table, cluster, loaded

    def test_healthy_shards_serve_byte_identical(self, quarantined):
        table, cluster, loaded = quarantined
        assert loaded.quarantined_shards() == [1]
        ref_values, ref_exists = cluster.lookup(table.keys)
        sid = cluster.partitioner.shard_of(table.keys)
        healthy = sid != 1

        res = (
            loaded.query()
            .where_keys(table.keys)
            .on_error("partial")
            .execute()
        )
        np.testing.assert_array_equal(res.exists[healthy], ref_exists[healthy])
        for col in ref_values:
            np.testing.assert_array_equal(
                res.values[col][healthy], ref_values[col][healthy]
            )
        assert not res.exists[~healthy].any()
        assert res.explain.keys_unresolved == int((~healthy).sum())
        assert len(res.explain.owners_failed) == 1

    def test_point_lookup_raise_mode_refuses(self, quarantined):
        table, _, loaded = quarantined
        with pytest.raises(OwnerFailure, match="shard:1"):
            loaded.query().where_keys(table.keys).execute()

    def test_scans_and_ranges_refuse_loudly(self, quarantined):
        table, _, loaded = quarantined
        with pytest.raises(IntegrityError, match="quarantined"):
            loaded.query().scan().execute()
        with pytest.raises(IntegrityError, match="quarantined"):
            loaded.query().where_range(
                int(table.keys[0]), int(table.keys[-1])
            ).execute()

    def test_mutations_refuse(self, quarantined):
        table, _, loaded = quarantined
        # The last key routes to the quarantined range shard.
        with pytest.raises(IntegrityError):
            loaded.delete(table.keys[-1:])

    def test_resave_refuses_data_laundering(self, quarantined, tmp_path):
        # Persisting a cluster with quarantined placeholders would
        # turn "corrupt but detected" into silent data loss.
        _, _, loaded = quarantined
        with pytest.raises(IntegrityError, match="refusing to save"):
            save_sharded_store(loaded, str(tmp_path / "resaved"))

    def test_row_accounting_survives_quarantine(self, quarantined):
        table, cluster, loaded = quarantined
        # num_rows comes from the manifest's shard_rows, so capacity
        # reporting stays truthful even for the placeholder.
        assert loaded.num_rows == cluster.num_rows == table.keys.size

    def test_all_shards_corrupt_still_raises(self, saved_cluster, tmp_path):
        _, _, path = saved_cluster
        dst = copy_of(path, tmp_path)
        corrupt_shard(dst, shard=0)
        corrupt_shard(dst, shard=1)
        with pytest.warns(RuntimeWarning):
            with pytest.raises(IntegrityError, match="every shard"):
                load_sharded_store(dst, on_corrupt="quarantine")
