"""Cross-store federation: one plan over several member stores.

:class:`FederatedStore` composes N :class:`~repro.api.protocol.MappingStore`
members — any mix of DeepMapping, sharded, and baseline stores —
behind the same protocol surface, so every query-layer feature (plans,
projection + predicate pushdown, the streaming executor, the serving
engine) runs unchanged against the federation.  Two composition modes:

* ``mode="partition"`` — members own **disjoint key ranges** split at
  ``boundaries`` (sorted ints, one fewer than members; member *i* owns
  ``[boundaries[i-1], boundaries[i])`` with open ends).  Lookups
  scatter per member and gather back in request order; range/scan key
  sources concatenate the members' ascending streams; mutations route
  to the owning member.  E.g. two sharded clusters over disjoint key
  spaces behind one facade.

* ``mode="replicate"`` — every member holds the **same relation**
  (e.g. a DeepMapping primary + a HashStore replica).  Each dispatched
  morsel is answered by ONE member: ``policy="primary"`` always asks
  member 0 (deterministic), ``policy="round_robin"`` rotates members
  per dispatch so a morsel stream load-balances across replicas while
  earlier morsels' host halves are still draining.  Mutations apply to
  every member, keeping replicas in sync.

Federation invariants:

* members expose identical column sets (checked at construction);
* partition members' key ranges are disjoint by construction — a key
  is answered by exactly one member, so scatter/gather is a
  permutation (the sharded-cluster invariant, one level up);
* replicate members agree on content (the caller's responsibility —
  e.g. built from one table or kept in sync through the facade);
  *values* equality across replicas is semantic, not byte-level
  (different store types may decode to different dtypes).

A federation is a runtime composition, not a storage format: ``save``
is intentionally unsupported — persist the members individually and
recompose.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api.plan import ExplainStats
from repro.api.protocol import MappingStore
from repro.api.routing import LazyFanoutPool, gather_parts, group_runs

MODES = ("partition", "replicate")
POLICIES = ("primary", "round_robin")


class _PendingFederatedLookup:
    """Per-member dispatches in flight for one request batch."""

    __slots__ = (
        "keys", "parts", "route_s", "predicates", "member_ids", "use_fanout",
    )

    def __init__(self, keys, parts, route_s, predicates, member_ids,
                 use_fanout):
        self.keys = keys
        self.parts = parts          # [(member, positions, handle), ...]
        self.route_s = route_s
        self.predicates = predicates
        self.member_ids = member_ids
        self.use_fanout = use_fanout


class FederatedStore(MappingStore):
    """One logical store over several member stores (see module doc)."""

    def __init__(
        self,
        members: Sequence[MappingStore],
        mode: str = "partition",
        boundaries: Optional[Sequence[int]] = None,
        policy: str = "primary",
    ):
        if not members:
            raise ValueError("federation needs at least one member store")
        if mode not in MODES:
            raise ValueError(f"unknown federation mode {mode!r}; have {MODES}")
        if policy not in POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; have {POLICIES}")
        cols = tuple(members[0].columns)
        for i, m in enumerate(members[1:], 1):
            # set equality: different store types canonicalize column
            # ORDER differently (MLPSpec sorts tasks, baselines keep
            # table order); values are keyed by name, so order is
            # presentation only and member 0's wins.
            if set(m.columns) != set(cols):
                raise ValueError(
                    f"member {i} columns {tuple(m.columns)} != member 0 "
                    f"columns {cols}; federation needs one schema"
                )
        if mode == "partition":
            if boundaries is None or len(boundaries) != len(members) - 1:
                raise ValueError(
                    "partition mode needs len(members)-1 sorted boundaries"
                )
            b = [int(x) for x in boundaries]
            if sorted(b) != b:
                raise ValueError(f"boundaries must be ascending: {b}")
            self.boundaries = np.asarray(b, dtype=np.int64)
        else:
            if boundaries is not None:
                raise ValueError("replicate mode takes no boundaries")
            self.boundaries = None
        self.members = list(members)
        self.mode = mode
        self.policy = policy
        self._columns = cols
        self._rr = 0  # round-robin cursor (replicate mode)
        # Morsel-parallel collect: member host halves gather on the
        # same lazy fan-out pool machinery the sharded store uses.
        self._fanout = LazyFanoutPool(None, "fed-collect")

    # --------------------------------------------------------------- routing
    def _member_of(self, keys: np.ndarray) -> np.ndarray:
        return np.searchsorted(self.boundaries, keys, side="right")

    def _scatter(self, keys: np.ndarray) -> List[Tuple[int, np.ndarray]]:
        """Partition-mode scatter -> ``[(member_id, positions), ...]``
        (ascending member id; empty members skipped).  Zero-length
        batches scatter to nobody — mutations stay no-ops."""
        if keys.shape[0] == 0:
            return []
        return group_runs(self._member_of(keys))

    def _pick_replica(self) -> int:
        if self.policy == "primary":
            return 0
        i = self._rr % len(self.members)
        self._rr += 1
        return i

    # -------------------------------------------------------------- protocol
    @property
    def columns(self) -> Tuple[str, ...]:
        """Member 0's column order (sets are identical by contract)."""
        return self._columns

    def _dispatch_lookup(self, keys, columns=None, fanout=None, predicates=(),
                         keys_exist=False):
        """Per-member scatter: every touched member's device work is
        enqueued before any host half runs, so a federated morsel
        overlaps member inference the same way the sharded store
        overlaps shard inference.  ``keys_exist`` forwards to every
        member (partition-mode range/scan keys come from the members'
        own existence indexes)."""
        keys = np.asarray(keys, dtype=np.int64)
        t0 = time.perf_counter()
        if self.mode == "replicate" or keys.shape[0] == 0:
            mid = self._pick_replica() if self.mode == "replicate" else 0
            groups = [(mid, np.arange(keys.shape[0], dtype=np.int64))]
        else:
            groups = self._scatter(keys)
        route_s = time.perf_counter() - t0
        parts = [
            (
                m,
                pos,
                self.members[m]._dispatch_lookup(
                    keys[pos], columns, fanout=fanout, predicates=predicates,
                    keys_exist=keys_exist,
                ),
            )
            for m, pos in groups
        ]
        use_fanout = (fanout is None or bool(fanout)) and len(parts) > 1
        return _PendingFederatedLookup(
            keys, parts, route_s, tuple(predicates), [m for m, _ in groups],
            use_fanout,
        )

    def _collect_lookup(self, pending: _PendingFederatedLookup):
        """Morsel-parallel gather: collect the members' host halves —
        on the lazy fan-out pool when more than one member answered
        (``Query.fanout(False)`` restores serial visits) — and permute
        results back to request order."""
        n = pending.keys.shape[0]
        agg = ExplainStats(route_s=pending.route_s, async_fanout=pending.use_fanout)

        def visit(part):
            m, pos, handle = part
            values, exists, match, stats = self.members[m]._collect_lookup(handle)
            # Namespace member-local shard ids before the union: two
            # sharded members both have a "shard 0", and deduping them
            # would under-report the federation's true fan-out.
            stats.shard_ids = tuple(f"m{m}:{s}" for s in stats.shard_ids)
            return pos, values, exists, match, stats

        if pending.use_fanout:
            visited = self._fanout.map(
                visit, pending.parts, owners=len(self.members)
            )
        else:
            visited = [visit(p) for p in pending.parts]
        collected = []
        member_plan: Tuple[str, ...] = ()
        for pos, values, exists, match, stats in visited:
            agg.merge_timings(stats)
            if not member_plan:
                member_plan = stats.plan
            collected.append((pos, values, exists, match))
        t0 = time.perf_counter()
        if pending.predicates and any(m is None for _, _, _, m in collected):
            # Contract: a member given predicates must return a match
            # selector; substituting "nothing matched" would silently
            # drop rows instead of surfacing the broken member hook.
            raise RuntimeError(
                "federation member returned match=None for a predicated "
                "lookup; its _collect_lookup violates the hook contract"
            )
        if len(collected) == 1 and np.array_equal(
            collected[0][0], np.arange(n, dtype=np.int64)
        ):
            # One member answered the whole batch in request order
            # (always true in replicate mode): the inverse permutation
            # is the identity — skip the per-column fancy-index copies.
            _, values, exists, match = collected[0]
        else:
            values, exists = gather_parts(
                n, ((p, v, e) for p, v, e, _ in collected)
            )
            match = None
            if pending.predicates:
                match = np.zeros(n, dtype=bool)
                for pos, _, _, m in collected:
                    match[pos] = m
        agg.gather_s += time.perf_counter() - t0
        agg.plan = (
            f"federate[{self.mode}:"
            f"{','.join(str(m) for m in pending.member_ids)}]",
        ) + member_plan
        return values, exists, match, agg

    def lookup(
        self, keys: np.ndarray, columns: Optional[Tuple[str, ...]] = None
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Batched exact-match lookup across the members (scatter in
        partition mode, one replica in replicate mode)."""
        values, exists, _, _ = self._collect_lookup(
            self._dispatch_lookup(keys, columns)
        )
        return values, exists

    def _range_keys(self, lo: int, hi: Optional[int]) -> np.ndarray:
        if self.mode == "replicate":
            return self.members[0]._range_keys(lo, hi)
        parts = []
        for i, m in enumerate(self.members):
            m_lo = lo if i == 0 else max(lo, int(self.boundaries[i - 1]))
            m_hi = hi if i == len(self.members) - 1 else (
                int(self.boundaries[i])
                if hi is None
                else min(hi, int(self.boundaries[i]))
            )
            if m_hi is not None and m_hi <= m_lo:
                continue
            part = m._range_keys(m_lo, m_hi)
            if part.size:
                parts.append(part)
        if not parts:
            return np.zeros(0, dtype=np.int64)
        # members are ordered by boundary, so concatenation is ascending
        return np.concatenate(parts)

    # ---------------------------------------------------------- mutations
    # Validated against EVERY affected member before mutating ANY
    # (same discipline as the sharded facade): a rejected batch must
    # leave the federation untouched, not half-mutated up to the
    # member that raised.
    def insert(self, keys: np.ndarray, columns: Dict[str, np.ndarray]) -> None:
        """Insert new rows — routed to owners (partition) or applied to
        every member (replicate); validated before any member mutates."""
        keys = np.asarray(keys, dtype=np.int64)
        if keys.size and np.unique(keys).size != keys.size:
            raise ValueError("duplicate keys in insert batch")
        if self.mode == "replicate":
            # every member validates (a drifted replica must reject the
            # batch BEFORE any member mutates, or replicas diverge more)
            for m in self.members:
                if m.lookup(keys, columns=())[1].any():
                    raise ValueError("insert of existing key; use update()")
            for m in self.members:
                m.insert(keys, columns)
            return
        batches = self._scatter(keys)
        for mid, pos in batches:
            if self.members[mid].lookup(keys[pos], columns=())[1].any():
                raise ValueError("insert of existing key; use update()")
        for mid, pos in batches:
            self.members[mid].insert(
                keys[pos], {c: v[pos] for c, v in columns.items()}
            )

    def delete(self, keys: np.ndarray) -> None:
        """Idempotent like the members — no validation needed."""
        keys = np.asarray(keys, dtype=np.int64)
        if self.mode == "replicate":
            for m in self.members:
                m.delete(keys)
            return
        for mid, pos in self._scatter(keys):
            self.members[mid].delete(keys[pos])

    def update(self, keys: np.ndarray, columns: Dict[str, np.ndarray]) -> None:
        """Overwrite existing rows (validated against every affected
        member before mutating any, like :meth:`insert`)."""
        keys = np.asarray(keys, dtype=np.int64)
        if self.mode == "replicate":
            for m in self.members:
                if not m.lookup(keys, columns=())[1].all():
                    raise ValueError("update of non-existing key; use insert()")
            for m in self.members:
                m.update(keys, columns)
            return
        batches = self._scatter(keys)
        for mid, pos in batches:
            if not self.members[mid].lookup(keys[pos], columns=())[1].all():
                raise ValueError("update of non-existing key; use insert()")
        for mid, pos in batches:
            self.members[mid].update(
                keys[pos], {c: v[pos] for c, v in columns.items()}
            )

    def mutation_version(self):
        """Tuple of member tokens: a mutation through the facade OR
        directly on a member store invalidates the federation's cached
        plans (members are caller-owned and reachable)."""
        return tuple(m.mutation_version() for m in self.members)

    # --------------------------------------------------------- accounting
    @property
    def num_rows(self) -> int:
        """Logical row count (member sum in partition mode; member 0's
        in replicate mode — replicas hold the same relation)."""
        if self.mode == "replicate":
            return int(self.members[0].num_rows)
        return int(sum(m.num_rows for m in self.members))

    def size_breakdown(self) -> Dict[str, int]:
        """Per-member storage accounting, keys namespaced ``memberN.*``."""
        out: Dict[str, int] = {}
        for i, m in enumerate(self.members):
            for k, v in m.size_breakdown().items():
                out[f"member{i}.{k}"] = v
        return out

    # -------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        """Intentionally unsupported — persist members individually."""
        raise NotImplementedError(
            "a federation is a runtime composition; save each member "
            "store individually and recompose with FederatedStore(...)"
        )

    @classmethod
    def load(cls, path: str, pool=None) -> "FederatedStore":
        """Intentionally unsupported — load members and recompose."""
        raise NotImplementedError(
            "load the member stores individually (repro.open) and "
            "recompose with FederatedStore(...)"
        )
