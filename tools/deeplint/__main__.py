"""CLI entry point: ``python -m tools.deeplint src/repro [options]``.

Exit codes: 0 clean (or fully baselined/suppressed), 1 non-baselined
findings, 2 usage or parse error.
"""

from __future__ import annotations

import argparse
import datetime
import sys
from pathlib import Path

from tools.deeplint import engine
from tools.deeplint.rules import ALL_RULES, RULE_IDS

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.deeplint",
        description="Repo-invariant static analysis (stdlib ast).",
    )
    parser.add_argument("paths", nargs="*", help="files or directories to lint")
    parser.add_argument(
        "--format", choices=("text", "json"), default="text", dest="fmt"
    )
    parser.add_argument(
        "--baseline",
        type=Path,
        default=DEFAULT_BASELINE,
        help=f"baseline file (default: {DEFAULT_BASELINE.name} next to the tool)",
    )
    parser.add_argument(
        "--no-baseline",
        action="store_true",
        help="report every finding, ignoring the baseline file",
    )
    parser.add_argument(
        "--write-baseline",
        action="store_true",
        help="rewrite the baseline file with the current findings and exit 0",
    )
    parser.add_argument(
        "--rules",
        help="comma-separated rule ids to run (default: all)",
    )
    parser.add_argument(
        "--output", type=Path, help="write the report here instead of stdout"
    )
    parser.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog and exit"
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for mod in ALL_RULES:
            print(f"{mod.RULE_ID}: {mod.SUMMARY}")
        return 0
    if not args.paths:
        parser.error("paths are required unless --list-rules is given")

    rules = ALL_RULES
    if args.rules:
        wanted = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in wanted if r not in RULE_IDS]
        if unknown:
            print(f"deeplint: unknown rule(s): {', '.join(unknown)}", file=sys.stderr)
            return 2
        rules = [RULE_IDS[r] for r in wanted]

    paths = [Path(p) for p in args.paths]
    missing = [str(p) for p in paths if not p.exists()]
    if missing:
        print(f"deeplint: no such path: {', '.join(missing)}", file=sys.stderr)
        return 2

    root = Path.cwd()
    findings, suppressed, errors = engine.run(paths, root, rules)
    if errors:
        for err in errors:
            print(f"deeplint: parse error: {err}", file=sys.stderr)
        return 2

    if args.write_baseline:
        date = datetime.date.today().isoformat()
        engine.write_baseline(args.baseline, findings, date)
        print(
            f"deeplint: wrote {len(findings)} finding(s) to {args.baseline}",
            file=sys.stderr,
        )
        return 0

    baseline = {} if args.no_baseline else engine.load_baseline(args.baseline)
    new, baselined = engine.apply_baseline(findings, baseline)

    file_count = len({f.path for f in new} | {f.path for f in baselined})
    if args.fmt == "json":
        report = engine.render_json(
            new, baselined, len(suppressed), file_count, [str(p) for p in paths]
        )
    else:
        report = engine.render_text(new, baselined, len(suppressed), file_count)

    if args.output:
        args.output.write_text(report + "\n", encoding="utf-8")
    else:
        print(report)
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
