"""Compression codec registry.

The paper evaluates Dictionary encoding, Gzip, Z-Standard and LZMA
(§V-A3) and tunes the compression level per use-case (§V-A4): zstd
level 1 for small-batch / latency-dominated workloads, higher levels
when decompression is off the critical path.  Codec identity strings
(``"zstd"``, ``"lzma"``, ...) are stable across save/load.
"""

from __future__ import annotations

import dataclasses
import gzip
import lzma
import zlib
from typing import Callable, Dict

import zstandard


@dataclasses.dataclass(frozen=True)
class Codec:
    name: str
    compress: Callable[[bytes], bytes]
    decompress: Callable[[bytes], bytes]


def _zstd(level: int) -> Codec:
    def comp(data: bytes, _level=level) -> bytes:
        return zstandard.ZstdCompressor(level=_level).compress(data)

    def decomp(data: bytes) -> bytes:
        return zstandard.ZstdDecompressor().decompress(data)

    return Codec(f"zstd{'' if level == 3 else level}", comp, decomp)


CODECS: Dict[str, Codec] = {
    "none": Codec("none", lambda b: b, lambda b: b),
    "zstd": _zstd(3),
    "zstd1": _zstd(1),
    "zstd9": _zstd(9),
    "gzip": Codec(
        "gzip",
        lambda b: gzip.compress(b, compresslevel=6),
        gzip.decompress,
    ),
    "zlib": Codec("zlib", lambda b: zlib.compress(b, 6), zlib.decompress),
    "lzma": Codec(
        "lzma",
        lambda b: lzma.compress(b, preset=6),
        lzma.decompress,
    ),
}


def get_codec(name: str) -> Codec:
    try:
        return CODECS[name]
    except KeyError:
        raise KeyError(f"unknown codec {name!r}; have {sorted(CODECS)}") from None
