"""deepseek-v3-671b — MLA + MoE (1 shared + 256 routed top-8)
[arXiv:2412.19437].  61L d_model=7168 128H vocab=129280; expert
d_ff=2048; first 3 layers dense FFN (d_ff 18432); MLA: q_lora 1536,
kv_lora 512, nope 128, rope 64, v_head 128.  MTP head omitted (noted in
DESIGN.md — single-token training objective here)."""

from repro.configs.base import ArchSpec, register
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,       # MLA: per-head K expanded from shared latent
    d_ff=18432,             # dense-FFN prefix layers
    vocab_size=129280,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    num_experts=256,
    experts_per_token=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=3,
    capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="dsv3-smoke",
    family="moe",
    num_layers=4,           # 2 dense prefix + 2 MoE
    d_model=32,
    num_heads=4,
    num_kv_heads=4,
    d_ff=64,
    vocab_size=128,
    use_mla=True,
    q_lora_rank=16,
    kv_lora_rank=8,
    qk_nope_dim=8,
    qk_rope_dim=4,
    v_head_dim=8,
    num_experts=4,
    experts_per_token=2,
    num_shared_experts=1,
    moe_d_ff=16,
    first_dense_layers=2,
    capacity_factor=2.0,
    dtype="float32",
    remat="none",
)

SPEC = register(
    ArchSpec(
        arch_id="deepseek-v3-671b",
        config=CONFIG,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        notes="Full attention (MLA) -> long_500k skipped; MTP omitted.",
    )
)
