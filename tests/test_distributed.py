"""Distributed-execution integration tests.

These run REAL sharded computation on 8 virtual CPU devices in a
subprocess (the device count is pinned at jax init, so the main test
process stays single-device).  They verify semantics the dry-run can't:
DP gradient agreement, TP logit equivalence, elastic remesh restore,
and hierarchical/compressed reduction numerics under shard_map.
"""

import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(body: str, devices: int = 8, timeout: int = 600):
    prog = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import jax
        if not hasattr(jax.sharding, "AxisType"):
            # Older jax: meshes are Auto-typed by default; accept and
            # drop the axis_types kwarg so the snippets below run as-is.
            class _AxisType:
                Auto = None
            jax.sharding.AxisType = _AxisType
            _orig_make_mesh = jax.make_mesh
            jax.make_mesh = (
                lambda shape, names, axis_types=None: _orig_make_mesh(shape, names)
            )
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=REPO,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


class TestShardedTraining:
    def test_dp_tp_train_step_matches_single_device(self):
        run_in_subprocess(
            """
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_arch
            from repro.sharding.partition import batch_shardings, state_shardings
            from repro.train.optimizer import adamw
            from repro.train.train_step import init_state, make_train_step

            cfg = get_arch("tinyllama-1.1b").smoke
            opt = adamw(lr=1e-3)
            state = init_state(cfg, opt, seed=0)
            step = make_train_step(cfg, opt)
            batch = {"tokens": jnp.asarray(
                np.random.default_rng(0).integers(0, cfg.vocab_size, (8, 16)))}

            # single-device reference
            ref_state, ref_metrics = jax.jit(step)(state, batch)

            # 4x2 (data, model) mesh
            mesh = jax.make_mesh((4, 2), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            st_sh = state_shardings(cfg, mesh, jax.eval_shape(lambda: init_state(cfg, opt, seed=0)))
            b_sh = batch_shardings(cfg, mesh, batch)
            with mesh:
                sharded = jax.jit(step, in_shardings=(st_sh, b_sh),
                                  out_shardings=(st_sh, None))(state, batch)
            sh_state, sh_metrics = sharded
            np.testing.assert_allclose(float(sh_metrics["loss"]),
                                       float(ref_metrics["loss"]), rtol=1e-4)
            a = np.asarray(jax.tree.leaves(ref_state.params)[0])
            b = np.asarray(jax.tree.leaves(sh_state.params)[0])
            np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)
            print("DP/TP == single-device OK")
            """
        )

    def test_moe_expert_parallel_runs(self):
        run_in_subprocess(
            """
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_arch
            from repro.sharding.partition import batch_shardings, state_shardings
            from repro.train.optimizer import adamw
            from repro.train.train_step import init_state, make_train_step

            cfg = get_arch("deepseek-v3-671b").smoke  # MLA + MoE family
            opt = adamw(lr=1e-3)
            state = init_state(cfg, opt, seed=0)
            step = make_train_step(cfg, opt)
            batch = {"tokens": jnp.asarray(
                np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 16)))}
            mesh = jax.make_mesh((2, 4), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            st_sh = state_shardings(cfg, mesh, jax.eval_shape(lambda: init_state(cfg, opt, seed=0)))
            b_sh = batch_shardings(cfg, mesh, batch)
            with mesh:
                (new_state, metrics) = jax.jit(
                    step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None)
                )(state, batch)
            assert np.isfinite(float(metrics["loss"]))
            print("EP MoE sharded step OK", float(metrics["loss"]))
            """
        )

    def test_sharded_decode_sequence_cache(self):
        run_in_subprocess(
            """
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_arch
            from repro.models import DecoderLM
            from repro.serve.serve_step import make_cache_factory, make_decode_step
            from repro.sharding.partition import cache_shardings, param_shardings

            cfg = get_arch("tinyllama-1.1b").smoke
            m = DecoderLM(cfg)
            params = m.init(0)
            decode = make_decode_step(cfg)
            # single-device reference
            cache0 = make_cache_factory(cfg)(batch=1, max_len=64)
            ref, _ = jax.jit(decode)(params, cache0, jnp.zeros((1,1), jnp.int32))

            mesh = jax.make_mesh((8, 1), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            p_sh = param_shardings(cfg, mesh, jax.eval_shape(lambda: m.init(0)))
            c_sh = cache_shardings(cfg, mesh, jax.eval_shape(
                lambda: make_cache_factory(cfg)(batch=1, max_len=64)))
            with mesh:
                out, _ = jax.jit(decode, in_shardings=(p_sh, c_sh, None),
                                 out_shardings=None)(params, cache0,
                                                     jnp.zeros((1,1), jnp.int32))
            np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                       rtol=2e-3, atol=2e-3)
            print("sequence-sharded decode == single-device OK")
            """
        )


class TestHierarchicalCollectives:
    def test_hierarchical_psum_equals_flat(self):
        run_in_subprocess(
            """
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from jax.experimental.shard_map import shard_map
            from repro.train.compression import hierarchical_psum

            mesh = jax.make_mesh((2, 4), ("pod", "data"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)

            flat = shard_map(lambda v: jax.lax.psum(v, ("data", "pod")),
                             mesh=mesh, in_specs=P(("pod","data")),
                             out_specs=P(("pod","data")))(x)
            hier = shard_map(lambda v: hierarchical_psum(v, "data", "pod"),
                             mesh=mesh, in_specs=P(("pod","data")),
                             out_specs=P(("pod","data")))(x)
            np.testing.assert_allclose(np.asarray(flat), np.asarray(hier), rtol=1e-6)
            print("hierarchical psum OK")
            """
        )

    def test_compressed_cross_pod_mean(self):
        run_in_subprocess(
            """
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from jax.experimental.shard_map import shard_map
            from repro.train.compression import compressed_cross_pod_mean, ef_init

            mesh = jax.make_mesh((4, 2), ("pod", "data"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            rng = np.random.default_rng(0)
            g = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))

            def body(gv):
                grads = {"w": gv}
                ef = ef_init(grads)
                reduced, ef = compressed_cross_pod_mean(grads, ef, "pod")
                return reduced["w"]

            out = shard_map(body, mesh=mesh, in_specs=P("pod", None),
                            out_specs=P("pod", None))(g)
            want = np.broadcast_to(np.asarray(g).reshape(4, 1, 32).mean(axis=0),
                                   (4, 1, 32)).reshape(4, 32)
            # int8 quantization: loose tolerance, but structure preserved
            np.testing.assert_allclose(np.asarray(out), want, atol=0.05)
            print("compressed cross-pod mean OK")
            """
        )


class TestElasticRemesh:
    def test_checkpoint_restores_onto_different_mesh(self):
        run_in_subprocess(
            """
            import os, tempfile
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_arch
            from repro.sharding.partition import state_shardings
            from repro.train.checkpoint import save_checkpoint
            from repro.train.fault_tolerance import elastic_restore
            from repro.train.optimizer import adamw
            from repro.train.train_step import init_state

            cfg = get_arch("granite-3-2b").smoke
            opt = adamw(lr=1e-3)
            state = init_state(cfg, opt, seed=0)
            with tempfile.TemporaryDirectory() as d:
                save_checkpoint(d, 42, state)
                # restore onto a 2x4 mesh (as if scaled from 1 device to 8)
                mesh = jax.make_mesh((2, 4), ("data", "model"),
                                     axis_types=(jax.sharding.AxisType.Auto,)*2)
                like = jax.eval_shape(lambda: init_state(cfg, opt, seed=0))
                sh = state_shardings(cfg, mesh, like)
                step, restored = elastic_restore(d, like, sh)
                assert step == 42
                leaf = jax.tree.leaves(restored.params)[0]
                assert len(leaf.sharding.device_set) > 1
                orig = jax.tree.leaves(state.params)[0]
                np.testing.assert_allclose(np.asarray(leaf), np.asarray(orig))
            print("elastic remesh restore OK")
            """
        )
