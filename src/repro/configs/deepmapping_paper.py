"""The paper's own workload config: DeepMapping hybrid structures for
the evaluation datasets (§V-A6 search/training hyper-parameters)."""

import dataclasses

from repro.core.hybrid import DeepMappingConfig
from repro.core.mhas.search import MHASConfig
from repro.core.trainer import TrainConfig

# Paper-scale settings (§V-A6) — used on real hardware.
PAPER_MHAS = MHASConfig(
    layer_sizes=(100, 200, 400, 800, 1200, 1600, 2000),
    max_layers=2,
    total_iters=2000,
    model_iters=2000,
    controller_iters=40,
    model_epochs_per_iter=5,
    model_batch=16384,
    controller_batch=2048,
    lr_model=1e-3,
    lr_controller=3.5e-4,
    early_stop_tol=1e-4,
)

PAPER_STORE = DeepMappingConfig(
    base=10,
    codec="zstd",                  # DM-Z; "lzma" -> DM-L
    partition_bytes=4 * 1024 * 1024,  # §V-A5: ~4MB optimal for DM-Z
    train=TrainConfig(batch_size=16384, epochs=200, lr=1e-3, lr_decay=0.999,
                      early_stop_tol=1e-4),
)

# CPU-scale settings for this container's benchmarks.
BENCH_MHAS = dataclasses.replace(
    PAPER_MHAS,
    layer_sizes=(32, 64, 128, 256),
    total_iters=120,
    model_iters=120,
    controller_iters=6,
    model_epochs_per_iter=2,
    model_batch=4096,
    controller_batch=2048,
    finetune_epochs=40,
)

BENCH_STORE = dataclasses.replace(
    PAPER_STORE,
    partition_bytes=128 * 1024,
    train=TrainConfig(batch_size=4096, epochs=120, lr=1e-3, lr_decay=0.999,
                      early_stop_tol=1e-4),
)
