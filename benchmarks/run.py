"""Benchmark harness entrypoint — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Default preset is sized
for this CPU container (~minutes); ``--full`` widens datasets/batches.

Sections:
  table1  — storage + lookup latency, exceeds-memory pool   (paper Tab. I)
  table2  — storage + lookup latency, fits-in-memory pool   (paper Tab. II)
  table3  — insertions, same distribution                   (paper Tab. III)
  table4  — insertions, shifted distribution                (paper Tab. IV)
  table5  — deletions                                       (paper Tab. V)
  fig6    — storage breakdown                               (paper Fig. 6)
  fig7    — latency breakdown                               (paper Fig. 7)
  fig9    — MHAS search progression                         (paper Fig. 9/10)
  shards  — sharded cluster scaling: build / lookup QPS / dirty-shard retrain
  query   — plan executor vs legacy lookup (point/range/scan, projection
            pushdown, sharded sync vs async fan-out)
  query_stream — streaming operator pipeline: multi-plan pipelined vs
            serial, value-predicate pushdown vs post-hoc filter, plus
            the adaptive-execution section (warm-vs-cold plan cache,
            baseline partition pruning, adaptive vs fixed morsel
            sizing), the code-space aggregate section (count-only
            GROUP BY with rows_decoded == 0 and code-table sum/min/max
            vs the decode-then-aggregate reference) and the mesh
            shard-scatter vs thread-pool fan-out comparison; writes
            BENCH_query.json at the repo root (uploaded by the CI
            smoke-bench job alongside BENCH_lookup.json)
  lookup_pipeline — staged (seed path) vs pipelined (inference engine)
            hot-path comparison; writes BENCH_lookup.json at the repo
            root (p50/p99 latency, QPS, compile counts) — the CI
            smoke-bench job uploads it as the perf-trajectory artifact
  tokens  — beyond-paper: DeepMapping-compressed LM data pipeline
  roofline — assignment §Roofline terms from the dry-run records
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized lookup_pipeline run (fewer rows/batches)")
    ap.add_argument("--sections", nargs="*", default=None)
    ap.add_argument(
        "--telemetry-dir", default=None, metavar="DIR",
        help="after the sections run, export the process telemetry "
             "there: metrics.prom (Prometheus text), metrics.json "
             "(registry snapshot), trace.json (Chrome trace — open at "
             "https://ui.perfetto.dev)",
    )
    args = ap.parse_args()

    from benchmarks import bench_beyond, bench_breakdown, bench_lookup
    from benchmarks import bench_mhas, bench_modify, bench_query, bench_shards
    from benchmarks import roofline
    from benchmarks import common as C

    datasets = list(C.DATASETS) if args.full else list(C.FAST_DATASETS)
    batches = (1000, 10_000, 100_000) if args.full else (1000, 10_000)

    sections = {
        "table1": lambda: bench_lookup.run(datasets=datasets, batches=batches,
                                           pool_mode="small"),
        "table2": lambda: bench_lookup.run(datasets=datasets, batches=batches,
                                           pool_mode="large"),
        "table3": lambda: bench_modify.run_inserts(shift=False),
        "table4": lambda: bench_modify.run_inserts(shift=True),
        "table5": lambda: bench_modify.run_deletes(),
        "fig6": lambda: bench_breakdown.run_storage(datasets=datasets),
        "fig7": lambda: bench_breakdown.run_latency(datasets=datasets),
        "fig9": lambda: bench_mhas.run(iters=None if args.full else 60),
        "shards": lambda: bench_shards.run(
            shard_counts=(1, 2, 4, 8) if args.full else (1, 4)
        ),
        "query": lambda: bench_query.run(
            datasets=("tpcds_customer_demographics",),
            batches=batches,
            num_shards=8 if args.full else 4,
        ),
        # scaled down by default like every section; the acceptance-
        # grade 1M-row record needs --full (CI smoke uses --smoke)
        "lookup_pipeline": lambda: bench_lookup.write_pipeline_json(
            bench_lookup.run_pipeline(
                n=1_000_000 if args.full else 150_000,
                fixed_repeats=4 if (args.smoke or not args.full) else 8,
                sweep_sizes=50,
            )
        ),
        "query_stream": lambda: bench_query.write_query_json(
            dict(
                bench_query.run_streaming(smoke=args.smoke),
                adaptive=bench_query.run_adaptive(smoke=args.smoke),
                aggregate=bench_query.run_aggregate(
                    n=1_000_000 if args.full else 150_000, smoke=args.smoke
                ),
                degraded=bench_shards.run_degraded(smoke=args.smoke),
                mesh=bench_shards.run_mesh(smoke=args.smoke),
            )
        ),
        # lazy: bench_tokens hard-imports zstandard (optional elsewhere);
        # a host without it should still run every other section
        "tokens": lambda: __import__(
            "benchmarks.bench_tokens", fromlist=["run"]
        ).run(),
        "beyond": lambda: bench_beyond.run(),
        "roofline": lambda: roofline.run(),
    }
    wanted = args.sections or list(sections)
    failures = 0
    for name in wanted:
        print(f"# === {name} ===", flush=True)
        try:
            sections[name]()
        except Exception:  # noqa: BLE001 — report all sections
            failures += 1
            print(f"# SECTION {name} FAILED", flush=True)
            traceback.print_exc()
    if args.telemetry_dir:
        import os

        from repro import obs

        os.makedirs(args.telemetry_dir, exist_ok=True)
        for fname, writer in (
            ("metrics.prom", obs.write_prometheus),
            ("metrics.json", obs.write_json_snapshot),
            ("trace.json", obs.write_chrome_trace),
        ):
            print(f"# telemetry: {writer(os.path.join(args.telemetry_dir, fname))}",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
