"""TPC-DS-like table generators.

``customer_demographics`` is generated exactly as TPC-DS does: the
table is the full cross product of its attribute domains, so every
column is a deterministic periodic function of ``cd_demo_sk`` — this is
the paper's flagship high-correlation case (compressed to 0.6% of raw,
§V-B1).  ``catalog_sales``/``catalog_returns`` are mostly-random fact
tables (low correlation, larger cardinalities)."""

from __future__ import annotations

import numpy as np

from repro.core.table import Table

_GENDER = np.array(["F", "M"])
_MARITAL = np.array(["D", "M", "S", "U", "W"])
_EDUCATION = np.array(
    ["2 yr Degree", "4 yr Degree", "Advanced Degree", "College",
     "Primary", "Secondary", "Unknown"]
)
_CREDIT = np.array(["Good", "High Risk", "Low Risk", "Unknown"])


def customer_demographics_like(n: int | None = None, seed: int = 0) -> Table:
    """Cross product of demographic domains (full table = 1,920,800 rows).

    ``n`` truncates the cross product (keys stay dense 1..n)."""
    dims = [
        ("cd_gender", _GENDER),
        ("cd_marital_status", _MARITAL),
        ("cd_education_status", _EDUCATION),
        ("cd_purchase_estimate", np.arange(500, 10500, 500, dtype=np.int32)),  # 20
        ("cd_credit_rating", _CREDIT),
        ("cd_dep_count", np.arange(0, 7, dtype=np.int32)),
        ("cd_dep_employed_count", np.arange(0, 7, dtype=np.int32)),
        ("cd_dep_college_count", np.arange(0, 7, dtype=np.int32)),
    ]
    full = int(np.prod([len(d) for _, d in dims]))
    n = full if n is None else min(n, full)
    keys = np.arange(1, n + 1, dtype=np.int64)
    idx = keys - 1
    cols = {}
    stride = full
    for name, domain in dims:
        stride //= len(domain)
        cols[name] = domain[(idx // stride) % len(domain)]
    return Table(keys=keys, columns=cols)


def catalog_sales_like(n: int = 400_000, seed: int = 0) -> Table:
    rng = np.random.default_rng(seed)
    keys = np.arange(1, n + 1, dtype=np.int64)
    return Table(
        keys=keys,
        columns={
            "cs_ship_mode_sk": rng.integers(1, 21, n).astype(np.int32),
            "cs_warehouse_sk": rng.integers(1, 16, n).astype(np.int32),
            "cs_promo_sk": rng.integers(1, 301, n).astype(np.int32),
            "cs_call_center_sk": rng.integers(1, 7, n).astype(np.int32),
            "cs_quantity": rng.integers(1, 101, n).astype(np.int32),
        },
    )


def catalog_returns_like(n: int = 140_000, seed: int = 0) -> Table:
    rng = np.random.default_rng(seed)
    keys = np.arange(1, n + 1, dtype=np.int64)
    return Table(
        keys=keys,
        columns={
            "cr_reason_sk": rng.integers(1, 36, n).astype(np.int32),
            "cr_return_quantity": rng.integers(1, 101, n).astype(np.int32),
            "cr_return_ship_mode": rng.integers(1, 21, n).astype(np.int32),
        },
    )
