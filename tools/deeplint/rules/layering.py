"""Rule ``layering``: package import isolation inside ``repro``.

The dependency discipline (see DESIGN.md §Invariants) is expressed as an
allow-list of importable package prefixes per ``repro`` subpackage.  Only
module-scope imports are checked: a function-local import is the
sanctioned way to break an intentional late-binding cycle, and is skipped.

Key edges enforced:

* ``repro.obs`` imports nothing from ``repro`` outside itself (it must be
  importable from any layer without cycles).
* ``repro.kernels`` never imports ``repro.api``/``serve``/``cluster``/
  ``baselines`` — kernels sit below the query layer.
* ``repro.core`` may import only the protocol surface of ``repro.api``
  (``plan``/``protocol``/``cache``), never the executor/query/serving side.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Set, Tuple

from tools.deeplint.engine import Finding, Project, SourceModule

RULE_ID = "layering"
SUMMARY = "module-scope import crosses a forbidden package boundary"

# Subpackage -> allowed repro import prefixes (itself always allowed).
# Subpackages not listed are unchecked.  Prefixes may be modules
# ("repro.api.plan") to allow a narrow slice of a wider package.
ALLOWED: Dict[str, Tuple[str, ...]] = {
    "repro.obs": (),
    "repro.storage": (),
    "repro.fault": ("repro.obs",),
    "repro.api": ("repro.obs", "repro.storage", "repro.fault"),
    "repro.kernels": ("repro.core", "repro.obs", "repro.storage"),
    "repro.core": (
        "repro.api.plan",
        "repro.api.protocol",
        "repro.api.cache",
        "repro.kernels",
        "repro.models",
        "repro.train",
        "repro.data",
        "repro.obs",
        "repro.storage",
        "repro.configs",
        "repro.fault",
    ),
    "repro.baselines": (
        "repro.api",
        "repro.core",
        "repro.obs",
        "repro.storage",
        "repro.fault",
    ),
    "repro.cluster": (
        "repro.api",
        "repro.core",
        "repro.kernels",
        # mesh_scatter lays shard fleets out on launch-layer meshes
        # (make_shard_mesh); launch stays a leaf w.r.t. repro.cluster.
        "repro.launch",
        "repro.models",
        "repro.obs",
        "repro.storage",
        "repro.sharding",
        "repro.fault",
    ),
    "repro.serve": (
        "repro.api",
        "repro.core",
        "repro.cluster",
        "repro.kernels",
        "repro.models",
        "repro.obs",
        "repro.storage",
        "repro.fault",
    ),
}


def _owning_package(module: str) -> str | None:
    parts = module.split(".")
    if len(parts) < 2 or parts[0] != "repro":
        return None
    return ".".join(parts[:2])


def _module_scope_imports(src: SourceModule) -> Iterable[ast.stmt]:
    """Imports at module/class scope (not inside any function)."""

    def walk(body: List[ast.stmt]) -> Iterable[ast.stmt]:
        for node in body:
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                yield node
            elif isinstance(node, (ast.If, ast.Try, ast.ClassDef, ast.With)):
                for field in ("body", "orelse", "finalbody", "handlers"):
                    sub = getattr(node, field, [])
                    for item in sub:
                        if isinstance(item, ast.ExceptHandler):
                            yield from walk(item.body)
                        elif isinstance(item, ast.stmt):
                            yield from walk([item])

    yield from walk(src.tree.body)


def _targets(node: ast.stmt, module: str) -> List[str]:
    """Dotted names an import statement could bind (repro.* only)."""
    out: List[str] = []
    if isinstance(node, ast.Import):
        out.extend(alias.name for alias in node.names)
    elif isinstance(node, ast.ImportFrom):
        base = node.module or ""
        if node.level:
            # Resolve relative imports against the importing module.
            parts = module.split(".")
            anchor = parts[: len(parts) - node.level]
            base = ".".join(anchor + ([base] if base else []))
        for alias in node.names:
            out.append(base + "." + alias.name if base else alias.name)
        if base:
            out.append(base)
    return [t for t in out if t == "repro" or t.startswith("repro.")]


def _allowed(target: str, own_pkg: str, prefixes: Tuple[str, ...]) -> bool:
    for prefix in (own_pkg,) + prefixes:
        if target == prefix or target.startswith(prefix + "."):
            return True
    # "from repro import obs" produces targets "repro.obs" and "repro";
    # the bare package root is fine when every alias target is allowed,
    # which the caller checks alias-by-alias.  "repro" alone is allowed.
    return target == "repro"


def check(project: Project) -> Iterable[Finding]:
    findings: List[Finding] = []
    for src in project.modules:
        if not src.module:
            continue
        own_pkg = _owning_package(src.module)
        if own_pkg is None or own_pkg not in ALLOWED:
            continue
        prefixes = ALLOWED[own_pkg]
        for node in _module_scope_imports(src):
            bad: Set[str] = set()
            if isinstance(node, ast.ImportFrom):
                # Allowed iff every alias resolves inside the allow-list
                # (the bare "from X" module may be wider than the slice).
                alias_targets = _targets(node, src.module)
                base = alias_targets[-1] if alias_targets else ""
                per_alias = alias_targets[:-1] or alias_targets
                for t in per_alias:
                    if not _allowed(t, own_pkg, prefixes) and not _allowed(
                        base, own_pkg, prefixes
                    ):
                        bad.add(t)
            else:
                for t in _targets(node, src.module):
                    if not _allowed(t, own_pkg, prefixes):
                        bad.add(t)
            for t in sorted(bad):
                findings.append(
                    src.finding(
                        RULE_ID,
                        node,
                        f"{own_pkg} must not import {t} at module scope "
                        f"(allowed: {', '.join((own_pkg,) + prefixes)})",
                    )
                )
    return findings
