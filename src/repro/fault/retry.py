"""Bounded retry with exponential backoff and per-owner deadlines.

:func:`call_guarded` is the single retry loop used by every fan-out
site (shard visits, federation member visits).  It turns an arbitrary
callable's failure into a structured
:class:`~repro.fault.errors.OwnerError` *value* instead of letting the
exception kill the plan, and counts retries / terminal failures into
the ``deepmap_fault_*`` metric families.

Backoff is computed, not drawn: ``backoff_s * multiplier**(attempt-1)``
capped at ``max_backoff_s`` — deterministic, so fault tests replay
identically.  Deadlines are cooperative: the loop checks the monotonic
clock *between* attempts (it cannot interrupt a stuck callable — that
is what the delay-injection site plus small deadlines simulate in
tests).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro import obs
from repro.fault.errors import OwnerError


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry/backoff/deadline knobs for one fan-out site.

    ``max_attempts`` counts the first try (1 = no retry).
    ``deadline_s`` bounds the *total* wall time across attempts for one
    owner; ``None`` disables the deadline.  The default policy retries
    twice with 1 ms initial backoff — fast enough for tests, real
    deployments tune it per store.
    """

    max_attempts: int = 3
    backoff_s: float = 0.001
    backoff_multiplier: float = 2.0
    max_backoff_s: float = 0.05
    deadline_s: Optional[float] = None

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.backoff_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff must be >= 0")
        if self.backoff_multiplier < 1.0:
            raise ValueError("backoff_multiplier must be >= 1")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError("deadline_s must be > 0")

    def backoff(self, attempt: int) -> float:
        """Sleep before ``attempt`` (1-based retry index)."""
        if attempt < 1:
            return 0.0
        return min(
            self.backoff_s * (self.backoff_multiplier ** (attempt - 1)),
            self.max_backoff_s,
        )


#: Policy used when a store is built without explicit fault tuning.
DEFAULT_POLICY = RetryPolicy()

#: No retries, no deadline — the legacy fail-fast behaviour, used for
#: mutation fan-out where retrying a half-applied write is unsafe.
FAIL_FAST = RetryPolicy(max_attempts=1)


@dataclasses.dataclass(frozen=True)
class GuardedOutcome:
    """Result of :func:`call_guarded`: exactly one of ``value`` /
    ``error`` is meaningful (``ok`` tells which); ``retries`` counts
    attempts beyond the first; ``latency_s`` the total wall time."""

    ok: bool
    value: object
    error: Optional[OwnerError]
    retries: int
    latency_s: float


def call_guarded(
    fn: Callable[[int], object],
    *,
    owner: str,
    site: str,
    policy: RetryPolicy = DEFAULT_POLICY,
) -> GuardedOutcome:
    """Run ``fn(attempt_index)`` under ``policy``, capturing failure.

    ``fn`` receives the 0-based attempt index so callers can
    distinguish "use the already-dispatched handle" (attempt 0) from
    "re-dispatch fresh" (attempts >= 1) — a consumed async handle must
    not be collected twice.

    Never raises for ``fn``'s failures: returns a
    :class:`GuardedOutcome` whose ``error`` is the structured
    :class:`OwnerError` after the last attempt (or a deadline kill).
    ``BaseException``s that are not ``Exception`` (KeyboardInterrupt,
    SystemExit) propagate.
    """
    reg = obs.registry()
    start = time.monotonic()
    last: Optional[BaseException] = None
    attempt = 0
    while attempt < policy.max_attempts:
        if policy.deadline_s is not None and attempt > 0:
            if time.monotonic() - start >= policy.deadline_s:
                break
        if attempt > 0:
            reg.counter(
                "deepmap_fault_retries_total",
                "Retry attempts (beyond the first try), by site.",
            ).inc(site=site)
            pause = policy.backoff(attempt)
            if pause > 0.0:
                time.sleep(pause)
        try:
            value = fn(attempt)
        except Exception as exc:  # noqa: BLE001 — captured as OwnerError
            last = exc
            attempt += 1
            continue
        latency = time.monotonic() - start
        if policy.deadline_s is not None and latency >= policy.deadline_s:
            # The attempt "succeeded" but blew the owner deadline —
            # treat as failure so slow owners degrade instead of
            # stalling the plan (delay-injection exercises this).
            err = OwnerError(
                owner=owner, site=site, attempts=attempt + 1,
                error_type="DeadlineExceeded",
                message=f"owner exceeded deadline of {policy.deadline_s}s",
                deadline_exceeded=True,
            )
            _note_terminal(reg, site, deadline=True)
            return GuardedOutcome(
                ok=False, value=None, error=err,
                retries=attempt, latency_s=latency,
            )
        return GuardedOutcome(
            ok=True, value=value, error=None,
            retries=attempt, latency_s=latency,
        )
    latency = time.monotonic() - start
    deadline_hit = (
        policy.deadline_s is not None
        and latency >= policy.deadline_s
        and attempt < policy.max_attempts
    )
    if last is None:
        error_type, message = "DeadlineExceeded", (
            f"owner exceeded deadline of {policy.deadline_s}s before any attempt"
        )
    else:
        error_type, message = type(last).__name__, str(last)
    err = OwnerError(
        owner=owner, site=site, attempts=max(attempt, 1),
        error_type=error_type, message=message,
        deadline_exceeded=deadline_hit,
    )
    _note_terminal(reg, site, deadline=deadline_hit)
    return GuardedOutcome(
        ok=False, value=None, error=err,
        retries=max(attempt - 1, 0), latency_s=latency,
    )


def _note_terminal(reg, site: str, *, deadline: bool) -> None:
    reg.counter(
        "deepmap_fault_owner_errors_total",
        "Terminal owner failures after retries, by site and cause.",
    ).inc(site=site, cause="deadline" if deadline else "error")
