"""Shared storage substrate: compression codecs + bounded memory pool.

Used by both the DeepMapping auxiliary table (``repro.core.aux_table``)
and the paper's baselines (``repro.baselines``), so that compression and
eviction behaviour are identical across compared systems — the paper's
benchmark discipline (§V-A4/A5).
"""

from repro.storage.codecs import CODECS, Codec, get_codec  # noqa: F401
from repro.storage.pool import MemoryPool  # noqa: F401
