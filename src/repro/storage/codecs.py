"""Compression codec registry.

The paper evaluates Dictionary encoding, Gzip, Z-Standard and LZMA
(§V-A3) and tunes the compression level per use-case (§V-A4): zstd
level 1 for small-batch / latency-dominated workloads, higher levels
when decompression is off the critical path.  Codec identity strings
(``"zstd"``, ``"lzma"``, ...) are stable across save/load.

``zstandard`` (a third-party wheel) and ``lzma`` (absent from some
minimal CPython builds) are OPTIONAL: when unavailable, their codec
names stay registered but compress through stdlib ``zlib`` instead, so
a clean environment still imports, builds, and round-trips stores.
Decompression sniffs container magic bytes, so blobs written by the
fallback load fine on hosts that do have the real library (the reverse
— real-zstd blobs on a host without ``zstandard`` — raises a clear
error instead of corrupting).
"""

from __future__ import annotations

import dataclasses
import gzip
import zlib
from typing import Callable, Dict

try:  # pragma: no cover - exercised implicitly by the import
    import zstandard

    HAVE_ZSTD = True
except ImportError:  # clean environment: stdlib-only fallback
    zstandard = None
    HAVE_ZSTD = False

try:
    import lzma

    HAVE_LZMA = True
except ImportError:  # CPython built without _lzma
    lzma = None
    HAVE_LZMA = False

# Container magic bytes, used to route decompression when a codec name
# is served by the zlib fallback.
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"
_XZ_MAGIC = b"\xfd7zXZ\x00"
_ZLIB_FIRST_BYTE = 0x78


@dataclasses.dataclass(frozen=True)
class Codec:
    name: str
    compress: Callable[[bytes], bytes]
    decompress: Callable[[bytes], bytes]


def _fallback(canonical_name: str, native_magic: bytes, level: int) -> Codec:
    """zlib-backed stand-in for an unavailable library, keyed under the
    canonical codec name so configs/saved stores keep working."""

    def comp(data: bytes, _level=level) -> bytes:
        return zlib.compress(data, _level)

    def decomp(data: bytes) -> bytes:
        if data[:1] and data[0] == _ZLIB_FIRST_BYTE:
            return zlib.decompress(data)
        if data.startswith(native_magic):
            raise RuntimeError(
                f"blob was written with the real {canonical_name!r} codec "
                f"but the library is not installed in this environment"
            )
        return zlib.decompress(data)

    return Codec(f"{canonical_name}(zlib-fallback)", comp, decomp)


def _zstd(level: int) -> Codec:
    name = f"zstd{'' if level == 3 else level}"
    if not HAVE_ZSTD:
        return _fallback(name, _ZSTD_MAGIC, level=min(level, 9))

    def comp(data: bytes, _level=level) -> bytes:
        return zstandard.ZstdCompressor(level=_level).compress(data)

    def decomp(data: bytes) -> bytes:
        if data[:1] and data[0] == _ZLIB_FIRST_BYTE and not data.startswith(_ZSTD_MAGIC):
            return zlib.decompress(data)  # written by the fallback
        return zstandard.ZstdDecompressor().decompress(data)

    return Codec(name, comp, decomp)


def _lzma() -> Codec:
    if not HAVE_LZMA:
        return _fallback("lzma", _XZ_MAGIC, level=9)

    def decomp(data: bytes) -> bytes:
        if data[:1] and data[0] == _ZLIB_FIRST_BYTE:
            return zlib.decompress(data)  # written by the fallback
        return lzma.decompress(data)

    return Codec("lzma", lambda b: lzma.compress(b, preset=6), decomp)


CODECS: Dict[str, Codec] = {
    "none": Codec("none", lambda b: b, lambda b: b),
    "zstd": _zstd(3),
    "zstd1": _zstd(1),
    "zstd9": _zstd(9),
    "gzip": Codec(
        "gzip",
        lambda b: gzip.compress(b, compresslevel=6),
        gzip.decompress,
    ),
    "zlib": Codec("zlib", lambda b: zlib.compress(b, 6), zlib.decompress),
    "lzma": _lzma(),
}


def get_codec(name: str) -> Codec:
    try:
        return CODECS[name]
    except KeyError:
        raise KeyError(f"unknown codec {name!r}; have {sorted(CODECS)}") from None
