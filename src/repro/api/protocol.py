"""The formal ``MappingStore`` protocol — one lookup contract over
interchangeable store structures (learned-index tradition: RMI exposes
one ``lookup`` over trees of models; NeurStore one model-store API).

Every store in this repo — :class:`~repro.core.hybrid.DeepMappingStore`,
:class:`~repro.cluster.sharded_store.ShardedDeepMappingStore`,
:class:`~repro.baselines.array_store.ArrayStore`,
:class:`~repro.baselines.hash_store.HashStore` — subclasses
:class:`MappingStore` and is exercised by the shared conformance suite
(``tests/test_store_protocol.py``).

Conformance contract (what the suite checks):

1. ``lookup(keys, columns) -> (values, exists)``: values aligned with
   the request, NULL rows carry placeholder values and must be masked
   by ``exists``; zero-length key batches return typed empty columns
   and never reach inference/stack paths.
2. ``insert`` raises on existing keys and mutates nothing on reject;
   ``update`` raises on missing keys likewise; ``delete`` is
   idempotent.  All accept zero-length batches as no-ops.
3. ``range_lookup(lo, hi)`` / ``scan()`` return ``(keys, values)`` with
   keys ascending and every key existing.
4. ``size_breakdown()`` maps component name -> bytes and sums to
   ``size_bytes()``.
5. ``save(path)`` then ``type(store).load(path)`` (or ``repro.open``)
   round-trips: identical query results.
6. ``query()`` plans execute byte-identically to the direct methods,
   including after interleaved insert/delete/update, and projection
   pushdown (``select``) never changes selected-column bytes.
"""

from __future__ import annotations

import abc
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.api.plan import ExplainStats

#: Methods every conforming store must expose (used by the suite's
#: surface check; behavioural checks live in the parametrized tests).
CONFORMANCE_METHODS = (
    "lookup",
    "insert",
    "delete",
    "update",
    "range_lookup",
    "scan",
    "size_breakdown",
    "size_bytes",
    "save",
    "load",
    "query",
)


class MappingStore(abc.ABC):
    """Abstract base of every key->row store (learned or baseline)."""

    # ------------------------------------------------------------- required
    @property
    @abc.abstractmethod
    def columns(self) -> Tuple[str, ...]:
        """Value column names, in the store's canonical order."""

    @abc.abstractmethod
    def lookup(
        self, keys: np.ndarray, columns: Optional[Tuple[str, ...]] = None
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Batched exact-match lookup -> ``(values, exists)``."""

    @abc.abstractmethod
    def insert(self, keys: np.ndarray, columns: Dict[str, np.ndarray]) -> None:
        """Insert new rows; raises ``ValueError`` if any key exists."""

    @abc.abstractmethod
    def delete(self, keys: np.ndarray) -> None:
        """Delete rows (idempotent: missing keys are ignored)."""

    @abc.abstractmethod
    def update(self, keys: np.ndarray, columns: Dict[str, np.ndarray]) -> None:
        """Overwrite existing rows; raises ``ValueError`` on missing keys."""

    @abc.abstractmethod
    def size_breakdown(self) -> Dict[str, int]:
        """Bytes per storage component (the paper's Fig. 6 accounting)."""

    @abc.abstractmethod
    def save(self, path: str) -> None:
        """Persist to ``path`` (atomic).  ``type(store).load`` restores."""

    @classmethod
    @abc.abstractmethod
    def load(cls, path: str, pool=None) -> "MappingStore":
        """Restore a store saved by :meth:`save`."""

    @abc.abstractmethod
    def _range_keys(self, lo: int, hi: Optional[int]) -> np.ndarray:
        """Existing keys in ``[lo, hi)`` ascending (``hi=None`` =
        unbounded) — the key source for range/scan plans."""

    # ------------------------------------------------------ shared surface
    def _all_keys(self) -> np.ndarray:
        return self._range_keys(0, None)

    def range_lookup(
        self, lo: int, hi: int, columns: Optional[Tuple[str, ...]] = None
    ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        """Paper §IV-E first approach: range-filter the existence index,
        then answer the collected keys by batched lookup."""
        keys = self._range_keys(int(lo), int(hi))
        values, exists = self.lookup(keys, columns)
        assert bool(exists.all())
        return keys, values

    def scan(
        self, columns: Optional[Tuple[str, ...]] = None
    ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        """Full relation scan -> ``(keys, values)``, keys ascending."""
        keys = self._all_keys()
        values, exists = self.lookup(keys, columns)
        assert bool(exists.all())
        return keys, values

    def size_bytes(self) -> int:
        return sum(self.size_breakdown().values())

    def query(self):
        """Start a plan-based query: ``store.query().select(...)
        .where_keys(ks) | .where_range(lo, hi) | .scan() .execute()``."""
        from repro.api.query import Query  # local: avoids import cycle

        return Query(self)

    # ------------------------------------------- async lookup pipeline hooks
    def _dispatch_lookup(self, keys, columns=None, fanout=None):
        """Begin an async lookup; :meth:`_collect_lookup` finishes it.

        Model-backed stores override the pair so device inference for
        one batch overlaps host aux-merge/decode of another (the
        executor and serving engine dispatch batch *i+1* before
        collecting batch *i*).  The default defers everything to
        collect time — baseline stores have no device stage to
        overlap, so dispatch/collect degenerates to a plain call."""
        return (keys, columns, fanout)

    def _collect_lookup(self, handle):
        """Finish a lookup begun by :meth:`_dispatch_lookup` ->
        ``(values, exists, ExplainStats)``."""
        keys, columns, fanout = handle
        return self._lookup_with_stats(keys, columns, fanout=fanout)

    # ------------------------------------------------- executor stats hook
    def _lookup_with_stats(
        self,
        keys: np.ndarray,
        columns: Optional[Tuple[str, ...]] = None,
        fanout: Optional[bool] = None,
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray, ExplainStats]:
        """Lookup plus per-call :class:`ExplainStats` (no mutable
        side-channel).  Default wraps :meth:`lookup` with coarse
        timing; model-backed stores override with real stage
        breakdowns.  ``fanout`` is advisory (sharded stores only)."""
        t0 = time.perf_counter()
        values, exists = self.lookup(keys, columns)
        stats = ExplainStats(
            plan=("lookup",),
            heads_skipped=tuple(self.columns),  # no model heads ran
            columns_decoded=tuple(values),
            columns_skipped=tuple(c for c in self.columns if c not in values),
        )
        stats.decode_s = time.perf_counter() - t0
        return values, exists, stats
