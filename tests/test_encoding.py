import numpy as np
import pytest

from repro.core.encoding import KeyEncoder, ValueCodec, build_codecs, onehot_digits
import jax.numpy as jnp


class TestKeyEncoder:
    def test_width_covers_domain(self):
        enc = KeyEncoder(max_key=999, base=10)
        assert enc.width == 3 and enc.capacity == 1000
        enc = KeyEncoder(max_key=1000, base=10)
        assert enc.width == 4

    def test_digits_roundtrip(self):
        enc = KeyEncoder(max_key=99999, base=10)
        keys = np.array([0, 7, 123, 99999, 40205], dtype=np.int64)
        d = enc.digits(keys)
        recon = (d * enc._divisors[None, :]).sum(axis=1)
        np.testing.assert_array_equal(recon, keys)

    @pytest.mark.parametrize("base", [2, 10, 16, 64])
    def test_bases(self, base):
        enc = KeyEncoder(max_key=12345, base=base)
        keys = np.arange(0, 12346, 997, dtype=np.int64)
        d = enc.digits(keys)
        assert d.min() >= 0 and d.max() < base
        recon = (d.astype(np.int64) * enc._divisors[None, :]).sum(axis=1)
        np.testing.assert_array_equal(recon, keys)

    def test_out_of_range_raises(self):
        enc = KeyEncoder(max_key=99, base=10)
        with pytest.raises(ValueError):
            enc.digits(np.array([100]))
        with pytest.raises(ValueError):
            enc.digits(np.array([-1]))

    def test_onehot_matches_digits(self):
        enc = KeyEncoder(max_key=999, base=10)
        keys = np.array([42, 0, 999])
        oh = enc.onehot(keys)
        assert oh.shape == (3, 30)
        np.testing.assert_array_equal(oh.sum(axis=1), [3, 3, 3])
        d = enc.digits(keys)
        oh2 = np.asarray(onehot_digits(jnp.asarray(d), 10))
        np.testing.assert_array_equal(oh, oh2)

    def test_digits_jax_matches_numpy(self):
        enc = KeyEncoder(max_key=88888, base=7)
        keys = np.array([0, 1, 88888, 1234], dtype=np.int64)
        np.testing.assert_array_equal(
            np.asarray(enc.digits_jax(jnp.asarray(keys))), enc.digits(keys)
        )


class TestValueCodec:
    def test_factorize_decode(self):
        vals = np.array(["b", "a", "b", "c"])
        c = ValueCodec("col", vals)
        assert c.cardinality == 3
        np.testing.assert_array_equal(c.decode(c.codes), vals)

    def test_encode_unseen(self):
        c = ValueCodec("col", np.array([1, 2, 3]))
        codes, known = c.encode(np.array([2, 99]))
        assert known.tolist() == [True, False] and codes[1] == -1
        c.extend(np.array([99]))
        codes, known = c.encode(np.array([99]))
        assert known.all() and c.decode(codes)[0] == 99

    def test_build_codecs_order(self):
        cols = {"x": np.array([1, 1, 2]), "y": np.array(["p", "q", "p"])}
        codecs = build_codecs(cols)
        assert set(codecs) == {"x", "y"}
        assert codecs["y"].cardinality == 2


class TestValueCodecExtend:
    def test_batch_extend_first_occurrence_order(self):
        """Vectorized extend must assign codes in first-occurrence
        order, exactly like the old per-value np.append loop."""
        vc = ValueCodec("c", np.array([10, 20]))
        vc.extend(np.array([99, 20, 77, 99, 42, 77]))
        np.testing.assert_array_equal(vc.decode_map, [10, 20, 99, 77, 42])
        codes, known = vc.encode(np.array([42, 99, 77, 10]))
        assert known.all()
        np.testing.assert_array_equal(codes, [4, 2, 3, 0])

    def test_extend_strings_widen(self):
        vc = ValueCodec("c", np.array(["ab", "cd"]))
        vc.extend(np.array(["longer-string", "ab"]))
        assert vc.decode_map[2] == "longer-string"
        np.testing.assert_array_equal(vc.decode(np.array([0, 2])),
                                      ["ab", "longer-string"])

    def test_extend_empty_noop(self):
        vc = ValueCodec("c", np.array([1, 2]))
        vc.extend(np.array([], dtype=np.int64))
        assert vc.cardinality == 2

    def test_large_batch_single_concatenate(self):
        vc = ValueCodec("c", np.array([0]))
        vals = np.arange(5000)
        vc.extend(vals)
        assert vc.cardinality == 5000
        np.testing.assert_array_equal(vc.decode(vc.encode(vals)[0]), vals)


class TestPositionOps:
    @pytest.mark.parametrize("residues", [(), (7,), (5, 12)])
    def test_position_ops_reproduce_digits(self, residues):
        enc = KeyEncoder(99_999, base=10, residues=residues)
        keys = np.random.default_rng(0).integers(0, 100_000, 500).astype(np.int64)
        want = enc.digits(keys)
        ops = enc.position_ops()
        assert len(ops) == enc.width
        got = np.stack(
            [((keys % mod) // div) % enc.base for mod, div in ops], axis=1
        ).astype(np.int32)
        np.testing.assert_array_equal(got, want)
