"""``ShardedDeepMappingStore`` — a fleet of per-partition DeepMapping
stores behind one ``DeepMappingStore``-shaped facade.

Rationale (ROADMAP north star; RMI's tree-of-models; NeurStore's
many-small-models storage): K small memorization MLPs each owning a
key partition build faster (parallel, independent training), retrain
locally (only dirty shards pay Algorithm-3/4/5 debt), and bound lookup
tail latency (each shard's aux table and bitvector stay small).

Invariants the router relies on:

* routing is a pure function of the key — a key's owning shard never
  changes between build and retrain (the partitioner is immutable);
* every key belongs to exactly ONE shard, so scatter/gather is a
  permutation and `(values, exists)` match a single store built on the
  same table (NULL rows carry per-shard placeholder values — callers
  must respect the ``exists`` mask, same contract as the single store);
* all shards charge decompressed partitions to one shared
  :class:`~repro.storage.pool.MemoryPool`, so cluster memory pressure
  is bounded globally, not per shard.

On-disk layout (atomic tmp+rename, shards reuse ``core/serialize.py``):

    cluster/
      manifest.msgpack   — version, partitioner state, shard dirs,
                           per-shard counters
      shard_00000/       — one ``core.serialize`` store directory
      shard_00001/
      ...
"""

from __future__ import annotations

import dataclasses
import os
import shutil
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import msgpack
import numpy as np

from repro.cluster.partitioner import Partitioner, make_partitioner
from repro.cluster.router import ShardRouter
from repro.core.hybrid import DeepMappingConfig, DeepMappingStore, LookupStats
from repro.core.serialize import load_store, save_store
from repro.core.table import Table
from repro.storage import MemoryPool

MANIFEST_VERSION = 1


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Cluster-level knobs (per-shard knobs stay in DeepMappingConfig)."""

    num_shards: int = 4
    policy: str = "range"          # "range" (planner-balanced) | "hash"
    seed: int = 0                  # hash-policy mixing seed
    max_workers: Optional[int] = None  # build/retrain thread pool size


class ShardedDeepMappingStore:
    """K independent :class:`DeepMappingStore` shards behind a router.

    Drop-in for the single store everywhere the serving layer cares:
    ``lookup`` / ``insert`` / ``delete`` / ``update`` / ``range_lookup``
    / ``should_retrain`` / ``retrain`` / ``size_breakdown`` keep their
    signatures and semantics.
    """

    def __init__(
        self,
        partitioner: Partitioner,
        shards: List[DeepMappingStore],
        cluster: ClusterConfig,
        pool: MemoryPool,
    ):
        if partitioner.num_shards != len(shards):
            raise ValueError(
                f"partitioner maps to {partitioner.num_shards} shards, "
                f"got {len(shards)} stores"
            )
        self.partitioner = partitioner
        self.router = ShardRouter(partitioner)
        self.shards = shards
        self.cluster = cluster
        self.pool = pool
        self.last_stats = LookupStats()

    # ------------------------------------------------------------------ build
    @classmethod
    def build(
        cls,
        table: Table,
        config: DeepMappingConfig = DeepMappingConfig(),
        cluster: ClusterConfig = ClusterConfig(),
        pool: Optional[MemoryPool] = None,
        verbose: bool = False,
    ) -> "ShardedDeepMappingStore":
        """Partition ``table`` and train every shard (thread pool).

        The planner may return fewer than ``cluster.num_shards`` shards
        on tiny/degenerate tables (quantile boundaries collapse); hash
        partitioning of a small table raises if a shard would be empty
        — lower ``num_shards`` or use the range policy there.
        """
        partitioner = make_partitioner(
            cluster.policy, table.keys, cluster.num_shards, seed=cluster.seed
        )
        pool = pool if pool is not None else MemoryPool(1 << 30)
        router = ShardRouter(partitioner)
        batches = {b.shard_id: b for b in router.scatter(table.keys)}
        missing = [i for i in range(partitioner.num_shards) if i not in batches]
        if missing:
            raise ValueError(
                f"shards {missing} would be empty; lower num_shards or "
                f"use the 'range' policy (planner guarantees non-empty)"
            )
        sub_tables = [
            table.take(batches[i].positions) for i in range(partitioner.num_shards)
        ]

        def build_one(i: int) -> DeepMappingStore:
            return DeepMappingStore.build(
                sub_tables[i], config, pool=pool, verbose=False
            )

        with ThreadPoolExecutor(max_workers=cluster.max_workers) as ex:
            shards = list(ex.map(build_one, range(partitioner.num_shards)))
        store = cls(partitioner, shards, cluster, pool)
        if verbose:
            rows = [s.num_rows for s in shards]
            print(
                f"[cluster] built {len(shards)} {cluster.policy} shards, "
                f"rows/shard min={min(rows)} max={max(rows)}, "
                f"ratio {store.compression_ratio():.4f}"
            )
        return store

    # ---------------------------------------------------------------- lookup
    def lookup(
        self, keys: np.ndarray, columns: Optional[Tuple[str, ...]] = None
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Algorithm 1, scattered: route each key to its shard, batch
        per shard, gather results back in request order."""
        keys = np.asarray(keys, dtype=np.int64)
        stats = LookupStats()
        parts = []
        for batch in self.router.scatter(keys):
            shard = self.shards[batch.shard_id]
            vals, exists = shard.lookup(batch.keys, columns)
            s = shard.last_stats
            stats.infer_s += s.infer_s
            stats.exist_s += s.exist_s
            stats.aux_s += s.aux_s
            stats.decode_s += s.decode_s
            parts.append((batch, vals, exists))
        self.last_stats = stats
        values, exists = ShardRouter.gather(keys.shape[0], parts)
        if not values and keys.size == 0:
            # Empty request: keep the column structure of the facade.
            wanted = columns if columns is not None else tuple(self.shards[0].spec.tasks)
            values = {
                t: self.shards[0].codecs[t].decode(np.zeros(0, dtype=np.int32))
                for t in self.shards[0].spec.tasks
                if t in wanted
            }
        return values, exists

    def range_lookup(
        self, lo: int, hi: int, columns: Optional[Tuple[str, ...]] = None
    ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        """Range scatter (§IV-E): only shards whose ranges overlap
        ``[lo, hi)`` scan their existence index (all shards under hash
        partitioning); results merge in ascending key order."""
        all_keys, all_vals = [], []
        for sid in self.partitioner.shards_for_range(int(lo), int(hi)):
            shard = self.shards[int(sid)]
            keys = shard.vexist.keys_in_range(int(lo), int(hi))
            if keys.size == 0:
                continue
            vals, exists = shard.lookup(keys, columns)
            assert bool(exists.all())
            all_keys.append(keys)
            all_vals.append(vals)
        if not all_keys:
            return np.zeros(0, dtype=np.int64), {}
        keys = np.concatenate(all_keys)
        order = np.argsort(keys, kind="stable")
        values = {
            name: np.concatenate([v[name] for v in all_vals])[order]
            for name in all_vals[0]
        }
        return keys[order], values

    # ------------------------------------------------ modifications (Alg 3-5)
    def insert(self, keys: np.ndarray, columns: Dict[str, np.ndarray]) -> None:
        """Algorithm 3 per shard.  Validates against ALL shards before
        mutating ANY, so a duplicate key cannot leave the cluster
        half-inserted."""
        keys = np.asarray(keys, dtype=np.int64)
        batches = self.router.scatter(keys)
        for b in batches:
            if self.shards[b.shard_id].vexist.test(b.keys).any():
                raise ValueError("insert of existing key; use update()")
        for b in batches:
            self.shards[b.shard_id].insert(
                b.keys, ShardRouter.take_columns(columns, b.positions)
            )

    def delete(self, keys: np.ndarray) -> None:
        """Algorithm 4 per shard (idempotent, like the single store)."""
        keys = np.asarray(keys, dtype=np.int64)
        for b in self.router.scatter(keys):
            self.shards[b.shard_id].delete(b.keys)

    def update(self, keys: np.ndarray, columns: Dict[str, np.ndarray]) -> None:
        """Algorithm 5 per shard; all-exist validated before mutating."""
        keys = np.asarray(keys, dtype=np.int64)
        batches = self.router.scatter(keys)
        for b in batches:
            if not self.shards[b.shard_id].vexist.test(b.keys).all():
                raise ValueError("update of non-existing key; use insert()")
        for b in batches:
            self.shards[b.shard_id].update(
                b.keys, ShardRouter.take_columns(columns, b.positions)
            )

    # ------------------------------------------------------- lazy retrain
    def dirty_shards(self) -> List[int]:
        """Shard ids whose modified-bytes debt crossed the threshold."""
        return [i for i, s in enumerate(self.shards) if s.should_retrain()]

    def should_retrain(self) -> bool:
        return bool(self.dirty_shards())

    def retrain(
        self, shard_ids: Optional[Sequence[int]] = None, verbose: bool = False
    ) -> List[int]:
        """Rebuild ONLY the given (default: dirty) shards, in place.

        This is the sharding payoff over the single store's whole-
        relation retrain: modification debt is paid per partition.
        Returns the retrained shard ids.
        """
        ids = list(shard_ids) if shard_ids is not None else self.dirty_shards()

        def retrain_one(i: int) -> DeepMappingStore:
            return self.shards[i].retrain(verbose=False)

        if ids:
            with ThreadPoolExecutor(max_workers=self.cluster.max_workers) as ex:
                rebuilt = list(ex.map(retrain_one, ids))
            for i, store in zip(ids, rebuilt):
                self.shards[i] = store
        if verbose:
            print(f"[cluster] retrained shards {ids}")
        return ids

    def materialize(self) -> Table:
        """Reconstruct the full logical table, ascending key order."""
        tables = [s.materialize() for s in self.shards]
        keys = np.concatenate([t.keys for t in tables])
        order = np.argsort(keys, kind="stable")
        columns = {
            name: np.concatenate([t.columns[name] for t in tables])[order]
            for name in tables[0].columns
        }
        return Table(keys=keys[order], columns=columns)

    # ------------------------------------------------------------- accounting
    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @property
    def num_rows(self) -> int:
        return sum(s.num_rows for s in self.shards)

    @property
    def raw_bytes(self) -> int:
        return sum(s.raw_bytes for s in self.shards)

    @property
    def modified_bytes(self) -> int:
        return sum(s.modified_bytes for s in self.shards)

    def size_breakdown(self) -> Dict[str, int]:
        total: Dict[str, int] = {}
        for s in self.shards:
            for k, v in s.size_breakdown().items():
                total[k] = total.get(k, 0) + v
        return total

    def size_bytes(self) -> int:
        return sum(self.size_breakdown().values())

    def compression_ratio(self) -> float:
        return self.size_bytes() / max(1, self.raw_bytes)

    def memorized_fraction(self) -> float:
        aux_rows = sum(s.aux.num_rows for s in self.shards)
        return 1.0 - aux_rows / max(1, self.num_rows)


# ------------------------------------------------------------- serialization
def save_sharded_store(store: ShardedDeepMappingStore, path: str) -> None:
    """Directory-of-stores format: manifest + one ``core.serialize``
    directory per shard.  Atomic (tmp + rename), like the single-store
    format."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    shard_dirs = [f"shard_{i:05d}" for i in range(store.num_shards)]
    manifest = {
        "version": MANIFEST_VERSION,
        "partitioner": store.partitioner.to_state(),
        "cluster": {
            "num_shards": store.num_shards,
            "policy": store.cluster.policy,
            "seed": store.cluster.seed,
        },
        "shards": shard_dirs,
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    for shard, d in zip(store.shards, shard_dirs):
        save_store(shard, os.path.join(tmp, d))

    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def load_sharded_store(
    path: str, pool: Optional[MemoryPool] = None
) -> ShardedDeepMappingStore:
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    if manifest["version"] > MANIFEST_VERSION:
        raise ValueError(f"cluster manifest {manifest['version']} newer than reader")
    pool = pool if pool is not None else MemoryPool(1 << 30)
    partitioner = Partitioner.from_state(manifest["partitioner"])
    shards = [
        load_store(os.path.join(path, d), pool=pool) for d in manifest["shards"]
    ]
    cluster = ClusterConfig(
        num_shards=manifest["cluster"]["num_shards"],
        policy=manifest["cluster"]["policy"],
        seed=manifest["cluster"]["seed"],
    )
    return ShardedDeepMappingStore(partitioner, shards, cluster, pool)
