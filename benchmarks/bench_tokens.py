"""Beyond-paper benchmark: DeepMapping as the LM data pipeline's
compressed token store (DESIGN.md §4) — compression ratio vs zstd and
batch-materialization throughput."""

from __future__ import annotations

import time
from typing import Dict

import numpy as np
import zstandard

from benchmarks import common as C
from repro.core.hybrid import DeepMappingConfig
from repro.core.trainer import TrainConfig
from repro.data.loader import LoaderConfig, TokenBatchLoader
from repro.data.tokens import DeepMappingTokenStore, make_structured_tokens


def run(n_tokens: int = 60_000, vocab: int = 512) -> Dict:
    toks = make_structured_tokens(n_tokens, vocab=vocab, run_len=16, seed=0)
    raw_bytes = toks.astype(np.int32).nbytes
    zstd_bytes = len(zstandard.ZstdCompressor(level=3).compress(toks.tobytes()))

    store = DeepMappingTokenStore.build(
        toks,
        DeepMappingConfig(
            shared=(128, 64), private=(32,),
            train=TrainConfig(epochs=40, batch_size=8192),
        ),
    )
    loader = TokenBatchLoader(
        LoaderConfig(global_batch=8, seq_len=512, seed=0), store=store
    )
    ref = TokenBatchLoader(
        LoaderConfig(global_batch=8, seq_len=512, seed=0), tokens=toks
    )
    # losslessness check on a real batch
    np.testing.assert_array_equal(
        loader.batch_for_step(0)["tokens"], ref.batch_for_step(0)["tokens"]
    )

    t0 = time.perf_counter()
    steps = 5
    for s in range(steps):
        loader.batch_for_step(s)
    dt = (time.perf_counter() - t0) / steps
    toks_per_batch = 8 * 513

    C.emit(
        "tokens/compressed_pipeline",
        dt * 1e6,
        f"ratio_dm={store.size_bytes()/raw_bytes:.4f};"
        f"ratio_zstd={zstd_bytes/raw_bytes:.4f};"
        f"memorized={store.memorized_fraction():.3f};"
        f"tok_per_s={toks_per_batch/dt:.0f}",
    )
    return {
        "dm_bytes": store.size_bytes(),
        "zstd_bytes": zstd_bytes,
        "raw_bytes": raw_bytes,
        "batch_s": dt,
    }


if __name__ == "__main__":
    run()
