"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert, early
fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].
48L d_model=5120 40H (kv=8, head 128) expert d_ff=8192 vocab=202048.
Text backbone only (early-fusion image tokens arrive as embeddings)."""

from repro.configs.base import ArchSpec, register
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    experts_per_token=1,
    num_shared_experts=1,
    moe_d_ff=8192,
    rope_theta=500_000.0,
    capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="llama4-smoke",
    family="moe",
    num_layers=2,
    d_model=32,
    num_heads=4,
    num_kv_heads=2,
    head_dim=8,
    d_ff=64,
    vocab_size=128,
    num_experts=4,
    experts_per_token=1,
    num_shared_experts=1,
    moe_d_ff=32,
    capacity_factor=2.0,
    dtype="float32",
    remat="none",
)

SPEC = register(
    ArchSpec(
        arch_id="llama4-scout-17b-a16e",
        config=CONFIG,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        notes="Full attention -> long_500k skipped.",
    )
)
