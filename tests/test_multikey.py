"""Paper §III extensions: single-relation multi-key and cross-relation
(star-schema) mappings."""

import numpy as np
import pytest

from repro.core import DeepMappingConfig, Table
from repro.core.multikey import MultiKeyMapping, RelationGraph
from repro.core.trainer import TrainConfig

FAST = DeepMappingConfig(
    shared=(48,), private=(16,), train=TrainConfig(epochs=10, batch_size=512)
)


@pytest.fixture(scope="module")
def orders():
    n = 600
    keys = np.arange(n, dtype=np.int64)
    return Table(
        keys=keys,
        columns={
            "order_no": (10_000 + keys * 3).astype(np.int64),  # alt unique key
            "status": np.array(["F", "O", "P"])[(keys // 8) % 3],
            "clerk": ((keys // 4) % 50).astype(np.int32),
        },
    )


class TestMultiKeyMapping:
    def test_lookup_by_alternate_key(self, orders):
        mk = MultiKeyMapping.build(orders, [("order_no",)], FAST)
        q = orders.columns["order_no"][:50]
        vals, exists = mk.lookup(("order_no",), [q])
        assert exists.all()
        np.testing.assert_array_equal(vals["status"], orders.columns["status"][:50])
        np.testing.assert_array_equal(vals["clerk"], orders.columns["clerk"][:50])

    def test_multiple_choices_coexist(self, orders):
        mk = MultiKeyMapping.build(orders, [("__key__",), ("order_no",)], FAST)
        assert set(mk.key_choices) == {("__key__",), ("order_no",)}
        v1, e1 = mk.lookup(("__key__",), [orders.keys[:20]])
        v2, e2 = mk.lookup(("order_no",), [orders.columns["order_no"][:20]])
        assert e1.all() and e2.all()
        np.testing.assert_array_equal(v1["status"], v2["status"])

    def test_missing_alt_keys_null(self, orders):
        mk = MultiKeyMapping.build(orders, [("order_no",)], FAST)
        _, exists = mk.lookup(("order_no",), [np.array([1, 2, 3], dtype=np.int64)])
        assert not exists.any()

    def test_non_unique_key_choice_rejected(self, orders):
        with pytest.raises(ValueError, match="uniquely"):
            MultiKeyMapping.build(orders, [("status",)], FAST)

    def test_composite_string_key(self):
        n = 200
        keys = np.arange(n, dtype=np.int64)
        t = Table(
            keys=keys,
            columns={
                "region": np.array(["EU", "US"])[keys % 2],
                "seq": (keys // 2).astype(np.int64),
                "val": ((keys // 4) % 7).astype(np.int32),
            },
        )
        mk = MultiKeyMapping.build(t, [("region", "seq")], FAST)
        vals, exists = mk.lookup(
            ("region", "seq"), [t.columns["region"][:30], t.columns["seq"][:30]]
        )
        assert exists.all()
        np.testing.assert_array_equal(vals["val"], t.columns["val"][:30])
        # unseen region string -> NULL, not crash
        _, e = mk.lookup(("region", "seq"), [np.array(["XX"]), np.array([0])])
        assert not e.any()


class TestRelationGraph:
    def test_star_schema_two_hop(self):
        dim_keys = np.arange(40, dtype=np.int64)
        dim = Table(
            keys=dim_keys,
            columns={"part_name": np.array([f"part{i % 10}" for i in dim_keys])},
        )
        n = 500
        fact_keys = np.arange(n, dtype=np.int64)
        fk = ((fact_keys * 7) % 40).astype(np.int32)
        fact = Table(
            keys=fact_keys,
            columns={"part_sk": fk, "qty": ((fact_keys // 8) % 5).astype(np.int32)},
        )
        g = RelationGraph()
        g.add_relation("part", dim, FAST)
        g.add_relation("sales", fact, FAST)
        g.add_foreign_key("sales", "part_sk", "part")

        vals, exists = g.lookup_through("sales", fact_keys[:64], "part_sk",
                                        columns=("part_name",))
        assert exists.all()
        want = dim.columns["part_name"][fk[:64]]
        np.testing.assert_array_equal(vals["part_name"], want)

    def test_unknown_fk_raises(self):
        g = RelationGraph()
        t = Table(keys=np.arange(10), columns={"x": np.zeros(10, np.int32)})
        g.add_relation("a", t, FAST)
        with pytest.raises(KeyError):
            g.add_foreign_key("a", "x", "missing")

    def test_size_accounting(self):
        t = Table(keys=np.arange(50), columns={"x": (np.arange(50) % 3).astype(np.int32)})
        g = RelationGraph()
        g.add_relation("a", t, FAST)
        assert g.size_bytes() > 0
