"""Unified store API: the :class:`MappingStore` protocol, the
plan-based streaming query layer, cross-store federation, and the
``repro.open`` / ``repro.build`` entrypoints.

Store implementations (``repro.core``, ``repro.cluster``,
``repro.baselines``) subclass :class:`MappingStore`; this package never
imports them at module level (they import us), so the dependency
direction stays acyclic: ``api <- stores <- serve/benchmarks``.
"""

from repro.api.cache import PlanCache, plan_fingerprint  # noqa: F401
from repro.api.entry import build, open  # noqa: F401,A004
from repro.api.executor import (  # noqa: F401
    MorselResult,
    execute_plan,
    execute_plan_staged,
    execute_plans,
    next_morsel_rows,
    stream_plan,
)
from repro.api.federated import FederatedStore  # noqa: F401
from repro.api.plan import (  # noqa: F401
    AggregateResult,
    AggSpec,
    ExplainStats,
    JoinSpec,
    OperatorStats,
    Predicate,
    QueryPlan,
    QueryResult,
    evaluate_predicates,
)
from repro.api.protocol import CONFORMANCE_METHODS, MappingStore  # noqa: F401
from repro.api.query import Query  # noqa: F401
