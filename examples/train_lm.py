"""Train a small LM with the full substrate: DeepMapping-compressed
token store feeding the loader, fault-tolerant runner with atomic
checkpoints, any --arch from the assigned pool (reduced smoke config).

    PYTHONPATH=src python examples/train_lm.py --arch tinyllama-1.1b --steps 30
"""

import argparse
import tempfile

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.hybrid import DeepMappingConfig
from repro.core.trainer import TrainConfig
from repro.data.loader import LoaderConfig, TokenBatchLoader
from repro.data.tokens import DeepMappingTokenStore, make_structured_tokens
from repro.train.fault_tolerance import run_training
from repro.train.optimizer import adamw, warmup_cosine
from repro.train.train_step import init_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--compressed-data", action="store_true",
                    help="feed batches through the DeepMapping token store")
    args = ap.parse_args()

    cfg = get_arch(args.arch).smoke
    if cfg.is_encoder_decoder or cfg.modality != "text":
        raise SystemExit(f"{args.arch}: use a text decoder arch for this example")

    toks = make_structured_tokens(50_000, vocab=cfg.vocab_size, run_len=8, seed=0)
    loader_cfg = LoaderConfig(global_batch=args.batch, seq_len=args.seq, seed=0)
    if args.compressed_data:
        store = DeepMappingTokenStore.build(
            toks,
            DeepMappingConfig(shared=(128,), private=(32,),
                              train=TrainConfig(epochs=25, batch_size=8192)),
            verbose=True,
        )
        print(f"token store ratio={store.compression_ratio():.4f} "
              f"memorized={store.memorized_fraction():.1%}")
        loader = TokenBatchLoader(loader_cfg, store=store)
    else:
        loader = TokenBatchLoader(loader_cfg, tokens=toks)

    opt = adamw(lr=warmup_cosine(3e-3, 5, args.steps), max_grad_norm=1.0)
    state = init_state(cfg, opt, seed=0)
    step_fn = jax.jit(make_train_step(cfg, opt))

    def batch_fn(step):
        return {k: np.asarray(v) for k, v in loader.batch_for_step(step).items()}

    with tempfile.TemporaryDirectory() as ckpt_dir:
        report = run_training(
            step_fn, state, batch_fn, num_steps=args.steps,
            ckpt_dir=ckpt_dir, ckpt_every=10,
        )
    print(f"\narch={args.arch} steps={report.final_step} restarts={report.restarts}")
    print(f"loss: {report.losses[0]:.4f} -> {report.losses[-1]:.4f}")
    assert report.losses[-1] < report.losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
