"""Query-plan IR for the unified store API.

A :class:`QueryPlan` is the small declarative description the
:class:`~repro.api.query.Query` builder compiles to and the executor
(`repro.api.executor`) runs.  Plans have one *key source* (explicit
keys, a key range, or a full scan), an optional column projection
(pushed down so unselected columns are neither decoded nor — for
DeepMapping stores — even evaluated by their private model heads), and
an optional shard fan-out override.

Execution produces a :class:`QueryResult` carrying per-plan
:class:`ExplainStats` — the replacement for the mutable ``last_stats``
side-channel: every result owns its own immutable stats object, so
concurrent queries on one store cannot trample each other's timings.

This module is dependency-light on purpose (numpy only): the store
implementations import it, so it must not import them back.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

#: Valid ``QueryPlan.kind`` values.
PLAN_KINDS = ("point", "range", "scan")


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """Declarative query description — what to fetch, not how.

    ``kind`` selects the key source: ``"point"`` answers the explicit
    ``keys`` array, ``"range"`` every existing key in ``[lo, hi)``,
    ``"scan"`` every existing key.  ``columns`` is the projection
    (``None`` = all columns); ``fanout`` overrides the sharded store's
    parallel lookup fan-out (``None`` = store default, which is *on*
    for plan execution and *off* for the legacy ``lookup`` shim).
    """

    kind: str
    keys: Optional[np.ndarray] = None
    lo: Optional[int] = None
    hi: Optional[int] = None
    columns: Optional[Tuple[str, ...]] = None
    fanout: Optional[bool] = None

    def __post_init__(self) -> None:
        if self.kind not in PLAN_KINDS:
            raise ValueError(f"unknown plan kind {self.kind!r}; have {PLAN_KINDS}")
        if self.kind == "point" and self.keys is None:
            raise ValueError("point plan needs keys")
        if self.kind == "range" and (self.lo is None or self.hi is None):
            raise ValueError("range plan needs lo and hi")

    def source_stage(self) -> str:
        """Human-readable key-source stage name for explain output."""
        if self.kind == "point":
            return f"keys[{0 if self.keys is None else len(self.keys)}]"
        if self.kind == "range":
            return f"range[{self.lo},{self.hi})"
        return "scan"


@dataclasses.dataclass
class ExplainStats:
    """Per-plan execution report (the paper's Fig. 7 latency breakdown,
    plus pushdown and fan-out evidence).

    ``plan`` lists the executed pipeline stages in order.
    ``heads_evaluated``/``heads_skipped`` record which model private
    heads ran (DeepMapping stores only — baselines always report all
    heads skipped since they have no model); ``columns_decoded``/
    ``columns_skipped`` record the decode projection every store type
    honours.  Timings are seconds; under shard fan-out the per-stage
    times are summed across shards (CPU time), while ``total_s`` is
    wall clock.
    """

    kind: str = ""
    plan: Tuple[str, ...] = ()
    num_keys: int = 0
    num_rows: int = 0
    shards_visited: int = 0
    async_fanout: bool = False
    heads_evaluated: Tuple[str, ...] = ()
    heads_skipped: Tuple[str, ...] = ()
    columns_decoded: Tuple[str, ...] = ()
    columns_skipped: Tuple[str, ...] = ()
    route_s: float = 0.0
    infer_s: float = 0.0
    exist_s: float = 0.0
    aux_s: float = 0.0
    decode_s: float = 0.0
    total_s: float = 0.0

    def merge_timings(self, other: "ExplainStats") -> None:
        """Accumulate another stats object's stage timings (shard
        fan-out / server batch aggregation)."""
        self.route_s += other.route_s
        self.infer_s += other.infer_s
        self.exist_s += other.exist_s
        self.aux_s += other.aux_s
        self.decode_s += other.decode_s


@dataclasses.dataclass
class QueryResult:
    """Executed plan output.

    ``values`` maps column name -> decoded array aligned with ``keys``;
    ``exists`` is the existence mask (all-True for range/scan results,
    whose keys come from the existence index).  Rows where ``exists``
    is False carry placeholder values — callers must respect the mask,
    the same contract as the legacy ``lookup``.
    """

    keys: np.ndarray
    values: Dict[str, np.ndarray]
    exists: np.ndarray
    explain: ExplainStats

    @property
    def num_rows(self) -> int:
        return int(self.exists.sum())
