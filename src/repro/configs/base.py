"""Architecture registry: full assigned configs + reduced smoke twins.

Every assigned architecture registers an :class:`ArchSpec` with
* ``config`` — the EXACT dimensions from the assignment (full scale,
  only ever lowered via ShapeDtypeStruct in the dry-run);
* ``smoke``  — a reduced same-family config for CPU tests;
* ``shapes`` — which assigned input-shape cells apply (decode cells need
  a decoder; ``long_500k`` needs sub-quadratic sequence handling — see
  DESIGN.md §5 for the skip rationale).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.models.config import ModelConfig

# Assigned input shapes (LM shapes are seq_len x global_batch).
SHAPES: Dict[str, Dict] = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524288, "global_batch": 1, "kind": "decode"},
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    config: ModelConfig
    smoke: ModelConfig
    shapes: Tuple[str, ...]
    notes: str = ""


_REGISTRY: Dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    if spec.arch_id in _REGISTRY:
        raise ValueError(f"duplicate arch {spec.arch_id}")
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    # import side-effect registration
    import repro.configs  # noqa: F401

    try:
        return _REGISTRY[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}") from None


def list_archs() -> Tuple[str, ...]:
    import repro.configs  # noqa: F401

    return tuple(sorted(_REGISTRY))
