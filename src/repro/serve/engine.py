"""Batched lookup serving engine — the paper's deployment scenario.

Requests (key batches) are queued, merged into device-sized batches,
deduplicated, sorted (so each T_aux partition is decompressed at most
once per batch — §IV-B2), answered via the hybrid store, and scattered
back to requesters.

Merged batches run as a two-stage software pipeline over the store's
``_dispatch_lookup``/``_collect_lookup`` hooks: batch *i+1*'s device
work is enqueued (JAX async dispatch returns immediately) before batch
*i*'s host half — existence fallback, aux merge, decode, scatter —
runs, so consecutive merged batches overlap while the sliding window
keeps at most two batches in flight (device residency stays bounded
for arbitrarily large merged requests).  For baseline stores the hooks
degenerate to plain synchronous calls (no device stage to overlap), so
the pipeline is a no-op there.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.api.executor import execute_plan
from repro.api.plan import QueryPlan
from repro.api.protocol import MappingStore


@dataclasses.dataclass
class ServeStats:
    requests: int = 0
    keys: int = 0
    batches: int = 0
    total_s: float = 0.0
    infer_s: float = 0.0
    exist_s: float = 0.0
    aux_s: float = 0.0
    decode_s: float = 0.0

    def qps(self) -> float:
        return self.keys / self.total_s if self.total_s else 0.0


class LookupServer:
    """Merge-batch server over any :class:`~repro.api.protocol.MappingStore`
    (single, sharded, or baseline).

    Merged batches execute through the store's dispatch/collect hooks,
    so the server gets the unified pipeline — projection pushdown,
    sharded thread-pool fan-out, infer/aux overlap across consecutive
    merged batches, per-batch stats — for free; merged batches arrive
    at the store sorted, so the sharded store's scatter sees at most
    one contiguous run per shard.
    """

    def __init__(
        self,
        store: MappingStore,
        max_batch: int = 65536,
    ):
        self.store = store
        self.max_batch = max_batch
        self.stats = ServeStats()

    def lookup(
        self, keys: np.ndarray, columns: Optional[Tuple[str, ...]] = None
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Single-request path (still batched internally)."""
        return self.lookup_many([keys], columns)[0]

    def lookup_many(
        self,
        requests: List[np.ndarray],
        columns: Optional[Tuple[str, ...]] = None,
    ) -> List[Tuple[Dict[str, np.ndarray], np.ndarray]]:
        """Merge several key-batch requests into deduplicated device
        batches; scatter results back per request.  Device inference of
        batch *i+1* overlaps the host half of batch *i*."""
        if not requests:
            return []  # np.concatenate rejects an empty list
        t0 = time.perf_counter()
        lens = [len(r) for r in requests]
        merged = np.concatenate([np.asarray(r, dtype=np.int64) for r in requests])
        uniq, inverse = np.unique(merged, return_inverse=True)  # sorted + dedup

        chunks: Dict[str, List[np.ndarray]] = {}
        exists_u = np.zeros(uniq.shape[0], dtype=bool)
        cols = tuple(columns) if columns is not None else None
        if uniq.shape[0] == 0:
            # All requests zero-length: run one empty plan anyway so
            # callers still get typed empty columns (same contract as
            # the stores' own zero-batch lookups).
            res = execute_plan(
                self.store, QueryPlan(kind="point", keys=uniq, columns=cols)
            )
            for c, arr in res.values.items():
                chunks[c] = [arr]
        # Two-stage pipeline over a small sliding window of batches:
        # dispatch batch i+1's device work before collecting batch i,
        # without enqueueing the whole merged request at once (the
        # store layer bounds per-batch residency; this bounds batches).
        # Columns pass straight to the hook so unknown names degrade to
        # "ignored", like the legacy lookup did; fanout=True keeps the
        # sharded store's thread-pool fan-out, matching plan execution.
        def collect(start, handle):
            vals, exists, stats = self.store._collect_lookup(handle)
            exists_u[start : start + self.max_batch] = exists
            for c, arr in vals.items():
                chunks.setdefault(c, []).append(arr)
            self.stats.batches += 1
            self.stats.infer_s += stats.infer_s
            self.stats.exist_s += stats.exist_s
            self.stats.aux_s += stats.aux_s
            self.stats.decode_s += stats.decode_s

        window: List = []
        for start in range(0, uniq.shape[0], self.max_batch):
            window.append((start, self.store._dispatch_lookup(
                uniq[start : start + self.max_batch], cols, fanout=True
            )))
            if len(window) >= 2:  # one batch in flight ahead of the host
                collect(*window.pop(0))
        for start, handle in window:
            collect(start, handle)
        # Concatenate per column (rather than filling a preallocated
        # buffer) so chunks that disagree on dtype — e.g. a baseline
        # store's int placeholder chunk before a string chunk —
        # promote instead of crashing or truncating.
        vals_u = {c: np.concatenate(parts) for c, parts in chunks.items()}

        out: List[Tuple[Dict[str, np.ndarray], np.ndarray]] = []
        off = 0
        for n in lens:
            sel = inverse[off : off + n]
            out.append(({c: a[sel] for c, a in vals_u.items()}, exists_u[sel]))
            off += n
        self.stats.requests += len(requests)
        self.stats.keys += int(sum(lens))
        self.stats.total_s += time.perf_counter() - t0
        return out
