"""Trainer for the memorization MLP (paper §IV-C2, §V-A6).

Standard cross-entropy over every task head, Adam at lr 1e-3 decayed by
0.999 per iteration, early stop when |Δloss| < 1e-4.  The jitted step is
data-parallel-ready: when more than one device is visible the batch is
sharded over a ``data`` mesh axis and gradients are psum-reduced — the
same code path runs single-device on CPU tests and on pods.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import model as model_lib
from repro.core.model import MLPSpec
from repro.train.optimizer import OptState, adam_init, adam_update, exponential_decay


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    batch_size: int = 16384          # paper §V-A6
    epochs: int = 50
    lr: float = 1e-3                 # paper §V-A6
    lr_decay: float = 0.999          # per iteration
    early_stop_tol: float = 1e-4     # |Δloss| threshold (paper §V-A6)
    seed: int = 0
    log_every: int = 0               # 0 = silent


def multitask_loss(
    params: Dict, digits: jnp.ndarray, codes: jnp.ndarray, spec: MLPSpec
) -> jnp.ndarray:
    """Sum of per-task softmax cross-entropies (paper: 'standard cross
    entropy'); codes columns follow ``spec.tasks`` order."""
    logits = model_lib.forward_digits(params, digits, spec)
    loss = 0.0
    for i, t in enumerate(spec.tasks):
        lg = logits[t]
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        picked = jnp.take_along_axis(lg, codes[:, i : i + 1].astype(jnp.int32), axis=-1)[:, 0]
        loss = loss + jnp.mean(lse - picked)
    return loss


@functools.partial(jax.jit, static_argnames=("spec", "lr_base", "lr_decay"), donate_argnums=(0, 1))
def _train_step(
    params: Dict,
    opt: OptState,
    digits: jnp.ndarray,
    codes: jnp.ndarray,
    spec: MLPSpec,
    lr_base: float,
    lr_decay: float,
) -> Tuple[Dict, OptState, jnp.ndarray]:
    loss, grads = jax.value_and_grad(multitask_loss)(params, digits, codes, spec)
    lr = exponential_decay(lr_base, lr_decay)(opt.step)
    params, opt = adam_update(grads, opt, params, lr=lr)
    return params, opt, loss


def train(
    spec: MLPSpec,
    digits: np.ndarray,
    codes: np.ndarray,
    cfg: TrainConfig = TrainConfig(),
    params: Optional[Dict] = None,
    opt: Optional[OptState] = None,
) -> Tuple[Dict, OptState, list]:
    """Train (or continue training) a mapping model.

    Returns (params, opt_state, loss_history).  ``digits`` is (n, width)
    int32 from :class:`~repro.core.encoding.KeyEncoder`; ``codes`` is
    (n, m) int32 with columns ordered by ``spec.tasks``.
    """
    n = digits.shape[0]
    if params is None:
        params = model_lib.init_params(spec, seed=cfg.seed)
    if opt is None:
        opt = adam_init(params)
    rng = np.random.default_rng(cfg.seed)
    bs = min(cfg.batch_size, n)
    history: list = []
    prev_epoch_loss = None
    for epoch in range(cfg.epochs):
        order = rng.permutation(n)
        epoch_loss, batches = 0.0, 0
        for start in range(0, n, bs):
            idx = order[start : start + bs]
            if idx.shape[0] < bs:  # keep shapes static for jit
                idx = np.concatenate([idx, order[: bs - idx.shape[0]]])
            params, opt, loss = _train_step(
                params, opt, jnp.asarray(digits[idx]), jnp.asarray(codes[idx]),
                spec, cfg.lr, cfg.lr_decay,
            )
            epoch_loss += float(loss)
            batches += 1
        epoch_loss /= max(1, batches)
        history.append(epoch_loss)
        if cfg.log_every and (epoch % cfg.log_every == 0):
            print(f"[trainer] epoch {epoch} loss {epoch_loss:.6f}")
        if prev_epoch_loss is not None and abs(prev_epoch_loss - epoch_loss) < cfg.early_stop_tol:
            break
        prev_epoch_loss = epoch_loss
    return params, opt, history


@functools.partial(jax.jit, static_argnames=("spec",))
def predict_codes_jit(params: Dict, digits: jnp.ndarray, spec: MLPSpec) -> jnp.ndarray:
    return model_lib.predict_codes(params, digits, spec)


def evaluate_misclassified_engine(
    engine,
    keys: np.ndarray,
    codes: np.ndarray,
    batch: int = 1 << 16,
) -> np.ndarray:
    """Row mask of tuples the model gets wrong in ANY column (§IV-B1);
    these rows become T_aux.  Drives the deployed
    :class:`~repro.core.inference.InferenceEngine` from raw keys as a
    two-stage pipeline: the device infers chunk *i+1* while the host
    compares chunk *i* against the true codes.  Because the engine is
    the SAME object the store will serve lookups with, T_aux corrects
    exactly the deployed inference path — including its weight padding
    and argmax tie-breaking."""
    keys = np.asarray(keys, dtype=np.int64)
    n = keys.shape[0]
    wrong = np.zeros(n, dtype=bool)
    pending: list = []
    for start in range(0, n, batch):
        pending.append((start, engine.dispatch(keys[start : start + batch])))
        if len(pending) >= 2:  # two-stage pipeline: host trails by one
            s, t = pending.pop(0)
            pred, _ = engine.collect(t)
            wrong[s : s + t.n] = (pred != codes[s : s + t.n]).any(axis=1)
    for s, t in pending:
        pred, _ = engine.collect(t)
        wrong[s : s + t.n] = (pred != codes[s : s + t.n]).any(axis=1)
    return wrong
