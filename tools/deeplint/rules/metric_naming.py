"""Rule ``metric-naming``: ``deepmap_*`` metric names and bounded labels.

Two checks over every ``.counter(...)`` / ``.gauge(...)`` /
``.histogram(...)`` call with a literal name:

* Naming: names match ``deepmap_[a-z0-9_]+``; counters end ``_total``;
  histograms end in a unit suffix (``_seconds``/``_rows``/``_keys``/
  ``_bytes``); gauges must *not* end ``_total``.
* Bounded labels: label keyword values passed to ``.inc``/``.dec``/
  ``.observe``/``.set`` must not be f-strings, ``%``-formatting, or
  ``.format(...)`` calls — interpolated labels have unbounded
  cardinality and blow up the registry under real traffic.
"""

from __future__ import annotations

import ast
import re
from typing import Iterable, List

from tools.deeplint.engine import Finding, Project

RULE_ID = "metric-naming"
SUMMARY = "deepmap_* metric naming and bounded-cardinality label lint"

NAME_RE = re.compile(r"^deepmap_[a-z][a-z0-9_]*$")
HISTOGRAM_SUFFIXES = ("_seconds", "_rows", "_keys", "_bytes")
FAMILY_METHODS = {"counter", "gauge", "histogram"}
RECORD_METHODS = {"inc", "dec", "observe", "set"}


def _check_name(kind: str, name: str) -> str | None:
    if not NAME_RE.match(name):
        return (
            f"metric name {name!r} must match deepmap_[a-z0-9_]+ "
            "(project namespace prefix)"
        )
    if kind == "counter" and not name.endswith("_total"):
        return f"counter {name!r} must end with _total"
    if kind == "histogram" and not name.endswith(HISTOGRAM_SUFFIXES):
        return (
            f"histogram {name!r} must end with a unit suffix "
            f"({'/'.join(HISTOGRAM_SUFFIXES)})"
        )
    if kind == "gauge" and name.endswith("_total"):
        return f"gauge {name!r} must not end with _total (reserved for counters)"
    return None


def _unbounded(value: ast.expr) -> bool:
    if isinstance(value, ast.JoinedStr):
        return any(isinstance(v, ast.FormattedValue) for v in value.values)
    if isinstance(value, ast.BinOp) and isinstance(value.op, ast.Mod):
        return isinstance(value.left, (ast.Constant, ast.JoinedStr))
    if (
        isinstance(value, ast.Call)
        and isinstance(value.func, ast.Attribute)
        and value.func.attr == "format"
    ):
        return True
    return False


def check(project: Project) -> Iterable[Finding]:
    findings: List[Finding] = []
    for src in project.modules:
        for node in ast.walk(src.tree):
            if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)):
                continue
            attr = node.func.attr
            if attr in FAMILY_METHODS:
                if node.args and isinstance(node.args[0], ast.Constant) and isinstance(
                    node.args[0].value, str
                ):
                    msg = _check_name(attr, node.args[0].value)
                    if msg:
                        findings.append(src.finding(RULE_ID, node, msg))
            elif attr in RECORD_METHODS:
                for kw in node.keywords:
                    if kw.arg is not None and _unbounded(kw.value):
                        findings.append(
                            src.finding(
                                RULE_ID,
                                node,
                                f"label {kw.arg!r} is interpolated at the call "
                                "site (unbounded cardinality); pass a bounded "
                                "categorical value instead",
                            )
                        )
    return findings
