"""qwen2-7b — dense GQA with QKV bias [arXiv:2407.10671].
28L d_model=3584 28H (kv=4, head 128) d_ff=18944 vocab=152064."""

from repro.configs.base import ArchSpec, register
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-smoke",
    family="dense",
    num_layers=2,
    d_model=56,
    num_heads=4,
    num_kv_heads=2,
    head_dim=14,
    d_ff=112,
    vocab_size=128,
    qkv_bias=True,
    dtype="float32",
    remat="none",
)

SPEC = register(
    ArchSpec(
        arch_id="qwen2-7b",
        config=CONFIG,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        notes="Pure full attention -> long_500k skipped.",
    )
)
