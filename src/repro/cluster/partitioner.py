"""Key-space partitioning policies for the sharded DeepMapping cluster.

A :class:`Partitioner` maps every int64 key to one of ``K`` shard ids.
Two policies, mirroring the classic learned-index split (RMI assigns
contiguous key sub-ranges to leaf models; hash partitioning trades
range locality for load uniformity under adversarial key skew):

* :class:`RangePartitioner` — contiguous key ranges split at planner-
  chosen boundary keys.  Range queries touch only the overlapping
  shards; the size-balanced planner picks boundaries at row-count
  quantiles of the build keys so every shard trains on ~n/K rows.
* :class:`HashPartitioner` — a SplitMix64-style bit mixer mod ``K``.
  Every shard sees a uniform sample of the key domain; range queries
  must scatter to all shards.

Both are deterministic pure functions of the key (routing never
consults shard contents), serialize to a msgpack-friendly state dict,
and round-trip through the cluster manifest.
"""

from __future__ import annotations

from typing import Dict, Sequence

import numpy as np


class Partitioner:
    """Deterministic key -> shard-id mapping."""

    policy: str = "abstract"

    @property
    def num_shards(self) -> int:
        raise NotImplementedError

    def shard_of(self, keys: np.ndarray) -> np.ndarray:
        """Vectorized shard id for each key (int64 in, int64 out)."""
        raise NotImplementedError

    def shards_for_range(self, lo: int, hi: int) -> np.ndarray:
        """Shard ids that may hold keys in ``[lo, hi)`` — the router's
        range-scatter set.  Must be a superset of the true set."""
        raise NotImplementedError

    # -- manifest round-trip -------------------------------------------------
    def to_state(self) -> Dict:
        raise NotImplementedError

    @staticmethod
    def from_state(state: Dict) -> "Partitioner":
        policy = state["policy"]
        if policy == RangePartitioner.policy:
            return RangePartitioner(state["boundaries"])
        if policy == HashPartitioner.policy:
            return HashPartitioner(state["num_shards"], seed=state["seed"])
        raise ValueError(f"unknown partition policy {policy!r}")


class RangePartitioner(Partitioner):
    """Contiguous key ranges: shard ``i`` owns ``[b[i-1], b[i])`` with
    ``b`` the sorted boundary keys (``b[-1]`` is open-ended).  Keys
    below the first boundary belong to shard 0; there are ``K-1``
    interior boundaries for ``K`` shards."""

    policy = "range"

    def __init__(self, boundaries: Sequence[int]):
        self._boundaries = np.asarray(sorted(boundaries), dtype=np.int64)
        if np.unique(self._boundaries).size != self._boundaries.size:
            raise ValueError("range boundaries must be distinct")

    @property
    def num_shards(self) -> int:
        return int(self._boundaries.size) + 1

    @property
    def boundaries(self) -> np.ndarray:
        return self._boundaries

    def shard_of(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, dtype=np.int64)
        return np.searchsorted(self._boundaries, keys, side="right")

    def shards_for_range(self, lo: int, hi: int) -> np.ndarray:
        if hi <= lo:
            return np.zeros(0, dtype=np.int64)
        first = int(np.searchsorted(self._boundaries, lo, side="right"))
        last = int(np.searchsorted(self._boundaries, hi - 1, side="right"))
        return np.arange(first, last + 1, dtype=np.int64)

    def to_state(self) -> Dict:
        return {"policy": self.policy, "boundaries": self._boundaries.tolist()}


def _splitmix64(keys: np.ndarray, seed: int) -> np.ndarray:
    """SplitMix64 finalizer — avalanches low-entropy (dense, strided)
    key patterns so ``mixed % K`` is uniform.  Pure uint64 numpy."""
    z = keys.astype(np.uint64) + np.uint64(seed) + np.uint64(0x9E3779B97F4A7C15)
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


class HashPartitioner(Partitioner):
    """Uniform hash partitioning: ``splitmix64(key, seed) % K``."""

    policy = "hash"

    def __init__(self, num_shards: int, seed: int = 0):
        if num_shards < 1:
            raise ValueError("need at least one shard")
        self._num_shards = int(num_shards)
        self.seed = int(seed)

    @property
    def num_shards(self) -> int:
        return self._num_shards

    def shard_of(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, dtype=np.int64)
        return (_splitmix64(keys, self.seed) % np.uint64(self._num_shards)).astype(
            np.int64
        )

    def shards_for_range(self, lo: int, hi: int) -> np.ndarray:
        if hi <= lo:
            return np.zeros(0, dtype=np.int64)
        return np.arange(self._num_shards, dtype=np.int64)  # no range locality

    def to_state(self) -> Dict:
        return {
            "policy": self.policy,
            "num_shards": self._num_shards,
            "seed": self.seed,
        }


def plan_range_partitions(keys: np.ndarray, num_shards: int) -> RangePartitioner:
    """Size-balanced range planner: boundaries at the ``i/K`` row-count
    quantiles of the build keys, so each shard owns ~``n/K`` rows
    regardless of key-space skew (dense prefix + sparse tail splits
    evenly where equal-width ranges would not)."""
    if num_shards < 1:
        raise ValueError("need at least one shard")
    keys = np.unique(np.asarray(keys, dtype=np.int64))  # sorted + dedup
    if num_shards == 1 or keys.size == 0:
        return RangePartitioner(np.zeros(0, dtype=np.int64)[: num_shards - 1])
    cuts = (np.arange(1, num_shards) * keys.size) // num_shards
    cuts = np.minimum(cuts, keys.size - 1)
    boundaries = np.unique(keys[cuts])  # degenerate quantiles collapse
    # A boundary at the minimum key would leave shard 0 (keys < b[0])
    # empty; drop it so the shard count collapses instead.
    boundaries = boundaries[boundaries > keys[0]]
    return RangePartitioner(boundaries)


def make_partitioner(
    policy: str, keys: np.ndarray, num_shards: int, seed: int = 0
) -> Partitioner:
    """Build-time factory used by ``ShardedDeepMappingStore.build``."""
    if policy == "range":
        return plan_range_partitions(keys, num_shards)
    if policy == "hash":
        return HashPartitioner(num_shards, seed=seed)
    raise ValueError(f"unknown partition policy {policy!r}; have 'range', 'hash'")
