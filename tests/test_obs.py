"""Observability layer: registry/tracer units, exporter formats, the
PlanCache thread-safety regression, and the cross-layer invariants
suite (operator sums, monotone snapshots, trace round-trips, and the
dispatch/collect pipeline-overlap smoke test on a real hybrid store)."""

import json
import threading

import numpy as np
import pytest

from conftest import make_periodic_table
from repro import obs
from repro.api.cache import PlanCache
from repro.core import DeepMappingConfig, DeepMappingStore
from repro.core.trainer import TrainConfig


@pytest.fixture()
def fresh_obs():
    """Isolated registry + tracer installed as the process defaults
    (restored on teardown), so tests see only their own telemetry."""
    reg, trc = obs.MetricsRegistry(), obs.Tracer()
    prev_reg, prev_trc = obs.set_registry(reg), obs.set_tracer(trc)
    yield reg, trc
    obs.set_registry(prev_reg)
    obs.set_tracer(prev_trc)


@pytest.fixture(scope="module")
def obs_store():
    """Small trained store for the wiring/invariants tests."""
    table = make_periodic_table(n=2000)
    store = DeepMappingStore.build(
        table,
        DeepMappingConfig(shared=(64,), private=(16,),
                          train=TrainConfig(epochs=15, batch_size=512)),
    )
    return table, store


class TestMetricsRegistry:
    def test_counter_labels_and_values(self, fresh_obs):
        reg, _ = fresh_obs
        c = reg.counter("x_total", "help text")
        c.inc(kind="a")
        c.inc(3, kind="b")
        c.inc(kind="a")
        assert c.value(kind="a") == 2
        assert c.value(kind="b") == 3
        assert c.value(kind="never") == 0

    def test_counter_rejects_negative(self, fresh_obs):
        reg, _ = fresh_obs
        with pytest.raises(ValueError):
            reg.counter("x_total").inc(-1)

    def test_gauge_set_inc_dec(self, fresh_obs):
        reg, _ = fresh_obs
        g = reg.gauge("depth")
        g.set(5)
        g.inc(2)
        g.dec(3)
        assert g.value() == 4

    def test_histogram_quantiles_bracket_observations(self, fresh_obs):
        reg, _ = fresh_obs
        h = reg.histogram("lat_seconds")
        for v in (0.001, 0.002, 0.004, 0.008, 0.1):
            h.observe(v)
        p50, p99 = h.quantile(0.5), h.quantile(0.99)
        # log-bucket interpolation: within a factor of 2 of the truth
        assert 0.001 < p50 < 0.008
        assert 0.05 < p99 <= 0.2
        assert p50 <= p99

    def test_get_or_create_returns_same_family(self, fresh_obs):
        reg, _ = fresh_obs
        assert reg.counter("a_total") is reg.counter("a_total")

    def test_kind_mismatch_raises(self, fresh_obs):
        reg, _ = fresh_obs
        reg.counter("name")
        with pytest.raises(TypeError):
            reg.gauge("name")

    def test_enabled_flag_is_a_no_op_switch(self, fresh_obs):
        reg, _ = fresh_obs
        c = reg.counter("x_total")
        c.inc()
        reg.enabled = False
        c.inc()
        reg.histogram("h").observe(1.0)
        reg.enabled = True
        assert c.value() == 1
        assert reg.histogram("h").value() == 0

    def test_registry_injection(self, fresh_obs):
        reg, _ = fresh_obs
        assert obs.registry() is reg
        obs.counter("via_module_total").inc()
        assert reg.counter("via_module_total").value() == 1

    def test_concurrent_increments_lose_nothing(self, fresh_obs):
        reg, _ = fresh_obs
        c = reg.counter("hammer_total")
        h = reg.histogram("hammer_seconds")
        n_threads, per_thread = 8, 500

        def work():
            for _ in range(per_thread):
                c.inc(shard=1)
                h.observe(0.001)

        threads = [threading.Thread(target=work) for _ in range(n_threads)]
        [t.start() for t in threads]
        [t.join() for t in threads]
        assert c.value(shard=1) == n_threads * per_thread
        assert h.state().count == n_threads * per_thread


class TestTracer:
    def test_span_context_manager_records(self, fresh_obs):
        _, trc = fresh_obs
        with trc.span("work", track="host", morsel=3):
            pass
        (s,) = trc.spans("work")
        assert s.track == "host" and s.args["morsel"] == 3
        assert s.end >= s.start

    def test_add_span_clamps_negative_duration(self, fresh_obs):
        _, trc = fresh_obs
        trc.add_span("x", 2.0, 1.0)
        (s,) = trc.spans("x")
        assert s.duration == 0.0

    def test_ring_buffer_bounds_memory(self):
        trc = obs.Tracer(capacity=16)
        for i in range(100):
            trc.add_span(f"s{i}", 0.0, 1.0)
        assert len(trc) == 16
        assert trc.spans()[0].name == "s84"  # oldest survivors

    def test_disabled_tracer_records_nothing(self, fresh_obs):
        _, trc = fresh_obs
        trc.enabled = False
        with trc.span("nope"):
            pass
        trc.add_span("nope", 0.0, 1.0)
        assert len(trc) == 0

    def test_span_recorded_even_when_body_raises(self, fresh_obs):
        _, trc = fresh_obs
        with pytest.raises(RuntimeError):
            with trc.span("boom"):
                raise RuntimeError()
        assert len(trc.spans("boom")) == 1


class TestExporters:
    def test_prometheus_text_format(self, fresh_obs):
        reg, _ = fresh_obs
        reg.counter("c_total", "counts things").inc(2, kind="a")
        reg.histogram("h_seconds").observe(0.003)
        text = obs.to_prometheus(reg)
        assert "# HELP c_total counts things" in text
        assert "# TYPE c_total counter" in text
        assert 'c_total{kind="a"} 2' in text
        assert "# TYPE h_seconds histogram" in text
        assert 'h_seconds_bucket{le="+Inf"} 1' in text
        assert "h_seconds_count 1" in text

    def test_json_snapshot_round_trips(self, fresh_obs):
        reg, _ = fresh_obs
        reg.counter("c_total").inc(kind="a")
        reg.histogram("h_seconds").observe(0.01, stage="infer")
        snap = json.loads(obs.to_json_snapshot(reg))
        assert snap["c_total"]["values"] == [
            {"labels": {"kind": "a"}, "value": 1.0}
        ]
        hist = snap["h_seconds"]["values"][0]
        assert hist["count"] == 1 and hist["p50"] > 0

    def test_chrome_trace_round_trips_and_names_tracks(self, fresh_obs):
        _, trc = fresh_obs
        trc.add_span("infer_dispatch", 1.0, 2.0, track="device", morsel=0)
        trc.add_span("collect", 1.5, 1.8, track="host", morsel=0)
        doc = json.loads(json.dumps(obs.to_chrome_trace(trc)))
        events = doc["traceEvents"]
        names = {e["args"]["name"] for e in events if e["ph"] == "M"}
        assert {"device", "host"} <= names
        xs = [e for e in events if e["ph"] == "X"]
        assert len(xs) == 2
        # device pinned to tid 0; timestamps rebased to 0 in µs
        dev = next(e for e in xs if e["cat"] == "device")
        assert dev["tid"] == 0 and dev["ts"] == 0.0 and dev["dur"] == 1e6

    def test_write_helpers_produce_loadable_files(self, fresh_obs, tmp_path):
        reg, trc = fresh_obs
        reg.counter("c_total").inc()
        trc.add_span("s", 0.0, 1.0)
        prom = obs.write_prometheus(str(tmp_path / "m.prom"), reg)
        snap = obs.write_json_snapshot(str(tmp_path / "m.json"), reg)
        trace = obs.write_chrome_trace(str(tmp_path / "t.json"), trc)
        assert "c_total 1" in open(prom).read()
        assert json.load(open(snap))["c_total"]["kind"] == "counter"
        assert json.load(open(trace))["traceEvents"]

    def test_write_helpers_create_missing_directories(self, fresh_obs, tmp_path):
        """Regression: ``quickstart --telemetry-dir NEW_DIR`` crashed
        because the sinks assumed the directory already existed."""
        reg, trc = fresh_obs
        reg.counter("c_total").inc()
        out = tmp_path / "not" / "yet" / "there"
        assert obs.write_prometheus(str(out / "m.prom"), reg) == str(out / "m.prom")
        assert obs.write_chrome_trace(str(out / "t.json"), trc)
        assert (out / "m.prom").exists()


class TestPlanCacheThreadSafety:
    def test_hammered_hit_count_is_exact(self):
        """Regression: hits/misses were unlocked ``+=`` while sharded
        collect runs on fan-out pool threads — under contention the
        counts silently under-reported."""
        cache = PlanCache()
        fp = ("scan", None, (), True)
        cache.put(fp, 0, np.arange(64, dtype=np.int64), None)
        n_threads, per_thread = 8, 400

        def work():
            for _ in range(per_thread):
                assert cache.get(fp, 0) is not None

        threads = [threading.Thread(target=work) for _ in range(n_threads)]
        [t.start() for t in threads]
        [t.join() for t in threads]
        assert cache.hits == n_threads * per_thread
        assert cache.misses == 0

    def test_bypass_counted_and_exact_under_threads(self):
        cache = PlanCache()
        n_threads, per_thread = 4, 250

        def work():
            for _ in range(per_thread):
                cache.get(None, 0)

        threads = [threading.Thread(target=work) for _ in range(n_threads)]
        [t.start() for t in threads]
        [t.join() for t in threads]
        assert cache.bypass == n_threads * per_thread

    def test_concurrent_put_get_evict_is_crash_free(self):
        cache = PlanCache(plan_entries=4)
        stop = threading.Event()
        errors = []

        def reader():
            try:
                while not stop.is_set():
                    for i in range(8):
                        cache.get(("range", i, i + 1, None, (), True), 0)
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        def writer():
            try:
                for i in range(400):
                    cache.put(("range", i % 8, i % 8 + 1, None, (), True), 0,
                              np.arange(32, dtype=np.int64), None)
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        readers = [threading.Thread(target=reader) for _ in range(3)]
        writers = [threading.Thread(target=writer) for _ in range(2)]
        [t.start() for t in readers + writers]
        [t.join() for t in writers]
        stop.set()
        [t.join() for t in readers]
        assert not errors

    def test_cache_events_mirrored_to_registry(self, fresh_obs):
        reg, _ = fresh_obs
        cache = PlanCache()
        fp = ("scan", None, (), True)
        cache.get(fp, 0)            # miss
        cache.put(fp, 0, None, None)
        cache.get(fp, 0)            # hit
        cache.get(None, 0)          # bypass
        ev = reg.counter("deepmap_plan_cache_events_total")
        assert ev.value(outcome="miss") == 1
        assert ev.value(outcome="hit") == 1
        assert ev.value(outcome="bypass") == 1


class TestInvariants:
    """Cross-layer invariants the telemetry must preserve."""

    def test_operator_rows_sum_to_plan_total(self, obs_store):
        _, store = obs_store
        res = store.query().scan().execute()
        s = res.explain
        op_sum = sum(o.seconds for o in s.operators)
        assert op_sum > 0
        # Stage timings are measured inside the (serial) host half plus
        # route/gather, so their sum approximates the plan wall time;
        # generous slack for timer granularity and pipeline overlap.
        assert op_sum <= s.total_s * 1.5
        assert op_sum >= s.total_s * 0.2

    def test_registry_snapshots_monotone_across_repeated_plans(
        self, fresh_obs, obs_store
    ):
        _, store = obs_store

        def counter_values(snap):
            out = {}
            for name, fam in snap.items():
                if fam["kind"] != "counter":
                    continue
                for v in fam["values"]:
                    out[(name, tuple(sorted(v["labels"].items())))] = v["value"]
            return out

        store.query().scan().execute()
        first = counter_values(obs.snapshot())
        store.query().scan().execute()
        second = counter_values(obs.snapshot())
        assert first  # the executor actually recorded something
        for key, val in first.items():
            assert second.get(key, 0) >= val
        morsel_key = ("deepmap_executor_morsels_total", (("kind", "scan"),))
        assert second[morsel_key] > first[morsel_key]

    def test_engine_and_morsel_metrics_recorded(self, fresh_obs, obs_store):
        reg, _ = fresh_obs
        table, store = obs_store
        store.query().where_keys(table.keys[:256]).execute()
        assert reg.counter("deepmap_executor_morsels_total").value(kind="point") > 0
        assert reg.counter("deepmap_engine_events_total").value(
            event="dispatches") > 0
        assert reg.counter("deepmap_plan_cache_events_total").items()

    def test_dispatch_spans_overlap_collect_spans(self, fresh_obs, obs_store):
        """The acceptance smoke test: on the hybrid store, the device
        window (dispatch -> collect-start) of morsel i+1 must bracket
        the host collect span of morsel i — the streaming executor
        tops the dispatch window up BEFORE collecting, so the overlap
        is structural, and the trace must show it."""
        _, store = obs_store
        store.query().morsel(256).scan().execute()
        _, trc = fresh_obs
        dispatch = {s.args["morsel"]: s
                    for s in trc.spans("infer_dispatch", track="device")}
        collect = {s.args["morsel"]: s for s in trc.spans("collect", track="host")}
        assert len(dispatch) >= 4  # multiple morsels actually streamed
        overlaps = 0
        for i, c in collect.items():
            d_next = dispatch.get(i + 1)
            if d_next is not None and d_next.start < c.start and d_next.end >= c.end:
                overlaps += 1
        assert overlaps >= len(collect) - 1 - 1  # all but the final morsel

    def test_chrome_trace_of_real_plan_is_perfetto_shaped(
        self, fresh_obs, obs_store
    ):
        _, store = obs_store
        store.query().morsel(256).scan().execute()
        doc = json.loads(json.dumps(obs.to_chrome_trace()))
        xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        cats = {e["cat"] for e in xs}
        assert {"device", "host", "plans"} <= cats
        for e in xs:
            assert e["ts"] >= 0 and e["dur"] >= 0
            assert isinstance(e["pid"], int) and isinstance(e["tid"], int)

    def test_set_enabled_kills_all_recording(self, fresh_obs, obs_store):
        reg, trc = fresh_obs
        table, store = obs_store
        obs.set_enabled(False)
        try:
            store.query().where_keys(table.keys[:64]).execute()
        finally:
            obs.set_enabled(True)
        assert len(trc) == 0
        assert reg.counter("deepmap_executor_morsels_total").value(
            kind="point") == 0
