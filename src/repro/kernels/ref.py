"""Pure-jnp oracles for every kernel in this package.

These define the mathematical contract: kernels must ``allclose`` these
over shape/dtype sweeps (see ``tests/test_kernels.py``).  The oracles
are also the path used by the multi-pod dry-run lowering (kernels are
TPU-target; the virtual-device mesh compiles the oracle graph).
"""

from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.core.encoding import onehot_digits
from repro.core.model import MLPSpec, forward_digits


def ref_fused_mlp_logits(
    params: Dict, digits: jnp.ndarray, spec: MLPSpec
) -> Dict[str, jnp.ndarray]:
    """Oracle for the fused kernel's logits: the plain model forward."""
    return forward_digits(params, digits, spec)


def ref_fused_mlp_codes(params: Dict, digits: jnp.ndarray, spec: MLPSpec) -> jnp.ndarray:
    logits = forward_digits(params, digits, spec)
    return jnp.stack(
        [jnp.argmax(logits[t], axis=-1).astype(jnp.int32) for t in spec.tasks], axis=1
    )


def ref_fused_lookup(params: Dict, keys, encoder, vexist, spec: MLPSpec):
    """Oracle for the fused key->codes+exists kernel: host digit
    featurization + plain model forward + host BitVector test — the
    seed repo's staged reference path.  Returns ``(codes (n, m) int32
    numpy, exists (n,) bool numpy)``; out-of-capacity rows carry code 0
    (the ``_infer_codes`` zero-fill contract)."""
    import numpy as np

    keys = np.asarray(keys, dtype=np.int64)
    codes = np.zeros((keys.shape[0], len(spec.tasks)), dtype=np.int32)
    in_cap = (keys >= 0) & (keys < encoder.capacity)
    idx = np.flatnonzero(in_cap)
    if idx.size:
        digits = jnp.asarray(encoder.digits(keys[idx]))
        codes[idx] = np.asarray(ref_fused_mlp_codes(params, digits, spec))
    return codes, vexist.test(keys)


def ref_bitvector_test(words: jnp.ndarray, keys: jnp.ndarray) -> jnp.ndarray:
    """words (n_words,) uint32 packed LSB-first; keys (n,) int32."""
    w = words[keys >> 5]
    return ((w >> (keys & 31).astype(jnp.uint32)) & jnp.uint32(1)).astype(jnp.int32)


def ref_onehot_first_layer(
    w3: jnp.ndarray, b: jnp.ndarray, digits: jnp.ndarray
) -> jnp.ndarray:
    """Oracle for the in-VMEM one-hot gather-matmul: materialized one-hot
    times the flattened weight."""
    base = w3.shape[1]
    oh = onehot_digits(digits, base)
    return oh @ w3.reshape(-1, w3.shape[-1]) + b
