"""Scatter/gather routing for the sharded DeepMapping cluster.

The router turns one batched request over arbitrary keys into at most
one contiguous sub-batch per shard (scatter) and reassembles per-shard
results back into request order (gather).  Routing is a pure function
of the partitioner — the paper's batch discipline (§IV-B2: sort so
each compressed partition is decompressed at most once per batch)
extends here to: sort so each SHARD is visited at most once per batch.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Tuple

import numpy as np

from repro.api.routing import gather_parts, gather_parts_partial, group_runs
from repro.cluster.partitioner import Partitioner


@dataclasses.dataclass(frozen=True)
class ShardBatch:
    """One shard's slice of a scattered request.

    ``positions`` indexes into the original request array; gather
    writes this batch's results back through it.
    """

    shard_id: int
    positions: np.ndarray  # (m,) int64 indices into the request
    keys: np.ndarray       # (m,) int64 keys routed to this shard


class ShardRouter:
    """Routes key batches (and per-row column payloads) to shards."""

    def __init__(self, partitioner: Partitioner):
        self.partitioner = partitioner

    @property
    def num_shards(self) -> int:
        return self.partitioner.num_shards

    def scatter(self, keys: np.ndarray) -> List[ShardBatch]:
        """Group a key batch by owning shard (one batch per touched
        shard, shard-id ascending; empty shards are skipped)."""
        keys = np.asarray(keys, dtype=np.int64)
        if keys.size == 0:
            return []
        return [
            ShardBatch(shard_id=sid, positions=pos, keys=keys[pos])
            for sid, pos in group_runs(self.partitioner.shard_of(keys))
        ]

    @staticmethod
    def take_columns(
        columns: Dict[str, np.ndarray], positions: np.ndarray
    ) -> Dict[str, np.ndarray]:
        """Project per-row column payloads onto one shard's positions."""
        return {name: col[positions] for name, col in columns.items()}

    @staticmethod
    def gather(
        n: int, parts: Iterable[Tuple[ShardBatch, Dict[str, np.ndarray], np.ndarray]]
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Reassemble per-shard ``(values, exists)`` into request order
        (see :func:`repro.api.routing.gather_parts` for the inverse-
        permutation discipline)."""
        return gather_parts(
            n, ((b.positions, v, e) for b, v, e in parts)
        )

    @staticmethod
    def gather_partial(
        n: int, parts: Iterable[Tuple[ShardBatch, Dict[str, np.ndarray], np.ndarray]]
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray, np.ndarray]:
        """Degraded-mode gather over the *healthy* shards only ->
        ``(values, exists, covered)``; positions owned by a failed shard
        report ``exists=False`` with typed placeholder values and
        ``covered=False`` (see
        :func:`repro.api.routing.gather_parts_partial`)."""
        return gather_parts_partial(
            n, ((b.positions, v, e) for b, v, e in parts)
        )
