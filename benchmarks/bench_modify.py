"""Paper Tables III/IV (insertion), Table V (deletion), Fig. 8
(insertion speed): modification workloads against DM-Z (no retrain),
DM-Z1 (retrain at threshold), AB, ABC-Z, HB, HBC-Z.

``--shift`` inserts data that does NOT follow the original distribution
(Table IV): low-correlation inserts into the high-correlation store and
vice versa."""

from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

from benchmarks import common as C
from repro.baselines import BASELINE_FACTORIES
from repro.core import Table
from repro.data import synthetic_multi_column
from repro.storage import MemoryPool


def _insert_batch(base: Table, n: int, correlation: str, seed: int) -> Table:
    """Unseen keys continuing the key space, values per correlation."""
    t = synthetic_multi_column(n=n, correlation=correlation, seed=seed)
    return Table(keys=t.keys + base.max_key + 1, columns=t.columns)


def run_inserts(shift=False, steps=(0.1, 0.2, 0.3), batch=10_000) -> List[Dict]:
    rows = []
    for corr in ("low", "high"):
        ds = f"synth_multi_{corr}"
        table = C.DATASETS[ds]()
        raw = table.raw_size_bytes()
        ins_corr = ({"low": "high", "high": "low"}[corr]) if shift else corr
        n0 = table.num_rows

        # DM-Z without retrain and DM-Z1 with one retrain at ~20% inserted.
        for variant, retrain_frac in (("DM-Z", None), ("DM-Z1", 0.2)):
            store = C.dm_store(ds, "DM-Z")
            cur = table
            for frac in steps:
                n_ins = int(n0 * frac) - (cur.num_rows - n0)
                ins = _insert_batch(cur, n_ins, ins_corr, seed=int(frac * 100))
                t0 = time.perf_counter()
                store.insert(ins.keys, ins.columns)
                ins_s = time.perf_counter() - t0
                cur = cur.concat(ins)
                if retrain_frac is not None and frac >= retrain_frac and variant == "DM-Z1":
                    store = store.retrain()
                    retrain_frac = None  # only once, like the paper's DM-Z1
                keys = C.query_keys(cur, batch, seed=7)
                sec = C.time_lookup(store, keys)
                rows.append({"dataset": ds, "system": variant, "frac": frac,
                             "storage": store.size_bytes(), "latency_s": sec,
                             "insert_s": ins_s, "shift": shift})
                C.emit(
                    f"insert{'_shift' if shift else ''}/{ds}/{variant}/+{int(frac*100)}%",
                    sec * 1e6,
                    f"storage={store.size_bytes()};insert_us={ins_s*1e6:.0f}",
                )

        # baselines: rebuild at each size (array/hash stores are immutable
        # partitions; the paper rebuilds/extends them on insert).
        for sys_name in ("AB", "ABC-Z", "HB", "HBC-Z"):
            cur = table
            for frac in steps:
                n_ins = int(n0 * frac) - (cur.num_rows - n0)
                ins = _insert_batch(cur, n_ins, ins_corr, seed=int(frac * 100))
                t0 = time.perf_counter()
                cur = cur.concat(ins)
                store = BASELINE_FACTORIES[sys_name](cur, pool=MemoryPool(1 << 30))
                ins_s = time.perf_counter() - t0
                keys = C.query_keys(cur, batch, seed=7)
                sec = C.time_lookup(store, keys)
                rows.append({"dataset": ds, "system": sys_name, "frac": frac,
                             "storage": store.size_bytes(), "latency_s": sec,
                             "insert_s": ins_s, "shift": shift})
                C.emit(
                    f"insert{'_shift' if shift else ''}/{ds}/{sys_name}/+{int(frac*100)}%",
                    sec * 1e6,
                    f"storage={store.size_bytes()};insert_us={ins_s*1e6:.0f}",
                )
    return rows


def run_deletes(steps=(0.1, 0.2, 0.3), batch=10_000) -> List[Dict]:
    rows = []
    for corr in ("low", "high"):
        ds = f"synth_multi_{corr}"
        table = C.DATASETS[ds]()
        rng = np.random.default_rng(0)
        for variant in ("DM-Z", "DM-Z1"):
            store = C.dm_store(ds, "DM-Z")
            deleted = np.zeros(0, dtype=np.int64)
            retrained = False
            for frac in steps:
                remaining = np.setdiff1d(table.keys, deleted)
                n_del = int(table.num_rows * frac) - deleted.shape[0]
                dele = rng.choice(remaining, size=n_del, replace=False)
                store.delete(dele)
                deleted = np.concatenate([deleted, dele])
                if variant == "DM-Z1" and frac >= 0.2 and not retrained:
                    store = store.retrain()
                    retrained = True
                live = np.setdiff1d(table.keys, deleted)
                keys = rng.choice(live, size=min(batch, live.size), replace=True)
                sec = C.time_lookup(store, keys)
                rows.append({"dataset": ds, "system": variant, "frac": frac,
                             "storage": store.size_bytes(), "latency_s": sec})
                C.emit(
                    f"delete/{ds}/{variant}/-{int(frac*100)}%",
                    sec * 1e6,
                    f"storage={store.size_bytes()}",
                )
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--op", default="insert", choices=["insert", "delete"])
    ap.add_argument("--shift", action="store_true")
    args = ap.parse_args()
    if args.op == "insert":
        run_inserts(shift=args.shift)
    else:
        run_deletes()


if __name__ == "__main__":
    main()
