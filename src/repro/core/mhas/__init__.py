"""Multi-task Hybrid Architecture Search (paper §IV-C, Algorithm 2).

ENAS-style parameter sharing: every candidate architecture is a masked
sub-network of one max-width weight bank, so child models never train
from scratch and a single XLA compilation serves the entire search.
The LSTM controller samples (shared depth, shared sizes, per-task
private depth/sizes) autoregressively and is trained with REINFORCE
against the paper's Eq. 1 — the *whole hybrid structure's* compression
ratio, including the auxiliary table the sampled model would need.
"""

from repro.core.mhas.search import MHASConfig, MHASResult, run_mhas  # noqa: F401
from repro.core.mhas.search_space import SearchSpace  # noqa: F401
