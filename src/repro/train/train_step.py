"""Train step factory for every architecture family.

``make_train_step(spec_cfg, optimizer, microbatches)`` returns a pure
``(state, batch) -> (state, metrics)`` suitable for ``jax.jit`` with
in/out shardings.  Losses are next-token cross-entropy (teacher-forced
for enc-dec); MoE models add the load-balance auxiliary loss.  Gradient
accumulation over microbatches runs as a ``lax.scan`` so activation
memory is bounded by one microbatch.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import DecoderLM, EncDecLM
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.train.optimizer import OptState, adamw


class TrainState(NamedTuple):
    params: Dict
    opt: OptState


def init_state(cfg: ModelConfig, optimizer: adamw, seed: int = 0) -> TrainState:
    model = EncDecLM(cfg) if cfg.is_encoder_decoder else DecoderLM(cfg)
    params = model.init(seed)
    return TrainState(params=params, opt=optimizer.init(params))


def _lm_loss(model: DecoderLM, params: Dict, batch: Dict) -> jnp.ndarray:
    tokens = batch["tokens"]
    prefix = batch.get("patch_embeds")
    logits = model.apply(params, tokens, prefix_embeds=prefix)
    labels = tokens[:, 1:]
    lg = logits[:, :-1]
    mask = None
    if prefix is not None:
        # prefix positions carry embeddings, not predictable tokens
        P = prefix.shape[1]
        pos = jnp.arange(labels.shape[1])[None, :]
        mask = (pos >= P).astype(jnp.float32) * jnp.ones_like(labels, jnp.float32)
    return L.cross_entropy_loss(lg, labels, mask)


def _encdec_loss(model: EncDecLM, params: Dict, batch: Dict) -> jnp.ndarray:
    logits = model.apply(params, batch["frames"], batch["tokens"])
    return L.cross_entropy_loss(logits[:, :-1], batch["tokens"][:, 1:])


def make_loss_fn(cfg: ModelConfig) -> Tuple[Callable, object]:
    if cfg.is_encoder_decoder:
        model = EncDecLM(cfg)
        base = functools.partial(_encdec_loss, model)
    else:
        model = DecoderLM(cfg)
        base = functools.partial(_lm_loss, model)

    if cfg.is_moe:
        from repro.models import moe as M

        def loss_fn(params, batch):
            loss = base(params, batch)
            # one representative router (first MoE layer) keeps the aux
            # term O(1); production would sum over layers.
            seg = params["segments"][-1]
            if seg["groups"] is not None:
                router_p = jax.tree.map(lambda a: a[0], seg["groups"][0]["ffn"])
                x = L.embed(params["embed"], batch["tokens"])
                loss = loss + 0.01 * M.aux_load_balance_loss(router_p, cfg, x)
            return loss

        return loss_fn, model
    return base, model


def make_train_step(
    cfg: ModelConfig,
    optimizer: adamw,
    microbatches: int = 1,
) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    loss_fn, _ = make_loss_fn(cfg)

    def single(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        if microbatches <= 1:
            loss, grads = single(state.params, batch)
        else:
            def reshape(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            micro = jax.tree.map(reshape, batch)

            def acc_step(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = single(state.params, mb)
                return (
                    loss_acc + loss / microbatches,
                    jax.tree.map(lambda a, g: a + g / microbatches, grad_acc, grads),
                ), None

            zero = jax.tree.map(jnp.zeros_like, state.params)
            (loss, grads), _ = jax.lax.scan(acc_step, (jnp.zeros(()), zero), micro)
        new_params, new_opt = optimizer.update(grads, state.opt, state.params)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        return TrainState(new_params, new_opt), {"loss": loss, "grad_norm": gnorm}

    return train_step
