"""Markdown link checker for the docs CI job (stdlib only).

Scans the given markdown files for inline links/images
(``[text](target)``) and reference definitions (``[ref]: target``) and
fails when a **relative** target does not exist on disk (anchors are
stripped; bare ``#fragment`` links are ignored).  ``http(s)``/
``mailto`` targets are format-checked only — CI must not flake on
third-party outages.

    python tools/check_docs.py README.md DESIGN.md ROADMAP.md
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

#: Inline ``[text](target)`` — target captured lazily up to the first
#: unescaped ``)``; fenced code is stripped before matching.
INLINE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.MULTILINE)
FENCE = re.compile(r"```.*?```", re.DOTALL)
SCHEMES = ("http://", "https://", "mailto:")


def check_file(path: Path) -> list[str]:
    """Return a list of broken-link error strings for one file."""
    text = FENCE.sub("", path.read_text(encoding="utf-8"))
    errors = []
    targets = INLINE.findall(text) + REFDEF.findall(text)
    for target in targets:
        if target.startswith(SCHEMES):
            continue  # external: format-checked by the regex itself
        local = target.split("#", 1)[0]
        if not local:
            continue  # pure in-page anchor
        resolved = (path.parent / local).resolve()
        if not resolved.exists():
            errors.append(f"{path}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    """Check every argument file; exit non-zero on any broken link."""
    if not argv:
        print("usage: check_docs.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    failures = []
    for name in argv:
        path = Path(name)
        if not path.exists():
            failures.append(f"{name}: file not found")
            continue
        failures.extend(check_file(path))
    for f in failures:
        print(f, file=sys.stderr)
    print(
        f"checked {len(argv)} file(s): "
        + ("FAILED" if failures else "all links resolve")
    )
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
