"""Mixture-of-Experts FFN with token-choice top-k routing, capacity
dropping, and shared experts (DeepSeek-V3: 1 shared + 256 routed top-8;
Llama-4-Scout: 1 shared + 16 routed top-1).

Dispatch is SORT-based (no (tokens, E, C) one-hot blow-up): token copies
are argsorted by expert id, positions within each expert computed from
segment starts, then scattered into an (E, C, d) buffer.  Under pjit the
expert dimension is sharded over the ``model``/``expert`` mesh axis —
GSPMD materializes the token exchange as all-to-alls.  Capacity drops
overflow tokens (they pass through the residual / shared expert only),
which is the standard TPU-efficient formulation.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L


def moe_init(rng, cfg) -> Dict:
    d, E, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    r = jax.random.split(rng, 5)
    dt = jnp.dtype(cfg.dtype)
    scale_in = jnp.sqrt(1.0 / d)
    scale_out = jnp.sqrt(1.0 / f)
    p = {
        "router": L.dense_init(r[0], d, E, jnp.float32),  # fp32 router (std practice)
        "w_gate": (jax.random.normal(r[1], (E, d, f), jnp.float32) * scale_in).astype(dt),
        "w_up": (jax.random.normal(r[2], (E, d, f), jnp.float32) * scale_in).astype(dt),
        "w_down": (jax.random.normal(r[3], (E, f, d), jnp.float32) * scale_out).astype(dt),
    }
    if cfg.num_shared_experts:
        p["shared"] = L.mlp_init(r[4], d, cfg.moe_d_ff * cfg.num_shared_experts, dt)
    return p


def _capacity(tokens: int, cfg) -> int:
    c = int(tokens * cfg.experts_per_token * cfg.capacity_factor / cfg.num_experts)
    return max(8, ((c + 7) // 8) * 8)  # sublane-align


def moe_apply(p: Dict, cfg, x: jnp.ndarray) -> jnp.ndarray:
    """x (B,S,d) -> (B,S,d).  Routed top-k with capacity + shared expert."""
    nb = getattr(cfg, "moe_block_dispatch", 0)
    if nb and (x.shape[0] * x.shape[1]) % nb == 0:
        return _moe_apply_blocked(p, cfg, x, nb)
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    C = _capacity(T, cfg)
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ p["router"]["w"]) * cfg.router_scale
    probs = jax.nn.softmax(logits, axis=-1)                    # (T,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (T,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch ------------------------------------------------
    flat_e = gate_idx.reshape(-1)                              # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)     # token of each copy
    flat_w = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e)                                # stable in XLA
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    seg_start = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype))
    pos = jnp.arange(T * k, dtype=jnp.int32) - seg_start[se].astype(jnp.int32)
    keep = pos < C
    pos_c = jnp.where(keep, pos, C)                            # overflow -> pad slot

    # (E, C+1) scatter: token index per expert slot (T = pad sentinel)
    disp = jnp.full((E, C + 1), T, jnp.int32)
    disp = disp.at[se, pos_c].set(jnp.where(keep, st, T), mode="drop")
    disp = disp[:, :C]
    wts = jnp.zeros((E, C + 1), jnp.float32)
    wts = wts.at[se, pos_c].set(jnp.where(keep, sw, 0.0), mode="drop")
    wts = wts[:, :C]

    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xe = jnp.take(xpad, disp, axis=0)                          # (E,C,d)

    def _constrain(t, spec):
        if getattr(cfg, "moe_shard_constraints", False):
            try:
                return jax.lax.with_sharding_constraint(
                    t, jax.sharding.PartitionSpec(*spec)
                )
            except (RuntimeError, ValueError):
                return t  # no ambient mesh (single-device tests)
        return t

    # keep dispatch buffers expert-sharded on the tensor axis and
    # capacity-sharded on the data axis (§Perf: prevents GSPMD from
    # replicating the whole token set through the sort/scatter pipeline)
    xe = _constrain(xe, ("model", "data", None))

    # ---- expert computation (gated SiLU) -------------------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["w_up"]
    )
    h = _constrain(h, ("model", "data", None))
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])            # (E,C,d)
    ye = _constrain(ye, ("model", "data", None))

    # ---- combine ---------------------------------------------------------------
    yflat = jnp.zeros((T + 1, d), jnp.float32)
    yflat = yflat.at[disp.reshape(-1)].add(
        (ye * wts[..., None]).reshape(E * C, d).astype(jnp.float32), mode="drop"
    )
    y = yflat[:T].astype(x.dtype)

    if "shared" in p:
        y = y + L.mlp(p["shared"], xf)
    return y.reshape(B, S, d)


def _moe_apply_blocked(p: Dict, cfg, x: jnp.ndarray, nb: int) -> jnp.ndarray:
    """Block-local dispatch (§Perf): tokens routed within ``nb`` blocks
    whose leading dim is pinned to the data axis, so every gather/scatter
    in the dispatch pipeline is shard-local.  Capacity is enforced
    per-block (same expected drop rate; different tie-breaking than the
    global path)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    Tb = T // nb
    C = _capacity(Tb, cfg)
    xb = x.reshape(nb, Tb, d)

    def constrain(t, spec):
        try:
            return jax.lax.with_sharding_constraint(t, jax.sharding.PartitionSpec(*spec))
        except (RuntimeError, ValueError):
            return t

    xb = constrain(xb, ("data", None, None))

    logits = (xb.astype(jnp.float32) @ p["router"]["w"]) * cfg.router_scale
    probs = jax.nn.softmax(logits, axis=-1)                      # (nb,Tb,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    def block_dispatch(gi, gv):
        """Per-block sort-based dispatch (vmapped over blocks)."""
        flat_e = gi.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(Tb, dtype=jnp.int32), k)
        flat_w = gv.reshape(-1)
        order = jnp.argsort(flat_e)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        seg_start = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype))
        pos = jnp.arange(Tb * k, dtype=jnp.int32) - seg_start[se].astype(jnp.int32)
        keep = pos < C
        pos_c = jnp.where(keep, pos, C)
        disp = jnp.full((E, C + 1), Tb, jnp.int32)
        disp = disp.at[se, pos_c].set(jnp.where(keep, st, Tb), mode="drop")[:, :C]
        wts = jnp.zeros((E, C + 1), jnp.float32)
        wts = wts.at[se, pos_c].set(jnp.where(keep, sw, 0.0), mode="drop")[:, :C]
        return disp, wts

    disp, wts = jax.vmap(block_dispatch)(gate_idx, gate_vals)    # (nb,E,C)

    xpad = jnp.concatenate([xb, jnp.zeros((nb, 1, d), xb.dtype)], axis=1)
    xe = jax.vmap(lambda xp, dp: jnp.take(xp, dp, axis=0))(xpad, disp)  # (nb,E,C,d)
    xe = constrain(xe, ("data", "model", None, None))

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["w_gate"])) * jnp.einsum(
        "becd,edf->becf", xe, p["w_up"]
    )
    h = constrain(h, ("data", "model", None, None))
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"])
    ye = constrain(ye, ("data", "model", None, None))

    def block_combine(d_idx, w, y):
        out = jnp.zeros((Tb + 1, d), jnp.float32)
        out = out.at[d_idx.reshape(-1)].add(
            (y * w[..., None]).reshape(E * C, d).astype(jnp.float32), mode="drop"
        )
        return out[:Tb]

    y = jax.vmap(block_combine)(disp, wts, ye)                   # (nb,Tb,d)
    y = constrain(y, ("data", None, None)).astype(x.dtype)

    if "shared" in p:
        y = y + L.mlp(p["shared"], xb).reshape(nb, Tb, d)
    return y.reshape(B, S, d)


def aux_load_balance_loss(p: Dict, cfg, x: jnp.ndarray) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss (fraction·probability)."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.num_experts), axis=0)
    imp = jnp.mean(probs, axis=0)
    return cfg.num_experts * jnp.sum(frac * imp)
