"""Paper §IV-E range queries: existence-index filter + batch inference."""

import numpy as np
import pytest

from conftest import make_periodic_table
from repro.core import DeepMappingConfig, DeepMappingStore
from repro.core.trainer import TrainConfig


@pytest.fixture(scope="module")
def store_table():
    table = make_periodic_table(n=1200, stride=3)  # keys 0,3,6,...
    store = DeepMappingStore.build(
        table,
        DeepMappingConfig(shared=(64,), private=(16,),
                          train=TrainConfig(epochs=15, batch_size=512)),
    )
    return table, store


class TestRangeLookup:
    def test_exact_range_contents(self, store_table):
        table, store = store_table
        keys, values = store.range_lookup(30, 91)
        want = table.keys[(table.keys >= 30) & (table.keys < 91)]
        np.testing.assert_array_equal(keys, want)
        lut = dict(zip(table.keys.tolist(), table.columns["col0"].tolist()))
        np.testing.assert_array_equal(
            values["col0"], [lut[int(k)] for k in keys]
        )

    def test_empty_range(self, store_table):
        _, store = store_table
        keys, values = store.range_lookup(31, 32)  # stride-3 keys: none here
        assert keys.size == 0

    def test_range_beyond_domain_clamped(self, store_table):
        table, store = store_table
        keys, _ = store.range_lookup(0, 10**9)
        assert keys.size == table.num_rows

    def test_range_respects_deletes(self, store_table):
        table, store = store_table
        store.delete(np.array([60], dtype=np.int64))
        keys, _ = store.range_lookup(55, 70)
        assert 60 not in keys.tolist()

    def test_column_projection(self, store_table):
        _, store = store_table
        _, values = store.range_lookup(0, 50, columns=("col1",))
        assert set(values) == {"col1"}
