"""Top-level entrypoints: ``repro.open(path)`` / ``repro.build(table, ...)``.

``open`` sniffs the on-disk format and returns the right
:class:`~repro.api.protocol.MappingStore` implementation:

* directory with ``manifest.msgpack``  -> sharded cluster
  (:func:`~repro.cluster.sharded_store.load_sharded_store`);
* directory with ``meta.msgpack``      -> single DeepMapping store
  (:func:`~repro.core.serialize.load_store`);
* msgpack file with a ``kind`` header  -> AB/HB baseline store.

``build`` trains/assembles a store from a :class:`~repro.core.table.Table`:
a single :class:`DeepMappingStore` by default, or a sharded cluster when a
:class:`~repro.cluster.sharded_store.ClusterConfig` with ``num_shards > 1``
is given.  All imports are lazy so ``import repro`` stays side-effect
free w.r.t. JAX device state.
"""

from __future__ import annotations

import os


#: The on-disk formats ``open`` recognizes, newest-listed-first in the
#: sniffing order (also the error-message inventory).
SUPPORTED_FORMATS = (
    "sharded cluster: directory containing manifest.msgpack "
    "(ShardedDeepMappingStore.save)",
    "single DeepMapping store: directory containing meta.msgpack "
    "(DeepMappingStore.save)",
    "baseline overlay store: single msgpack file with an "
    "array_store/hash_store 'kind' header (ArrayStore/HashStore.save)",
)


def open(path: str, pool=None, on_corrupt: str = "raise"):  # noqa: A001 — deliberate builtin shadow inside repro.*
    """Load any saved store, sniffing the on-disk format.

    Format sniffing, in order: a **directory** holding
    ``manifest.msgpack`` is a sharded cluster; a directory holding
    ``meta.msgpack`` is a single DeepMapping store; a **file** is
    parsed as a baseline msgpack blob and dispatched on its ``kind``
    header (``array_store``/``hash_store``).  Anything else raises a
    ``ValueError`` (or ``FileNotFoundError`` when ``path`` does not
    exist) that lists the supported formats.  ``pool`` is the shared
    :class:`~repro.storage.MemoryPool` to charge decompressed
    partitions to (one is created per store when omitted).

    Every format verifies per-artifact crc32 checksums recorded at save
    time — a corrupt or truncated artifact raises
    :class:`~repro.fault.errors.IntegrityError` rather than decoding
    into wrong values.  ``on_corrupt`` applies to sharded clusters:
    ``'quarantine'`` degrades a cluster with corrupt shard directories
    to its healthy shards (see
    :func:`~repro.cluster.sharded_store.load_sharded_store`) instead of
    refusing outright.  A ``<path>.tmp`` with no ``<path>`` means a
    save died before its atomic rename — that raises a ``ValueError``
    naming the interruption, because there is nothing verified to load.
    """
    supported = "; ".join(SUPPORTED_FORMATS)
    if not os.path.exists(path) and os.path.exists(path + ".tmp"):
        raise ValueError(
            f"interrupted save detected: {path + '.tmp'!r} exists but "
            f"{path!r} does not — the save never completed its atomic "
            f"rename, and the tmp contents are unverifiable; rebuild the "
            f"store or restore from a backup/replica"
        )
    if os.path.isdir(path):
        if os.path.exists(os.path.join(path, "manifest.msgpack")):
            from repro.cluster.sharded_store import ShardedDeepMappingStore

            return ShardedDeepMappingStore.load(
                path, pool=pool, on_corrupt=on_corrupt
            )
        if os.path.exists(os.path.join(path, "meta.msgpack")):
            from repro.core.hybrid import DeepMappingStore

            return DeepMappingStore.load(path, pool=pool)
        raise ValueError(
            f"{path!r} is a directory but holds neither a cluster "
            f"manifest nor a store meta file; supported formats: "
            f"{supported}"
        )
    if os.path.isfile(path):
        from repro.baselines.partitioned import load_baseline_store
        from repro.fault.errors import IntegrityError

        try:
            return load_baseline_store(path, pool=pool)
        except IntegrityError:
            raise  # corruption, not an unrecognized format — say so
        except ValueError as err:
            raise ValueError(
                f"{err}; supported formats: {supported}"
            ) from err
    raise FileNotFoundError(
        f"{path!r} does not exist; repro.open loads any of: {supported}"
    )


def build(
    table,
    config=None,
    cluster=None,
    pool=None,
    verbose: bool = False,
    spec=None,
    params=None,
):
    """Build a store from a table.

    ``config`` is a :class:`~repro.core.hybrid.DeepMappingConfig`
    (default-constructed when omitted); pass ``cluster`` (a
    :class:`~repro.cluster.sharded_store.ClusterConfig`) with
    ``num_shards > 1`` to build a sharded cluster instead of a single
    store.  ``spec``/``params`` skip training (single store only,
    e.g. from MHAS).
    """
    from repro.core.hybrid import DeepMappingConfig, DeepMappingStore

    config = config if config is not None else DeepMappingConfig()
    if cluster is not None and cluster.num_shards > 1:
        from repro.cluster.sharded_store import ShardedDeepMappingStore

        if spec is not None or params is not None:
            raise ValueError("spec/params pre-seeding is single-store only")
        return ShardedDeepMappingStore.build(
            table, config, cluster, pool=pool, verbose=verbose
        )
    return DeepMappingStore.build(
        table, config, pool=pool, spec=spec, params=params, verbose=verbose
    )
