"""Markdown link checker for the docs CI job (stdlib only).

Scans the given markdown files for inline links/images
(``[text](target)``) and reference definitions (``[ref]: target``) and
fails when a **relative** target does not exist on disk (anchors are
stripped; bare ``#fragment`` links are ignored).  ``http(s)``/
``mailto`` targets are format-checked only — CI must not flake on
third-party outages.

For ``DESIGN.md`` the rule catalog in §Invariants & static analysis is
additionally cross-checked against the deeplint registry
(``tools.deeplint.rules.RULE_IDS``): every ``- **`rule-id`**`` bullet
must name a registered rule and every registered rule must appear, so
the documented catalog cannot drift from the code.

    python tools/check_docs.py README.md DESIGN.md ROADMAP.md
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

#: Inline ``[text](target)`` — target captured lazily up to the first
#: unescaped ``)``; fenced code is stripped before matching.
INLINE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.MULTILINE)
FENCE = re.compile(r"```.*?```", re.DOTALL)
SCHEMES = ("http://", "https://", "mailto:")


def check_file(path: Path) -> list[str]:
    """Return a list of broken-link error strings for one file."""
    text = FENCE.sub("", path.read_text(encoding="utf-8"))
    errors = []
    targets = INLINE.findall(text) + REFDEF.findall(text)
    for target in targets:
        if target.startswith(SCHEMES):
            continue  # external: format-checked by the regex itself
        local = target.split("#", 1)[0]
        if not local:
            continue  # pure in-page anchor
        resolved = (path.parent / local).resolve()
        if not resolved.exists():
            errors.append(f"{path}: broken link -> {target}")
    return errors


#: ``- **`rule-id`**`` bullets inside the DESIGN.md rule catalog.
CATALOG_BULLET = re.compile(r"^\s*-\s+\*\*`([a-z][a-z0-9-]*)`\*\*", re.MULTILINE)
CATALOG_HEADING = "### Rule catalog"


def check_rule_catalog(path: Path) -> list[str]:
    """Cross-check DESIGN.md's rule catalog against the deeplint registry."""
    try:
        from tools.deeplint.rules import RULE_IDS
    except Exception as exc:  # registry must stay importable
        return [f"{path}: cannot import deeplint registry: {exc}"]
    text = path.read_text(encoding="utf-8")
    start = text.find(CATALOG_HEADING)
    if start < 0:
        return [f"{path}: missing '{CATALOG_HEADING}' section"]
    # The catalog runs to the next heading.
    end = text.find("\n#", start + len(CATALOG_HEADING))
    section = text[start:end] if end > 0 else text[start:]
    documented = set(CATALOG_BULLET.findall(section))
    errors = []
    for rid in sorted(documented - set(RULE_IDS)):
        errors.append(f"{path}: documented rule {rid!r} is not in the registry")
    for rid in sorted(set(RULE_IDS) - documented):
        errors.append(f"{path}: registered rule {rid!r} missing from the catalog")
    return errors


def main(argv: list[str]) -> int:
    """Check every argument file; exit non-zero on any broken link."""
    if not argv:
        print("usage: check_docs.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    failures = []
    for name in argv:
        path = Path(name)
        if not path.exists():
            failures.append(f"{name}: file not found")
            continue
        failures.extend(check_file(path))
        if path.name == "DESIGN.md":
            failures.extend(check_rule_catalog(path))
    for f in failures:
        print(f, file=sys.stderr)
    print(
        f"checked {len(argv)} file(s): "
        + ("FAILED" if failures else "all links resolve")
    )
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
