"""Deterministic, stateless batch loader.

``batch_for_step(step)`` is a pure function of (seed, step, topology):
restart-safe (replays exactly), elastic-safe (a host owns
``process_index``-strided rows of the global batch), and usable as the
``batch_fn`` of the fault-tolerant runner."""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.data.tokens import DeepMappingTokenStore


@dataclasses.dataclass
class LoaderConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    process_index: int = 0
    process_count: int = 1


class TokenBatchLoader:
    """Batches from a raw array or a DeepMapping-compressed store."""

    def __init__(
        self,
        cfg: LoaderConfig,
        tokens: Optional[np.ndarray] = None,
        store: Optional[DeepMappingTokenStore] = None,
    ):
        if (tokens is None) == (store is None):
            raise ValueError("exactly one of tokens/store")
        self.cfg = cfg
        self._tokens = tokens
        self._store = store
        n = store.num_tokens if store is not None else tokens.shape[0]
        self._max_start = n - cfg.seq_len - 1
        if self._max_start <= 0:
            raise ValueError("corpus shorter than seq_len")

    def _starts(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.cfg.seed, step))
        starts = rng.integers(0, self._max_start, size=self.cfg.global_batch)
        # host shard: strided rows of the global batch
        return starts[self.cfg.process_index :: self.cfg.process_count]

    def batch_for_step(self, step: int) -> Dict[str, np.ndarray]:
        starts = self._starts(step)
        if self._store is not None:
            toks = self._store.get_batch(starts, self.cfg.seq_len + 1)
        else:
            pos = starts[:, None] + np.arange(self.cfg.seq_len + 1)[None, :]
            toks = self._tokens[pos]
        return {"tokens": toks.astype(np.int32)}
