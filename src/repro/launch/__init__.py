"""Launch layer: mesh factory, multi-pod dry-run driver, train/serve
entrypoints.  NOTE: ``dryrun`` must be executed as a module entry
(``python -m repro.launch.dryrun``) so its XLA_FLAGS device-count pin
happens before any jax import."""
