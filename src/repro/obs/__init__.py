"""Unified observability: metrics registry, span tracing, exporters.

Dependency-free (stdlib only) and imported BY the engine/executor/
serving layers — never the reverse — so it sits at the bottom of the
dependency graph next to :mod:`repro.api.plan`.

Quick tour::

    from repro import obs

    obs.counter("deepmap_executor_morsels_total").inc(kind="scan")
    with obs.span("collect", track="host", morsel=0):
        ...                                  # timed work
    print(obs.to_prometheus())               # /metrics scrape body
    obs.write_chrome_trace("trace.json")     # open in Perfetto

``obs.set_enabled(False)`` flips both the registry and tracer to
no-ops in one call — used by the benchmarks to measure the always-on
overhead (<3% QPS budget, recorded in BENCH_lookup.json).
"""

from repro.obs.export import (
    to_chrome_trace,
    to_json_snapshot,
    to_prometheus,
    write_chrome_trace,
    write_json_snapshot,
    write_prometheus,
)
from repro.obs.metrics import (
    LATENCY_BUCKETS,
    SIZE_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    registry,
    set_registry,
)
from repro.obs.tracing import Span, Tracer, set_tracer, tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "LATENCY_BUCKETS",
    "MetricsRegistry",
    "SIZE_BUCKETS",
    "Span",
    "Tracer",
    "counter",
    "gauge",
    "histogram",
    "registry",
    "set_enabled",
    "set_registry",
    "set_tracer",
    "snapshot",
    "span",
    "to_chrome_trace",
    "to_json_snapshot",
    "to_prometheus",
    "tracer",
    "write_chrome_trace",
    "write_json_snapshot",
    "write_prometheus",
]


def counter(name: str, help: str = "") -> Counter:
    """``registry().counter(...)`` on the current default registry."""
    return registry().counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:
    """``registry().gauge(...)`` on the current default registry."""
    return registry().gauge(name, help)


def histogram(name: str, help: str = "", buckets=None) -> Histogram:
    """``registry().histogram(...)`` on the current default registry."""
    return registry().histogram(name, help, buckets=buckets)


def span(name: str, track: str = "host", **args):
    """``tracer().span(...)`` on the current default tracer."""
    return tracer().span(name, track=track, **args)


def snapshot() -> dict:
    """JSON-able dump of the current default registry."""
    return registry().snapshot()


def set_enabled(enabled: bool) -> None:
    """Flip BOTH the default registry and default tracer on/off —
    the one-flag kill-switch for overhead measurement."""
    registry().enabled = enabled
    tracer().enabled = enabled
