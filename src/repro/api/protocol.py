"""The formal ``MappingStore`` protocol — one lookup contract over
interchangeable store structures (learned-index tradition: RMI exposes
one ``lookup`` over trees of models; NeurStore one model-store API).

Every store in this repo — :class:`~repro.core.hybrid.DeepMappingStore`,
:class:`~repro.cluster.sharded_store.ShardedDeepMappingStore`,
:class:`~repro.baselines.array_store.ArrayStore`,
:class:`~repro.baselines.hash_store.HashStore` — subclasses
:class:`MappingStore` and is exercised by the shared conformance suite
(``tests/test_store_protocol.py``).

Conformance contract (what the suite checks):

1. ``lookup(keys, columns) -> (values, exists)``: values aligned with
   the request, NULL rows carry placeholder values and must be masked
   by ``exists``; zero-length key batches return typed empty columns
   and never reach inference/stack paths.
2. ``insert`` raises on existing keys and mutates nothing on reject;
   ``update`` raises on missing keys likewise; ``delete`` is
   idempotent.  All accept zero-length batches as no-ops.
3. ``range_lookup(lo, hi)`` / ``scan()`` return ``(keys, values)`` with
   keys ascending and every key existing.
4. ``size_breakdown()`` maps component name -> bytes and sums to
   ``size_bytes()``.
5. ``save(path)`` then ``type(store).load(path)`` (or ``repro.open``)
   round-trips: identical query results.
6. ``query()`` plans execute byte-identically to the direct methods,
   including after interleaved insert/delete/update, and projection
   pushdown (``select``) never changes selected-column bytes.
7. Value-predicate pushdown (``where``) returns byte-identical rows to
   the post-hoc reference filter (``pushdown(False)``), including
   rows answered by the aux table / modification overlay
   (``tests/test_streaming_executor.py``).
"""

from __future__ import annotations

import abc
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.api.cache import PlanCache
from repro.api.plan import (
    ExplainStats,
    aggregate_rows,
    columns_with_predicates,
    evaluate_predicates,
)

#: Methods every conforming store must expose (used by the suite's
#: surface check; behavioural checks live in the parametrized tests).
CONFORMANCE_METHODS = (
    "lookup",
    "insert",
    "delete",
    "update",
    "range_lookup",
    "scan",
    "size_breakdown",
    "size_bytes",
    "save",
    "load",
    "query",
)


def _check_index_agreement(kind: str, exists: np.ndarray) -> None:
    """Keys sourced from the existence index must all exist; a miss
    means the index and the lookup path disagree.  A real error — not
    an ``assert``, which vanishes under ``python -O`` (the executor
    raises the same way)."""
    if not bool(exists.all()):
        raise RuntimeError(
            f"{kind} produced keys missing from the store: existence "
            f"index and lookup path disagree"
        )


class MappingStore(abc.ABC):
    """Abstract base of every key->row store (learned or baseline)."""

    # Lazily-created instance state (see mutation_version / plan_cache);
    # declared here so the typed surface knows their types.
    _mutation_version: int
    _plan_cache: PlanCache

    # ------------------------------------------------------------- required
    @property
    @abc.abstractmethod
    def columns(self) -> Tuple[str, ...]:
        """Value column names, in the store's canonical order."""

    @abc.abstractmethod
    def lookup(
        self, keys: np.ndarray, columns: Optional[Tuple[str, ...]] = None
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Batched exact-match lookup -> ``(values, exists)``."""

    @abc.abstractmethod
    def insert(self, keys: np.ndarray, columns: Dict[str, np.ndarray]) -> None:
        """Insert new rows; raises ``ValueError`` if any key exists."""

    @abc.abstractmethod
    def delete(self, keys: np.ndarray) -> None:
        """Delete rows (idempotent: missing keys are ignored)."""

    @abc.abstractmethod
    def update(self, keys: np.ndarray, columns: Dict[str, np.ndarray]) -> None:
        """Overwrite existing rows; raises ``ValueError`` on missing keys."""

    @abc.abstractmethod
    def size_breakdown(self) -> Dict[str, int]:
        """Bytes per storage component (the paper's Fig. 6 accounting)."""

    @abc.abstractmethod
    def save(self, path: str) -> None:
        """Persist to ``path`` (atomic).  ``type(store).load`` restores."""

    @classmethod
    @abc.abstractmethod
    def load(cls, path: str, pool=None) -> "MappingStore":
        """Restore a store saved by :meth:`save`."""

    @abc.abstractmethod
    def _range_keys(self, lo: int, hi: Optional[int]) -> np.ndarray:
        """Existing keys in ``[lo, hi)`` ascending (``hi=None`` =
        unbounded) — the key source for range/scan plans."""

    # ------------------------------------------------------ shared surface
    def _all_keys(self) -> np.ndarray:
        return self._range_keys(0, None)

    def range_lookup(
        self, lo: int, hi: int, columns: Optional[Tuple[str, ...]] = None
    ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        """Paper §IV-E first approach: range-filter the existence index,
        then answer the collected keys by batched lookup."""
        keys = self._range_keys(int(lo), int(hi))
        values, exists = self.lookup(keys, columns)
        _check_index_agreement("range", exists)
        return keys, values

    def scan(
        self, columns: Optional[Tuple[str, ...]] = None
    ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        """Full relation scan -> ``(keys, values)``, keys ascending."""
        keys = self._all_keys()
        values, exists = self.lookup(keys, columns)
        _check_index_agreement("scan", exists)
        return keys, values

    def size_bytes(self) -> int:
        """Total storage footprint (sum of :meth:`size_breakdown`)."""
        return sum(self.size_breakdown().values())

    def query(self):
        """Start a plan-based query: ``store.query().select(...)
        .where_keys(ks) | .where_range(lo, hi) | .scan() .execute()``."""
        from repro.api.query import Query  # local: avoids import cycle

        return Query(self)

    # ------------------------------------------------ plan-cache integration
    def mutation_version(self) -> object:
        """Opaque token that changes on every logical mutation.

        The plan cache stamps each artifact with this token and drops
        it on mismatch, so ``insert``/``delete``/``update`` (including
        a decode-map-growing insert) can never serve stale compiled
        plans.  Stores call :meth:`_note_mutation` from their mutators;
        composite stores (sharded, federated) combine member tokens.
        Comparison is by equality only — the value has no ordering.
        """
        return getattr(self, "_mutation_version", 0)

    def _note_mutation(self) -> None:
        """Advance :meth:`mutation_version` (call from every mutator)."""
        self._mutation_version = getattr(self, "_mutation_version", 0) + 1

    def plan_cache(self) -> PlanCache:
        """This store's lazily-created :class:`~repro.api.cache.PlanCache`.

        The streaming executor consults it for repeated-plan artifacts
        (key-source materializations, projection subsets); DeepMapping
        stores additionally memoize predicate code tables through it.
        ``store.plan_cache().clear()`` forces the cold path.
        """
        cache = getattr(self, "_plan_cache", None)
        if cache is None:
            cache = self._plan_cache = PlanCache()
        return cache

    # ------------------------------------------- async lookup pipeline hooks
    def _dispatch_lookup(
        self, keys, columns=None, fanout=None, predicates=(), keys_exist=False,
        on_error="raise",
    ):
        """Begin an async lookup; :meth:`_collect_lookup` finishes it.

        Model-backed stores override the pair so device inference for
        one morsel overlaps host aux-merge/decode of another (the
        streaming executor and serving engine dispatch morsel *i+1*
        before collecting morsel *i* — across plans, not just within
        one).  The default defers everything to collect time — baseline
        stores have no device stage to overlap, so dispatch/collect
        degenerates to a plain call.  ``predicates`` is the pushed-down
        value-filter conjunction (see :class:`~repro.api.plan.Predicate`);
        ``keys_exist`` asserts every requested key exists (the executor
        sets it for range/scan plans, whose keys come from the
        existence index) — stores may exploit it to skip work (baseline
        partition pruning) but must never rely on it for point plans.
        ``on_error`` is the plan's failure mode (``"raise"``/
        ``"partial"``); multi-owner stores degrade around failed owners
        under ``"partial"``, single-owner stores ignore it (the
        executor handles their partial fallback)."""
        return (keys, columns, fanout, tuple(predicates), keys_exist)

    def _collect_lookup(self, handle):
        """Finish a lookup begun by :meth:`_dispatch_lookup` ->
        ``(values, exists, match, ExplainStats)``.

        ``match`` is ``None`` when no predicates were pushed down;
        otherwise a bool row-selector aligned with the request keys
        (``exists`` AND every predicate holds) — the executor keeps
        only those rows.  The default evaluates predicates on the
        store's ordinary lookup output, i.e. for the baselines on the
        **modification-overlay view**: inserted/updated rows are
        filtered by their overlay values, deleted rows by ``exists``."""
        keys, columns, fanout, predicates, _keys_exist = handle
        if not predicates:
            values, exists, stats = self._lookup_with_stats(
                keys, columns, fanout=fanout
            )
            stats.rows_decoded += int(np.asarray(keys).shape[0])
            return values, exists, None, stats
        selected = tuple(columns) if columns is not None else tuple(self.columns)
        need = columns_with_predicates(selected, predicates)
        values, exists, stats = self._lookup_with_stats(keys, need, fanout=fanout)
        match = evaluate_predicates(predicates, values, exists, stats)
        stats.rows_decoded += int(np.asarray(keys).shape[0])
        if len(need) != len(selected):
            values = {c: values[c] for c in selected}
        return values, exists, match, stats

    def _collect_aggregate(self, handle, group_by, aggregates):
        """Finish an *aggregate* lookup begun by :meth:`_dispatch_lookup`
        -> ``(state, ExplainStats)``.

        ``state`` maps decoded group-value tuples to accumulator lists
        (one per :class:`~repro.api.plan.AggSpec`), foldable across
        morsels/shards/members with
        :func:`~repro.api.plan.merge_agg_states` — keyed by decoded
        VALUES, never codes, because composite stores aggregate over
        members with independent codecs.  The default is the
        decode-then-aggregate reference: collect the rows the ordinary
        way and fold them through
        :func:`~repro.api.plan.aggregate_rows` (baseline stores, which
        decode to answer at all, use this directly).  Code-space stores
        override it to aggregate argmax codes below decode."""
        values, exists, match, stats = self._collect_lookup(handle)
        sel = exists if match is None else match
        t0 = time.perf_counter()
        state: Dict[tuple, list] = {}
        aggregate_rows(state, group_by, aggregates, values, sel)
        stats.agg_s += time.perf_counter() - t0
        return state, stats

    def supports_kernel_filter(self, predicates: tuple = ()) -> bool:
        """Dispatch capability flag: ``True`` when the pushed-down
        ``predicates`` would be evaluated *inside* the store's device
        kernel (match bits emitted alongside codes + exist bits), so
        the executor's host ``Filter`` stage is redundant and may be
        skipped.  The default is ``False`` — baseline stores filter on
        the host.  Advisory only: the executor still honours the
        ``match`` column returned by :meth:`_collect_lookup`, so a
        store that answers ``True`` but falls back to host filtering
        for some chunk remains correct."""
        return False

    # ------------------------------------------------- executor stats hook
    def _lookup_with_stats(
        self,
        keys: np.ndarray,
        columns: Optional[Tuple[str, ...]] = None,
        fanout: Optional[bool] = None,
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray, ExplainStats]:
        """Lookup plus per-call :class:`ExplainStats` (no mutable
        side-channel).  Default wraps :meth:`lookup` with coarse
        timing; model-backed stores override with real stage
        breakdowns.  ``fanout`` is advisory (sharded stores only)."""
        t0 = time.perf_counter()
        values, exists = self.lookup(keys, columns)
        stats = ExplainStats(
            plan=("lookup",),
            heads_skipped=tuple(self.columns),  # no model heads ran
            columns_decoded=tuple(values),
            columns_skipped=tuple(c for c in self.columns if c not in values),
        )
        stats.decode_s = time.perf_counter() - t0
        return values, exists, stats
