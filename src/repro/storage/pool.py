"""Bounded LRU memory pool for decompressed partitions.

Models the paper's memory-constrained regime (§IV-B2): "we free up the
space of the least recently used (LRU) partition before loading the
subsequent partition ... when the memory becomes insufficient".  Every
store (DeepMapping aux table, AB/ABC/HB/HBC baselines) charges its
decompressed partitions against a shared pool so latency comparisons
see identical eviction pressure.
"""

from __future__ import annotations

import collections
import threading
from typing import Callable, Hashable, Tuple


class MemoryPool:
    """LRU cache of opaque objects with a byte budget.

    ``get(key, loader)`` returns the cached object or calls ``loader()``
    -> ``(obj, nbytes)`` and caches it, evicting least-recently-used
    entries until the budget holds.  Objects larger than the budget are
    returned uncached (pure streaming read — matches loading a partition,
    using it, and dropping it).
    """

    def __init__(self, budget_bytes: int):
        if budget_bytes <= 0:
            raise ValueError("budget must be positive")
        self.budget_bytes = int(budget_bytes)
        self._entries: "collections.OrderedDict[Hashable, Tuple[object, int]]" = (
            collections.OrderedDict()
        )
        self._used = 0
        self._lock = threading.Lock()
        # Statistics used by the latency-breakdown benchmark (paper Fig. 7).
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @property
    def used_bytes(self) -> int:
        return self._used

    def get(self, key: Hashable, loader: Callable[[], Tuple[object, int]]):
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
                self.hits += 1
                return entry[0]
            self.misses += 1
        obj, nbytes = loader()
        with self._lock:
            if nbytes > self.budget_bytes:
                return obj  # uncacheable: stream through
            while self._used + nbytes > self.budget_bytes and self._entries:
                _, (_, evicted) = self._entries.popitem(last=False)
                self._used -= evicted
                self.evictions += 1
            self._entries[key] = (obj, nbytes)
            self._used += nbytes
            return obj

    def invalidate(self, key: Hashable) -> None:
        with self._lock:
            entry = self._entries.pop(key, None)
            if entry is not None:
                self._used -= entry[1]

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._used = 0

    def reset_stats(self) -> None:
        self.hits = self.misses = self.evictions = 0
