"""Modality frontend STUBS (per assignment: the transformer backbone is
the deliverable; vision/audio preprocessing provides precomputed
embeddings).

These generate deterministic pseudo-embeddings shaped exactly like the
real frontends would emit: CLIP-style patch embeddings for phi-3-vision,
conformer-frame embeddings for seamless-m4t.  The dry-run's
``input_specs()`` uses only their shapes."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def vision_patch_embeds(
    batch: int, num_patches: int, d_model: int, seed: int = 0, dtype=jnp.bfloat16
) -> jnp.ndarray:
    """Stub CLIP tower output: (batch, num_patches, d_model)."""
    rng = jax.random.PRNGKey(seed)
    return (0.02 * jax.random.normal(rng, (batch, num_patches, d_model))).astype(dtype)


def audio_frame_embeds(
    batch: int, num_frames: int, d_model: int, seed: int = 0, dtype=jnp.bfloat16
) -> jnp.ndarray:
    """Stub speech-frontend output: (batch, num_frames, d_model)."""
    rng = jax.random.PRNGKey(seed + 1)
    return (0.02 * jax.random.normal(rng, (batch, num_frames, d_model))).astype(dtype)
