"""Hypothesis property tests on system-level invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.aux_table import AuxTable
from repro.core.bitvector import BitVector
from repro.core.encoding import KeyEncoder, ValueCodec
from repro.storage import MemoryPool, get_codec

SET = settings(
    max_examples=30, deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


class TestEncodingProperties:
    @SET
    @given(
        keys=st.lists(st.integers(0, 10**12), min_size=1, max_size=50, unique=True),
        base=st.sampled_from([2, 8, 10, 16]),
    )
    def test_digit_decomposition_bijective(self, keys, base):
        keys = np.asarray(keys, dtype=np.int64)
        enc = KeyEncoder(int(keys.max()), base=base)
        d = enc.digits(keys)
        recon = (d[:, : enc._digit_width].astype(np.int64) * enc._divisors).sum(axis=1)
        np.testing.assert_array_equal(recon, keys)
        # distinct keys -> distinct encodings
        assert len(np.unique(d[:, : enc._digit_width], axis=0)) == len(keys)

    @SET
    @given(
        vals=st.lists(
            st.one_of(st.integers(-100, 100), st.text(max_size=5)),
            min_size=1, max_size=60,
        )
    )
    def test_value_codec_roundtrip(self, vals):
        arr = np.asarray([str(v) for v in vals])
        c = ValueCodec("x", arr)
        np.testing.assert_array_equal(c.decode(c.codes), arr)
        assert c.cardinality == len(set(arr.tolist()))


class TestBitvectorProperties:
    @SET
    @given(
        present=st.sets(st.integers(0, 5000), min_size=0, max_size=200),
        probes=st.lists(st.integers(-10, 6000), min_size=1, max_size=100),
    )
    def test_membership_equals_set(self, present, probes):
        bv = BitVector.from_keys(np.fromiter(present, np.int64, len(present)),
                                 capacity=5001)
        got = bv.test(np.asarray(probes, dtype=np.int64))
        want = np.asarray([p in present for p in probes])
        np.testing.assert_array_equal(got, want)

    @SET
    @given(present=st.sets(st.integers(0, 2000), min_size=1, max_size=100))
    def test_serialization_identity(self, present):
        bv = BitVector.from_keys(np.fromiter(present, np.int64, len(present)))
        bv2 = BitVector.from_bytes(bv.to_bytes())
        assert bv.count() == bv2.count()


class TestAuxTableProperties:
    @SET
    @given(
        rows=st.dictionaries(
            st.integers(0, 10**6),
            st.tuples(st.integers(0, 99), st.integers(0, 99)),
            min_size=1, max_size=80,
        ),
        codec=st.sampled_from(["zstd", "none", "gzip"]),
        part=st.sampled_from([64, 256, 4096]),
    )
    def test_aux_is_exact_map(self, rows, codec, part):
        keys = np.fromiter(rows.keys(), np.int64, len(rows))
        codes = np.asarray([rows[int(k)] for k in keys], dtype=np.int32)
        aux = AuxTable.build(keys, codes, codec=codec, partition_bytes=part)
        found, got = aux.get(keys)
        assert found.all()
        np.testing.assert_array_equal(got, codes)
        absent = np.asarray([10**6 + 1, 10**6 + 2], dtype=np.int64)
        f2, _ = aux.get(absent)
        assert not f2.any()

    @SET
    @given(
        rows=st.dictionaries(
            st.integers(0, 10**4), st.integers(0, 9), min_size=2, max_size=50
        ),
        ops=st.lists(st.integers(0, 2), min_size=1, max_size=10),
    )
    def test_mutations_then_compact_is_identity(self, rows, ops):
        keys = np.fromiter(rows.keys(), np.int64, len(rows))
        codes = np.asarray([[rows[int(k)]] for k in keys], dtype=np.int32)
        aux = AuxTable.build(keys, codes)
        model = {int(k): int(v[0]) for k, v in zip(keys, codes)}
        rng = np.random.default_rng(len(rows))
        for op in ops:
            k = int(rng.choice(keys))
            if op == 0:
                nk = int(rng.integers(10**5, 10**6))
                aux.add(np.asarray([nk]), np.asarray([[7]], dtype=np.int32))
                model[nk] = 7
            elif op == 1 and k in model:
                aux.remove(np.asarray([k]))
                model.pop(k, None)
            else:
                aux.update(np.asarray([k]), np.asarray([[3]], dtype=np.int32))
                model[k] = 3
        before = {k: None for k in model}
        probe = np.fromiter(model.keys(), np.int64, len(model))
        f, got = aux.get(probe)
        assert f.all()
        np.testing.assert_array_equal(got[:, 0], [model[int(k)] for k in probe])
        aux.compact()
        f2, got2 = aux.get(probe)
        np.testing.assert_array_equal(got, got2)
        assert f2.all()


class TestCodecProperties:
    @SET
    @given(
        data=st.binary(min_size=0, max_size=5000),
        name=st.sampled_from(["zstd", "zstd1", "gzip", "lzma", "zlib", "none"]),
    )
    def test_codec_roundtrip(self, data, name):
        c = get_codec(name)
        assert c.decompress(c.compress(data)) == data


class TestMemoryPoolProperties:
    @SET
    @given(
        sizes=st.lists(st.integers(1, 500), min_size=1, max_size=30),
        budget=st.integers(100, 2000),
    )
    def test_budget_never_exceeded(self, sizes, budget):
        pool = MemoryPool(budget)
        for i, s in enumerate(sizes):
            pool.get(i, lambda s=s: (bytes(s), s))
            assert pool.used_bytes <= budget
