"""Model substrate: the assigned LM architecture families.

Pure-functional JAX (params as pytrees, stacked leading layer dim for
``lax.scan``).  Families: dense GQA decoders, MLA + MoE (DeepSeek-V3),
GQA + MoE (Llama-4), RWKV6 (attention-free), RG-LRU hybrid
(RecurrentGemma), sliding/global mixes (Gemma-3), encoder-decoder
(Seamless).  Modality frontends are stubs per the assignment: callers
supply precomputed patch/frame embeddings.
"""

from repro.models.config import ModelConfig  # noqa: F401
from repro.models.transformer import DecoderLM  # noqa: F401
from repro.models.encdec import EncDecLM  # noqa: F401
