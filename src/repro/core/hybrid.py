"""The DeepMapping hybrid structure ``M̂ = ⟨M, T_aux, V_exist, f_decode⟩``
(paper §IV) with Algorithm 1 lookup and Algorithm 3/4/5 modifications.

A :class:`DeepMappingStore` owns:

* ``params``/``spec``  — the multi-task memorization MLP ``M``;
* ``aux``              — :class:`~repro.core.aux_table.AuxTable` (``T_aux``);
* ``vexist``           — :class:`~repro.core.bitvector.BitVector`;
* ``codecs``           — per-column :class:`~repro.core.encoding.ValueCodec`
                         (``f_decode``);
* ``encoder``          — digit featurizer for keys.

Eq. 1 of the paper is :meth:`compression_ratio`:
``(size(M)+size(T_aux)+size(V_exist)+size(f_decode)) / size(D)``.

Modification semantics follow the paper exactly: inserts/updates/deletes
are materialized in the auxiliary structures without touching ``M``;
:meth:`should_retrain` triggers lazily once modified bytes exceed a
threshold (the paper's DM-Z1 retrains after 200 MB of modifications).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import model as model_lib
from repro.core import trainer as trainer_lib
from repro.core.aux_table import AuxTable
from repro.core.bitvector import BitVector
from repro.core.encoding import KeyEncoder, ValueCodec, build_codecs
from repro.core.model import MLPSpec
from repro.core.table import Table
from repro.storage import MemoryPool


@dataclasses.dataclass(frozen=True)
class DeepMappingConfig:
    """Build-time knobs. ``shared``/``private`` give the default manual
    architecture; MHAS (``repro.core.mhas``) searches these instead."""

    base: int = 10
    # Beyond-paper: residue feature positions (multi-digit key % r).
    # Empty + auto_residues=False = paper-faithful encoding.  See
    # DESIGN.md §Perf / EXPERIMENTS §Perf.
    residues: Tuple[int, ...] = ()
    auto_residues: bool = False   # detect per-column periods at build
    shared: Tuple[int, ...] = (256, 256)
    private: Tuple[int, ...] = (64,)
    codec: str = "zstd"                    # DM-Z; "lzma" = DM-L
    partition_bytes: int = 128 * 1024
    dtype: str = "float32"
    train: trainer_lib.TrainConfig = dataclasses.field(
        default_factory=trainer_lib.TrainConfig
    )
    # Retrain once this many raw bytes have been inserted/deleted/updated
    # (paper's DM-Z1 uses 200 MB). None disables auto-trigger.
    retrain_after_modified_bytes: Optional[int] = None
    inference_batch: int = 1 << 16
    # Route inference through the fused Pallas kernel (TPU hot path).
    # The SAME path is used for build-time misclassification evaluation
    # and lookup, so T_aux always corrects exactly the deployed model.
    use_pallas: bool = False


@dataclasses.dataclass
class LookupStats:
    """Per-call latency breakdown — feeds the paper's Fig. 7 benchmark."""

    infer_s: float = 0.0
    exist_s: float = 0.0
    aux_s: float = 0.0
    decode_s: float = 0.0

    def total(self) -> float:
        return self.infer_s + self.exist_s + self.aux_s + self.decode_s


def _make_predict_fn(params: Dict, spec: MLPSpec, config: "DeepMappingConfig"):
    """Inference path factory: fused Pallas kernel or plain jit.  Both
    build-time misclassification evaluation and lookup go through the
    SAME function — T_aux corrects exactly the deployed model."""
    if config.use_pallas:
        from repro.kernels import fused_mlp_codes

        return lambda digits: fused_mlp_codes(params, spec, digits)
    return lambda digits: trainer_lib.predict_codes_jit(params, digits, spec)


class DeepMappingStore:
    """Hybrid learned KV store for one relation (single packed key)."""

    def __init__(
        self,
        encoder: KeyEncoder,
        spec: MLPSpec,
        params: Dict,
        codecs: Dict[str, ValueCodec],
        aux: AuxTable,
        vexist: BitVector,
        raw_bytes: int,
        num_rows: int,
        config: DeepMappingConfig,
    ):
        self.encoder = encoder
        self.spec = spec
        self.params = params
        self.codecs = codecs
        self.aux = aux
        self.vexist = vexist
        self.raw_bytes = int(raw_bytes)
        self.num_rows = int(num_rows)
        self.config = config
        self.modified_bytes = 0
        self.last_stats = LookupStats()
        self._bytes_per_row = raw_bytes / max(1, num_rows)

    # ------------------------------------------------------------------ build
    @classmethod
    def build(
        cls,
        table: Table,
        config: DeepMappingConfig = DeepMappingConfig(),
        pool: Optional[MemoryPool] = None,
        spec: Optional[MLPSpec] = None,
        params: Optional[Dict] = None,
        verbose: bool = False,
    ) -> "DeepMappingStore":
        """Train (or accept) a mapping model and assemble the hybrid.

        Passing ``spec``+``params`` (e.g. from MHAS) skips training.
        """
        residues = config.residues
        if config.auto_residues:
            from repro.core.encoding import detect_residues

            residues = tuple(sorted(set(residues) | set(
                detect_residues(table.keys, table.columns, config.base)
            )))
            if verbose and residues:
                print(f"[build] auto-detected residue periods: {residues}")
        encoder = KeyEncoder(table.max_key, base=config.base, residues=residues)
        codecs = build_codecs(table.columns)
        if spec is None:
            spec = MLPSpec(
                base=config.base,
                width=encoder.width,
                shared=tuple(config.shared),
                private={n: tuple(config.private) for n in table.columns},
                out_cards={n: codecs[n].cardinality for n in table.columns},
                dtype=config.dtype,
            )
        digits = encoder.digits(table.keys)
        codes = np.stack([codecs[t].codes for t in spec.tasks], axis=1)
        if params is None:
            params, _, hist = trainer_lib.train(spec, digits, codes, config.train)
            if verbose:
                print(f"[build] trained {len(hist)} epochs, final loss {hist[-1]:.5f}")
        predict_fn = _make_predict_fn(params, spec, config)
        wrong = trainer_lib.evaluate_misclassified(
            params, digits, codes, spec, predict_fn=predict_fn
        )
        aux = AuxTable.build(
            table.keys[wrong],
            codes[wrong],
            codec=config.codec,
            partition_bytes=config.partition_bytes,
            pool=pool,
        )
        vexist = BitVector.from_keys(table.keys)
        store = cls(
            encoder=encoder,
            spec=spec,
            params=params,
            codecs=codecs,
            aux=aux,
            vexist=vexist,
            raw_bytes=table.raw_size_bytes(),
            num_rows=table.num_rows,
            config=config,
        )
        if verbose:
            memorized = 1.0 - wrong.mean() if wrong.size else 1.0
            print(
                f"[build] memorized {memorized:.1%} of {table.num_rows} rows; "
                f"ratio {store.compression_ratio():.4f}"
            )
        return store

    # ---------------------------------------------------------------- lookup
    def _infer_codes(self, keys: np.ndarray) -> np.ndarray:
        """Model predictions for (possibly out-of-capacity) keys."""
        if not hasattr(self, "_predict_fn"):
            self._predict_fn = _make_predict_fn(self.params, self.spec, self.config)
        out = np.zeros((keys.shape[0], len(self.spec.tasks)), dtype=np.int32)
        in_cap = keys < self.encoder.capacity
        idx = np.flatnonzero(in_cap)
        bs = self.config.inference_batch
        for start in range(0, idx.size, bs):
            sel = idx[start : start + bs]
            digits = self.encoder.digits(keys[sel])
            out[sel] = np.asarray(self._predict_fn(jnp.asarray(digits)))
        return out

    def lookup(
        self, keys: np.ndarray, columns: Optional[Tuple[str, ...]] = None
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Algorithm 1 — batched exact-match lookup.

        Returns ``(values, exists)``: per-column decoded arrays (rows
        where ``exists`` is False are NULL — filled with the column's
        code-0 value, callers must respect the mask) plus the existence
        mask.
        """
        keys = np.asarray(keys, dtype=np.int64)
        stats = LookupStats()

        t0 = time.perf_counter()
        pred = self._infer_codes(keys)                       # line 3 (batch inference)
        t1 = time.perf_counter()
        exists = self.vexist.test(keys)                      # line 5 (existence check)
        t2 = time.perf_counter()
        # line 6-8: aux override for existing keys only.
        exist_idx = np.flatnonzero(exists)
        found, aux_codes = self.aux.get(keys[exist_idx])
        pred[exist_idx[found]] = aux_codes[found]
        t3 = time.perf_counter()
        # line 13: decode.
        wanted = columns if columns is not None else self.spec.tasks
        values: Dict[str, np.ndarray] = {}
        for i, t in enumerate(self.spec.tasks):
            if t in wanted:
                safe = np.where(exists, pred[:, i], 0)
                values[t] = self.codecs[t].decode(safe)
        t4 = time.perf_counter()

        stats.infer_s, stats.exist_s = t1 - t0, t2 - t1
        stats.aux_s, stats.decode_s = t3 - t2, t4 - t3
        self.last_stats = stats
        return values, exists

    # ------------------------------------------------ modifications (Alg 3-5)
    def _encode_rows(self, columns: Dict[str, np.ndarray]) -> np.ndarray:
        """Encode raw values to codes, extending codecs for unseen values.

        Codes beyond a head's out_card can never be predicted by ``M``,
        so such rows are automatically routed to T_aux — exactly the
        paper's semantics for values the model cannot express.
        """
        cols = []
        for t in self.spec.tasks:
            codec = self.codecs[t]
            codec.extend(columns[t])
            codes, known = codec.encode(columns[t])
            assert known.all(), "extend() must make every value encodable"
            cols.append(codes)
        return np.stack(cols, axis=1)

    def insert(self, keys: np.ndarray, columns: Dict[str, np.ndarray]) -> None:
        """Algorithm 3. Pairs the model already generalizes to are NOT
        stored; the rest land in T_aux."""
        keys = np.asarray(keys, dtype=np.int64)
        if self.vexist.test(keys).any():
            raise ValueError("insert of existing key; use update()")
        codes = self._encode_rows(columns)
        self.vexist.set(keys, True)                      # line 4
        pred = self._infer_codes(keys)                   # line 5 (inference check)
        wrong = (pred != codes).any(axis=1) | (keys >= self.encoder.capacity)
        if wrong.any():
            self.aux.add(keys[wrong], codes[wrong])      # line 9
        self.num_rows += keys.shape[0]
        self.raw_bytes += int(keys.shape[0] * self._bytes_per_row)
        self.modified_bytes += int(keys.shape[0] * self._bytes_per_row)

    def delete(self, keys: np.ndarray) -> None:
        """Algorithm 4. Existence bit off; purge from T_aux if present."""
        keys = np.asarray(keys, dtype=np.int64)
        present = self.vexist.test(keys)
        keys = keys[present]
        if keys.size == 0:
            return
        self.vexist.set(keys, False)                     # line 4
        in_aux = self.aux.contains(keys)                 # line 5
        if in_aux.any():
            self.aux.remove(keys[in_aux])
        self.num_rows -= keys.shape[0]
        self.raw_bytes -= int(keys.shape[0] * self._bytes_per_row)
        self.modified_bytes += int(keys.shape[0] * self._bytes_per_row)

    def update(self, keys: np.ndarray, columns: Dict[str, np.ndarray]) -> None:
        """Algorithm 5. Correctly-predicted updates drop any aux entry;
        the rest are upserted into T_aux."""
        keys = np.asarray(keys, dtype=np.int64)
        if not self.vexist.test(keys).all():
            raise ValueError("update of non-existing key; use insert()")
        codes = self._encode_rows(columns)
        pred = self._infer_codes(keys)
        right = (pred == codes).all(axis=1) & (keys < self.encoder.capacity)
        if right.any():
            in_aux = self.aux.contains(keys[right])      # line 4
            if in_aux.any():
                self.aux.remove(keys[right][in_aux])
        wrong = ~right
        if wrong.any():
            self.aux.update(keys[wrong], codes[wrong])   # lines 7-11
        self.modified_bytes += int(keys.shape[0] * self._bytes_per_row)

    def range_lookup(
        self, lo: int, hi: int, columns: Optional[Tuple[str, ...]] = None
    ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        """Paper §IV-E, first approach: range-filter the existence index
        to collect keys in [lo, hi), then answer them by batch inference
        (Algorithm 1).  Exact (not the approximate view-based variant).

        Returns (keys, values) for existing keys in the range.
        """
        keys = self.vexist.keys_in_range(lo, hi)
        values, exists = self.lookup(keys, columns)
        assert bool(exists.all())
        return keys, values

    def should_retrain(self) -> bool:
        thr = self.config.retrain_after_modified_bytes
        return thr is not None and self.modified_bytes >= thr

    def materialize(self) -> Table:
        """Reconstruct the full logical table (used by retrain)."""
        keys = self.vexist.keys_in_range()
        values, exists = self.lookup(keys)
        assert bool(exists.all())
        return Table(keys=keys, columns=values)

    def retrain(self, verbose: bool = False) -> "DeepMappingStore":
        """Rebuild model + auxiliary structures on current logical data
        (paper: lazily, offline/background/non-peak)."""
        return DeepMappingStore.build(
            self.materialize(), self.config, pool=self.aux.pool, verbose=verbose
        )

    # ------------------------------------------------------------- accounting
    def size_breakdown(self) -> Dict[str, int]:
        """Bytes per component — the paper's Fig. 6 storage breakdown."""
        return {
            "model": model_lib.model_size_bytes(self.params),
            "aux_table": self.aux.size_bytes(),
            "exist_bitvector": self.vexist.size_bytes(),
            "decode_map": sum(c.size_bytes() for c in self.codecs.values())
            + self.encoder.size_bytes(),
        }

    def size_bytes(self) -> int:
        return sum(self.size_breakdown().values())

    def compression_ratio(self) -> float:
        """Paper Eq. 1 — lower is better; 1.0 means no compression."""
        return self.size_bytes() / max(1, self.raw_bytes)

    def memorized_fraction(self) -> float:
        """Fraction of rows answered by ``M`` alone (paper reports 66-81%)."""
        return 1.0 - self.aux.num_rows / max(1, self.num_rows)
