"""TPC-H query suite for the code-space aggregate/join layer (ISSUE 10).

Small generated TPC-H tables (``repro.data.tpch``) through the real
query verbs:

* Q1-style aggregation — ``GROUP BY l_returnflag, l_linestatus`` with
  count + sum/min/max(l_quantity), with and without the quantity
  predicate — value-identical to the pure-numpy oracle in
  ``tests/tpch_reference.py``;
* lineitem ⋈ orders key-equi join through the composite-key decode
  (``l_orderkey = key // 8``), surviving rows and joined ``o_clerk``
  values checked against a python-dict oracle;
* the tentpole evidence contract on real TPC-H shapes: count-only
  aggregates over the model-backed store report ``rows_decoded == 0``.

Marked ``tpch`` so the dedicated CI job can run it standalone
(``pytest -m tpch``); it stays cheap enough for tier-1 too.
"""

import numpy as np
import pytest
from tpch_reference import (
    assert_aggregate_equal,
    ref_group_aggregate,
    ref_join_mask,
)

from repro.cluster import ClusterConfig, ShardedDeepMappingStore
from repro.core import DeepMappingConfig, DeepMappingStore
from repro.core.trainer import TrainConfig
from repro.data.tpch import lineitem_like, orders_like

pytestmark = pytest.mark.tpch

TINY = DeepMappingConfig(
    shared=(16,), private=(4,), train=TrainConfig(epochs=2, batch_size=512)
)

N_LINEITEM = 8_400
N_ORDERS = 2_000

#: lineitem keys are pack_composite_key([orderkey, lineno(1..7)]) —
#: mixed-radix with radix 8 on the low digit.
def l_orderkey(keys):
    return keys // 8


@pytest.fixture(scope="module")
def lineitem():
    table = lineitem_like(n=N_LINEITEM, seed=3)
    return table, DeepMappingStore.build(table, TINY)


@pytest.fixture(scope="module")
def orders():
    table = orders_like(n=N_ORDERS, seed=4)
    store = ShardedDeepMappingStore.build(
        table, TINY, ClusterConfig(num_shards=3, policy="range")
    )
    return table, store


class TestQ1Aggregation:
    GROUP = ("l_returnflag", "l_linestatus")
    SPECS = (
        "count", ("sum", "l_quantity"), ("min", "l_quantity"),
        ("max", "l_quantity"),
    )
    REF = (
        ("count", None), ("sum", "l_quantity"), ("min", "l_quantity"),
        ("max", "l_quantity"),
    )

    def test_q1_groupby_matches_oracle(self, lineitem):
        table, store = lineitem
        groups, aggs = ref_group_aggregate(table.columns, self.GROUP, self.REF)
        res = (
            store.query().group_by(*self.GROUP).agg(*self.SPECS)
            .scan().execute()
        )
        assert_aggregate_equal(res, groups, aggs)
        assert res.num_groups == 6  # 3 returnflags x 2 linestatuses

    def test_q1_with_quantity_predicate(self, lineitem):
        table, store = lineitem
        sel = table.columns["l_quantity"] <= 25
        groups, aggs = ref_group_aggregate(
            table.columns, self.GROUP, self.REF, sel=sel
        )
        for pushdown in (True, False):
            res = (
                store.query().where("l_quantity", "<=", 25)
                .group_by(*self.GROUP).agg(*self.SPECS)
                .pushdown(pushdown).scan().execute()
            )
            assert_aggregate_equal(res, groups, aggs)

    def test_count_only_decodes_zero_rows(self, lineitem):
        table, store = lineitem
        res = (
            store.query().group_by(*self.GROUP).agg("count")
            .scan().execute()
        )
        groups, aggs = ref_group_aggregate(
            table.columns, self.GROUP, (("count", None),)
        )
        assert_aggregate_equal(res, groups, aggs)
        assert res.explain.rows_decoded == 0
        assert res.explain.groups_emitted == 6

    def test_shipmode_distribution(self, lineitem):
        table, store = lineitem
        groups, aggs = ref_group_aggregate(
            table.columns, ("l_shipmode",), (("count", None),)
        )
        res = store.query().group_by("l_shipmode").agg("count").scan().execute()
        assert_aggregate_equal(res, groups, aggs)
        assert res.explain.rows_decoded == 0


class TestLineitemOrdersJoin:
    def test_join_matches_oracle(self, lineitem, orders):
        ltable, lstore = lineitem
        otable, ostore = orders
        res = (
            lstore.query().join(ostore, key=l_orderkey, columns=("o_clerk",))
            .scan().execute()
        )
        mask = ref_join_mask(ltable.keys, l_orderkey, otable.keys)
        assert mask.any() and not mask.all()
        np.testing.assert_array_equal(res.keys, ltable.keys[mask])
        clerk = {int(k): int(v) for k, v in zip(
            otable.keys, otable.columns["o_clerk"]
        )}
        np.testing.assert_array_equal(
            np.asarray(res.values["o_clerk"]),
            [clerk[int(k) // 8] for k in res.keys],
        )
        assert res.explain.join_probes == len(ltable.keys)

    def test_join_with_lineitem_predicate(self, lineitem, orders):
        ltable, lstore = lineitem
        otable, ostore = orders
        res = (
            lstore.query().where("l_quantity", ">", 40)
            .join(ostore, key=l_orderkey, columns=("o_clerk",))
            .scan().execute()
        )
        mask = ref_join_mask(ltable.keys, l_orderkey, otable.keys)
        mask &= ltable.columns["l_quantity"] > 40
        np.testing.assert_array_equal(res.keys, ltable.keys[mask])
        np.testing.assert_array_equal(
            np.asarray(res.values["l_quantity"]),
            ltable.columns["l_quantity"][mask],
        )
