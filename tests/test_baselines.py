import numpy as np
import pytest

from repro.baselines import BASELINE_FACTORIES, ArrayStore, HashStore
from repro.data import synthetic_multi_column
from repro.data.tpch import orders_like
from repro.storage import MemoryPool


@pytest.fixture(scope="module")
def table():
    return synthetic_multi_column(n=5000, correlation="high", seed=1)


@pytest.fixture(scope="module")
def string_table():
    return orders_like(n=2000)


class TestBaselineStores:
    @pytest.mark.parametrize("name", sorted(BASELINE_FACTORIES))
    def test_exact_lookup_all(self, name, table):
        store = BASELINE_FACTORIES[name](table, partition_bytes=4096)
        q = table.keys[:: max(1, table.num_rows // 500)]
        vals, exists = store.lookup(q)
        assert exists.all()
        for col in table.columns:
            np.testing.assert_array_equal(vals[col], table.columns[col][:: max(1, table.num_rows // 500)])

    @pytest.mark.parametrize("name", ["AB", "ABC-Z", "HB", "HBC-Z"])
    def test_missing_keys(self, name, table):
        store = BASELINE_FACTORIES[name](table, partition_bytes=4096)
        missing = np.array([table.max_key + 10, table.max_key + 11], dtype=np.int64)
        _, exists = store.lookup(missing)
        assert not exists.any()

    @pytest.mark.parametrize("name", ["ABC-Z", "ABC-L", "ABC-G", "ABC-D"])
    def test_compression_shrinks(self, name, table):
        ab = BASELINE_FACTORIES["AB"](table, partition_bytes=65536)
        abc = BASELINE_FACTORIES[name](table, partition_bytes=65536)
        assert abc.size_bytes() < ab.size_bytes()

    def test_string_columns(self, string_table):
        for name in ["AB", "ABC-Z", "HB"]:
            store = BASELINE_FACTORIES[name](string_table, partition_bytes=8192)
            q = string_table.keys[:100]
            vals, exists = store.lookup(q)
            assert exists.all()
            got = vals["o_orderstatus"].astype(str)
            np.testing.assert_array_equal(
                got, string_table.columns["o_orderstatus"][:100].astype(str)
            )

    def test_shared_pool_pressure(self, table):
        pool = MemoryPool(budget_bytes=16 * 1024)
        store = ArrayStore.build(table, codec="zstd", partition_bytes=4096, pool=pool)
        vals, exists = store.lookup(table.keys)
        assert exists.all()
        assert pool.evictions > 0

    def test_hash_store_partition_count(self, table):
        hs = HashStore.build(table, codec="none", partition_bytes=2048)
        assert len(hs._partitions) > 1

    def test_column_projection(self, table):
        store = ArrayStore.build(table, codec="zstd")
        vals, _ = store.lookup(table.keys[:10], columns=["v0"])
        assert set(vals) == {"v0"}


class TestZoneMapPersistence:
    """Dictionary-mode zone maps ride the v2 checksummed envelope:
    built maps round-trip bit-exactly, stale or malformed entries are
    dropped (lazy rebuild covers them), and the payload crc covers the
    packed bits like every other field."""

    @pytest.fixture()
    def built(self, table):
        store = ArrayStore.build(
            table, codec="zlib", dictionary=True, partition_bytes=4096
        )
        zones = {
            c: store._partition_code_presence(c).copy()
            for c in store.names
        }
        return store, zones

    def test_round_trip_bit_exact(self, built, tmp_path):
        store, zones = built
        path = str(tmp_path / "ab.bin")
        store.save(path)
        loaded = ArrayStore.load(path)
        assert set(loaded._zone_maps) == set(zones)
        for c, z in zones.items():
            np.testing.assert_array_equal(loaded._zone_maps[c], z)

    def test_loaded_maps_match_lazy_rebuild(self, built, tmp_path):
        store, zones = built
        path = str(tmp_path / "ab.bin")
        store.save(path)
        loaded = ArrayStore.load(path)
        loaded._zone_maps.clear()  # force the from-partitions rebuild
        for c, z in zones.items():
            np.testing.assert_array_equal(
                loaded._partition_code_presence(c), z
            )

    def test_unbuilt_maps_save_nothing(self, table, tmp_path):
        store = ArrayStore.build(
            table, codec="none", dictionary=True, partition_bytes=4096
        )
        path = str(tmp_path / "ab.bin")
        store.save(path)  # no predicated scan ran: no maps built
        assert "zone_maps" not in store._extra_state()
        assert ArrayStore.load(path)._zone_maps == {}

    def test_stale_maps_dropped_gracefully(self, built, tmp_path):
        from repro.baselines.partitioned import _read_baseline_state

        store, zones = built
        path = str(tmp_path / "ab.bin")
        store.save(path)
        state = _read_baseline_state(path)
        zm = state["extra"]["zone_maps"]
        col0, col1 = sorted(zm)[:2]
        zm[col0]["partitions"] += 1          # partition-count drift
        zm[col1]["bits"] = zm[col1]["bits"][:1]  # truncated bit buffer
        zm["ghost"] = {"partitions": 1, "cardinality": 2, "bits": b"\xff"}
        loaded = ArrayStore.from_saved_state(state)
        # the corrupted/unknown entries are dropped; the load succeeds
        assert col0 not in loaded._zone_maps
        assert col1 not in loaded._zone_maps
        assert "ghost" not in loaded._zone_maps
        for c, z in zones.items():           # lazy rebuild still exact
            np.testing.assert_array_equal(
                loaded._partition_code_presence(c), z
            )

    def test_checksum_covers_zone_maps(self, built, tmp_path):
        from repro.core.serialize import IntegrityError

        store, _ = built
        path = str(tmp_path / "ab.bin")
        store.save(path)
        data = bytearray(open(path, "rb").read())
        # flip one bit near the end of the payload (zone maps serialize
        # inside "extra", the last state field)
        data[len(data) - 16] ^= 0x40
        with open(path, "wb") as f:
            f.write(bytes(data))
        with pytest.raises((IntegrityError, ValueError)):
            ArrayStore.load(path)
