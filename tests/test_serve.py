import numpy as np
import pytest

from conftest import make_periodic_table
from repro.core import DeepMappingConfig, DeepMappingStore
from repro.core.trainer import TrainConfig
from repro.serve import LookupServer


@pytest.fixture(scope="module")
def server():
    table = make_periodic_table(n=2000)
    store = DeepMappingStore.build(
        table,
        DeepMappingConfig(shared=(64,), private=(16,),
                          train=TrainConfig(epochs=15, batch_size=512)),
    )
    return table, LookupServer(store, max_batch=512)


class TestLookupServer:
    def test_single_request(self, server):
        table, srv = server
        vals, exists = srv.lookup(table.keys[:100])
        assert exists.all()
        np.testing.assert_array_equal(vals["col0"], table.columns["col0"][:100])

    def test_merged_requests_scatter_correctly(self, server):
        table, srv = server
        rng = np.random.default_rng(0)
        reqs = [rng.choice(table.keys, size=s) for s in (17, 300, 5)]
        results = srv.lookup_many(reqs)
        lut = dict(zip(table.keys.tolist(), table.columns["col0"].tolist()))
        for req, (vals, exists) in zip(reqs, results):
            assert exists.all()
            for k, v in zip(req.tolist(), vals["col0"].tolist()):
                assert lut[k] == v

    def test_dedup_shares_inference(self, server):
        table, srv = server
        srv.stats.keys = 0
        srv.stats.batches = 0
        same = np.full(1000, int(table.keys[3]), dtype=np.int64)
        out = srv.lookup_many([same, same])
        assert all(e.all() for _, e in out)
        # 2000 requested keys collapse into one device batch
        assert srv.stats.batches == 1

    def test_missing_keys_null(self, server):
        table, srv = server
        missing = np.array([table.max_key + 7, table.max_key + 9])
        _, exists = srv.lookup(missing)
        assert not exists.any()

    def test_column_projection(self, server):
        table, srv = server
        vals, _ = srv.lookup(table.keys[:5], columns=("col1",))
        assert set(vals) == {"col1"}

    def test_empty_request_list(self, server):
        """Regression: lookup_many([]) crashed in np.concatenate."""
        _, srv = server
        assert srv.lookup_many([]) == []

    def test_zero_length_requests(self, server):
        _, srv = server
        out = srv.lookup_many([np.zeros(0, dtype=np.int64)] * 3)
        assert len(out) == 3
        for vals, exists in out:
            assert exists.shape == (0,)
            # typed empty columns, same contract as the store itself
            assert set(vals) == set(srv.store.columns)
            for arr in vals.values():
                assert arr.shape == (0,)

    def test_stats_accumulate(self, server):
        table, srv = server
        srv.stats.requests = 0
        srv.lookup(table.keys[:10])
        srv.lookup(table.keys[:10])
        assert srv.stats.requests == 2
        assert srv.stats.qps() > 0

    def test_stats_record_all_pipeline_stages(self, server):
        """Regression: exist_s/decode_s used to be dropped on the floor."""
        table, srv = server
        srv.stats = type(srv.stats)()
        srv.lookup(table.keys[:200])
        s = srv.stats
        assert s.infer_s > 0 and s.decode_s > 0
        assert s.exist_s >= 0 and s.aux_s >= 0
        # fused existence runs in-kernel (exist_s ~ 0); host path times it
        assert s.total_s > 0

    def test_stats_route_and_gather_timings(self, server):
        """ISSUE 6: ServeStats surfaces executor route/gather accounting."""
        table, srv = server
        srv.stats = type(srv.stats)()
        srv.lookup_many([table.keys[:100], table.keys[50:150]])
        s = srv.stats
        assert s.route_s >= 0
        assert s.gather_s > 0  # per-request scatter always does work
        assert s.filter_s >= 0

    def test_stats_plan_cache_outcomes(self, server):
        """Cache hit/miss/bypass counts come from the executor, not a
        parallel server-side guess."""
        table, srv = server
        srv.stats = type(srv.stats)()
        srv.lookup(table.keys[:64])
        srv.lookup(table.keys[:64])
        s = srv.stats
        # every request is exactly one of hit/miss/bypass
        assert s.cache_hits + s.cache_misses + s.cache_bypass == 2
