"""Array-based baseline (paper's AB / ABC-*).

The table is sorted by key and split into fixed-row partitions.  Each
partition serializes ``keys`` + per-column value arrays into one buffer
(numpy raw bytes with a tiny header — the paper's "serialized numpy
array"), optionally dictionary-encodes values first (ABC-D) and/or
compresses the buffer (ABC-G/Z/L).  Lookup binary-searches boundary
keys for the partition, loads/decompresses it through the shared memory
pool, then binary-searches inside (the paper's stated lookup cost).

Modifications (insert/delete/update) and persistence come from
:class:`~repro.baselines.partitioned.PartitionedBaselineStore`: the
partitions stay immutable, an overlay patches lookups.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.baselines.partitioned import (
    PartitionedBaselineStore,
    _array_from_state,
    _array_to_state,
)
from repro.core.encoding import ValueCodec
from repro.core.table import Table
from repro.storage import MemoryPool, get_codec


def _pack_arrays(keys: np.ndarray, cols: Dict[str, np.ndarray]) -> bytes:
    """Self-describing buffer: [n, ncols] + keys + per-col (dtype tag, data)."""
    parts = [np.array([keys.shape[0], len(cols)], dtype=np.int64).tobytes()]
    parts.append(keys.tobytes())
    for name in sorted(cols):
        arr = cols[name]
        dt = arr.dtype.str.encode()
        parts.append(np.array([len(dt), arr.nbytes], dtype=np.int64).tobytes())
        parts.append(dt)
        parts.append(arr.tobytes())
    return b"".join(parts)


def _unpack_arrays(blob: bytes, names) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
    n, ncols = np.frombuffer(blob[:16], dtype=np.int64)
    n, ncols = int(n), int(ncols)
    off = 16
    keys = np.frombuffer(blob[off : off + 8 * n], dtype=np.int64)
    off += 8 * n
    cols: Dict[str, np.ndarray] = {}
    for name in sorted(names):
        dtlen, nbytes = np.frombuffer(blob[off : off + 16], dtype=np.int64)
        off += 16
        dt = blob[off : off + int(dtlen)].decode()
        off += int(dtlen)
        cols[name] = np.frombuffer(blob[off : off + int(nbytes)], dtype=np.dtype(dt))
        off += int(nbytes)
    return keys, cols


class ArrayStore(PartitionedBaselineStore):
    """AB (codec='none'), ABC-D (dictionary=True), ABC-G/Z/L."""

    kind = "array_store"

    def __init__(
        self,
        names,
        codec: str,
        dictionary: bool,
        partition_bytes: int,
        pool: Optional[MemoryPool],
    ):
        self.names = list(names)
        self.codec_name = codec
        self._codec = get_codec(codec)
        self.dictionary = dictionary
        self.partition_bytes = partition_bytes
        self.pool = pool if pool is not None else MemoryPool(1 << 30)
        self._partitions: list[bytes] = []
        self._boundaries = np.zeros(0, dtype=np.int64)
        self._decoders: Dict[str, ValueCodec] = {}
        # Lazy per-column zone maps over the immutable partitions
        # (dictionary mode only) — the partition-pruning evidence.
        self._zone_maps: Dict[str, np.ndarray] = {}
        self.num_rows = 0
        self._init_overlay()

    @classmethod
    def build(
        cls,
        table: Table,
        codec: str = "none",
        dictionary: bool = False,
        partition_bytes: int = 4 * 1024 * 1024,
        pool: Optional[MemoryPool] = None,
    ) -> "ArrayStore":
        store = cls(table.value_names, codec, dictionary, partition_bytes, pool)
        t = table.sorted_by_key()
        cols: Dict[str, np.ndarray] = {}
        for name in t.value_names:
            col = t.columns[name]
            if dictionary or col.dtype == object:
                vc = ValueCodec(name, col)
                store._decoders[name] = vc
                # smallest int dtype that fits the cardinality
                dt = np.uint8 if vc.cardinality <= 256 else (
                    np.uint16 if vc.cardinality <= 65536 else np.int32
                )
                cols[name] = vc.codes.astype(dt) if dictionary else col
                if not dictionary:
                    # object columns must still be encodable to raw bytes:
                    cols[name] = np.char.encode(col.astype(str), "utf-8").astype("S")
            else:
                cols[name] = col
        row_bytes = 8 + sum(
            (c.dtype.itemsize if c.dtype != object else 16) for c in cols.values()
        )
        rows_per_part = max(1, partition_bytes // row_bytes)
        bounds = []
        for start in range(0, t.num_rows, rows_per_part):
            k = t.keys[start : start + rows_per_part]
            pc = {n: c[start : start + rows_per_part] for n, c in cols.items()}
            store._partitions.append(store._codec.compress(_pack_arrays(k, pc)))
            bounds.append(int(k[0]))
        store._boundaries = np.asarray(bounds, dtype=np.int64)
        store.num_rows = t.num_rows
        return store

    def _load(self, idx: int):
        def loader():
            blob = self._codec.decompress(self._partitions[idx])
            part = _unpack_arrays(blob, self.names)
            nbytes = part[0].nbytes + sum(c.nbytes for c in part[1].values())
            return part, nbytes

        return self.pool.get(("ab", id(self), idx), loader)

    def _base_lookup(self, keys: np.ndarray, wanted: List[str]):
        n = keys.shape[0]
        exists = np.zeros(n, dtype=bool)
        out: Dict[str, np.ndarray] = {}
        gathered = {name: [] for name in wanted}
        # Hit bookkeeping only pays off when values must be gathered;
        # exists-only probes (mutation validation, predicate-only
        # requests) skip it.
        gathered_idx = [] if wanted else None
        if self._partitions:
            pid = np.searchsorted(self._boundaries, keys, side="right") - 1
            order = np.argsort(pid, kind="stable")
            start = 0
            while start < n:
                end = start
                p = pid[order[start]]
                while end < n and pid[order[end]] == p:
                    end += 1
                if p >= 0:
                    pkeys, pcols = self._load(int(p))
                    qidx = order[start:end]
                    qk = keys[qidx]
                    pos = np.searchsorted(pkeys, qk)
                    hit = (pos < pkeys.shape[0]) & (
                        pkeys[np.minimum(pos, pkeys.shape[0] - 1)] == qk
                    )
                    sel = qidx[hit]
                    exists[sel] = True
                    if gathered_idx is not None:
                        gathered_idx.append(sel)
                        for name in wanted:
                            gathered[name].append(pcols[name][pos[hit]])
                start = end
        idx = (
            np.concatenate(gathered_idx)
            if gathered_idx
            else np.zeros(0, dtype=np.int64)
        )
        for name in wanted:
            vals = (
                np.concatenate(gathered[name])
                if gathered[name]
                else np.zeros(0, dtype=np.int64)
            )
            if self.dictionary and name in self._decoders:
                decoded_hits = self._decoders[name].decode(vals)
            else:
                decoded_hits = vals
            col = np.zeros(n, dtype=decoded_hits.dtype if decoded_hits.size else np.int64)
            if idx.size:
                col[idx] = decoded_hits
            out[name] = col
        return out, exists

    # ----------------------------------------------------- pruning hooks
    def _column_decoder(self, column: str) -> Optional[ValueCodec]:
        """Dictionary-mode columns expose their codec for zone-map
        pruning; raw-value columns return ``None``."""
        if not self.dictionary:
            return None
        return self._decoders.get(column)

    # Memo of immutable derived data (see docstring) — a zone-map build
    # is not a logical store mutation and must NOT bump the PlanCache.
    # deeplint: ignore[mutation-version]
    def _partition_code_presence(self, column: str) -> Optional[np.ndarray]:
        """Lazy zone map: bool ``(num_partitions, cardinality)`` of the
        codes present in each partition (dictionary mode only).  Built
        once per column by one pass over the partitions — the same
        pool-cached loads a first scan pays anyway — and valid forever
        (base partitions are immutable; overlay rows are handled by the
        pruning path's touched-key exclusion)."""
        if self._column_decoder(column) is None:
            return None
        zone = self._zone_maps.get(column)
        if zone is None:
            cardinality = self._decoders[column].cardinality
            zone = np.zeros((len(self._partitions), cardinality), dtype=bool)
            for pidx in range(len(self._partitions)):
                _, pcols = self._load(pidx)
                codes = np.unique(np.asarray(pcols[column], dtype=np.int64))
                zone[pidx, codes] = True
            self._zone_maps[column] = zone
        return zone

    def _base_keys_in_range(self, lo: int, hi: Optional[int]) -> np.ndarray:
        first, last = self._partition_span(lo, hi)
        parts = []
        for p in range(first, last + 1):
            pkeys, _ = self._load(p)
            a = int(np.searchsorted(pkeys, lo, side="left"))
            b = pkeys.shape[0] if hi is None else int(np.searchsorted(pkeys, hi, side="left"))
            if b > a:
                parts.append(np.asarray(pkeys[a:b], dtype=np.int64))
        return np.concatenate(parts) if parts else np.zeros(0, dtype=np.int64)

    # ---------------------------------------------------------- accounting
    def _extra_breakdown(self) -> Dict[str, int]:
        return {"decode_map": sum(vc.size_bytes() for vc in self._decoders.values())}

    # ---------------------------------------------------------- persistence
    def _extra_state(self) -> Dict:
        state = {
            "dictionary": self.dictionary,
            "decoders": {
                name: _array_to_state(vc.decode_map)
                for name, vc in self._decoders.items()
            },
        }
        if self._zone_maps:
            # Persist whichever zone maps are already built (bit-packed:
            # a map is bool (partitions, cardinality)) so a loaded store
            # prunes from the first predicated scan without re-reading
            # every partition.  They ride the v2 envelope, so the crc
            # covers them like every other field.
            state["zone_maps"] = {
                name: {
                    "partitions": int(zone.shape[0]),
                    "cardinality": int(zone.shape[1]),
                    "bits": np.packbits(zone, axis=None).tobytes(),
                }
                for name, zone in self._zone_maps.items()
            }
        return state

    @classmethod
    def _construct(cls, state: Dict, pool: Optional[MemoryPool]) -> "ArrayStore":
        store = cls(
            state["names"],
            state["codec"],
            state["extra"]["dictionary"],
            state["partition_bytes"],
            pool,
        )
        for name, dm_state in state["extra"]["decoders"].items():
            store._decoders[name] = ValueCodec.from_decode_map(
                name, _array_from_state(dm_state)
            )
        n_parts = len(state["partitions"])
        for name, zm in state["extra"].get("zone_maps", {}).items():
            # A stale or malformed map (unknown column, partition count
            # or cardinality drift, truncated bits) is silently dropped:
            # the lazy build in ``_partition_code_presence`` regenerates
            # it, so pruning degrades to a first-scan rebuild instead of
            # a load failure.
            vc = store._decoders.get(name)
            rows, card = int(zm["partitions"]), int(zm["cardinality"])
            if vc is None or rows != n_parts or card != vc.cardinality:
                continue
            bits = np.frombuffer(zm["bits"], dtype=np.uint8)
            if bits.size * 8 < rows * card:
                continue
            store._zone_maps[name] = (
                np.unpackbits(bits, count=rows * card)
                .reshape(rows, card)
                .astype(bool)
            )
        return store
