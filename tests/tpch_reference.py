"""Naive decode-then-aggregate oracle for the differential harness.

Pure numpy/python on raw table columns — deliberately independent of
``repro.api.plan`` (no shared factorization, packing, or accumulator
code), so a bug in the code-space aggregation machinery cannot cancel
out in the reference.  ``tests/test_aggregate_join.py`` and
``tests/test_tpch_queries.py`` compare every executor path against
these functions value-for-value.
"""

import numpy as np


def agg_name(func, column):
    """Result key for one aggregate, mirroring ``AggSpec.name()``."""
    return "count" if column is None else f"{func}({column})"


def ref_group_aggregate(columns, group_by, aggregates, sel=None):
    """Group-aggregate a plain column dict the slow, obvious way.

    ``aggregates`` is a sequence of ``(func, column)`` pairs (``column
    is None`` for count).  Returns ``(groups, aggs)`` dicts shaped like
    :class:`repro.api.plan.AggregateResult` — one array per group-by
    column and per aggregate, rows sorted by group-value tuple.  An
    empty ``group_by`` is a global aggregate: exactly one group.
    ``sel`` restricts to a boolean row mask (predicate oracle).
    """
    cols = {c: np.asarray(v) for c, v in columns.items()}
    some = next(iter(cols.values()), None)
    n = 0 if some is None else len(some)
    idx = np.arange(n) if sel is None else np.flatnonzero(np.asarray(sel))
    if group_by:
        per_col = [cols[c][idx].tolist() for c in group_by]
        tuples = list(zip(*per_col)) if len(idx) else []
    else:
        tuples = [()] * len(idx)
    state = {}
    for row, g in zip(idx.tolist(), tuples):
        accs = state.get(g)
        if accs is None:
            accs = state[g] = [None] * len(aggregates)
        for j, (func, column) in enumerate(aggregates):
            if column is None:
                accs[j] = 1 if accs[j] is None else accs[j] + 1
                continue
            v = cols[column][row]
            v = float(v) if np.asarray(v).dtype.kind == "f" else int(v)
            if accs[j] is None:
                accs[j] = v
            elif func == "sum":
                accs[j] = accs[j] + v
            elif func == "min":
                accs[j] = min(accs[j], v)
            elif func == "max":
                accs[j] = max(accs[j], v)
            else:
                raise ValueError(func)
    order = sorted(state)
    groups = {
        c: np.asarray([g[i] for g in order]) for i, c in enumerate(group_by)
    }
    aggs = {
        agg_name(func, column): np.asarray([state[g][j] for g in order])
        for j, (func, column) in enumerate(aggregates)
    }
    return groups, aggs


def ref_join_mask(left_keys, key_fn, right_keys):
    """Boolean mask of left rows whose mapped key exists on the right
    (the inner key-equi join semantics), via a plain python set."""
    left_keys = np.asarray(left_keys, dtype=np.int64)
    probe = left_keys if key_fn is None else np.asarray(
        key_fn(left_keys), dtype=np.int64
    )
    right = set(np.asarray(right_keys, dtype=np.int64).tolist())
    return np.asarray([int(k) in right for k in probe.tolist()], dtype=bool)


def norm_strings(arr):
    """Normalize a (possibly bytes-decoded) string column for
    comparison: everything through ``astype(str)``."""
    arr = np.asarray(arr)
    if arr.dtype.kind in ("S", "U", "O"):
        return arr.astype(str)
    return arr


def assert_aggregate_equal(result, ref_groups, ref_aggs):
    """Value-identity between an :class:`AggregateResult` and the
    oracle's ``(groups, aggs)`` — same group rows, same order, same
    aggregate values (string group labels normalized)."""
    assert set(result.groups) == set(ref_groups)
    assert set(result.aggregates) == set(ref_aggs)
    for c, want in ref_groups.items():
        np.testing.assert_array_equal(
            norm_strings(result.groups[c]), norm_strings(want), err_msg=c
        )
    for name, want in ref_aggs.items():
        np.testing.assert_array_equal(
            np.asarray(result.aggregates[name]), want, err_msg=name
        )
