"""Shared fixtures. NOTE: no XLA device-count overrides here — smoke
tests and benches must see the single real CPU device; only the dry-run
(separate process) pins 512 virtual devices."""

import numpy as np
import pytest

from repro.core import DeepMappingConfig, DeepMappingStore, Table
from repro.core.trainer import TrainConfig


def make_periodic_table(n=1500, period=16, cards=(5, 3), stride=2, seed=0):
    """High-correlation table in the paper's sense: values are periodic
    along the key dimension (like TPC-DS customer_demographics)."""
    keys = np.arange(0, n * stride, stride, dtype=np.int64)
    cols = {}
    for i, c in enumerate(cards):
        cols[f"col{i}"] = ((keys // (period * (i + 1))) % c).astype(np.int32)
    return Table(keys=keys, columns=cols)


def make_random_table(n=1000, cards=(7,), key_space=None, seed=0):
    """Low-correlation table: values are independent of keys (like the
    TPC-H OrderStatus sample — Pearson ~1e-4)."""
    rng = np.random.default_rng(seed)
    space = key_space or (4 * n)
    keys = rng.permutation(space)[:n].astype(np.int64)
    cols = {
        f"col{i}": rng.integers(0, c, size=n).astype(np.int32)
        for i, c in enumerate(cards)
    }
    return Table(keys=keys, columns=cols)


@pytest.fixture(scope="session")
def small_store():
    """One trained store shared by read-only tests (training is the
    expensive part; mutating tests build their own)."""
    table = make_periodic_table()
    cfg = DeepMappingConfig(
        shared=(96, 96),
        private=(32,),
        train=TrainConfig(epochs=40, batch_size=512),
    )
    return table, DeepMappingStore.build(table, cfg)
