"""Quickstart: compress a table into a DeepMapping hybrid structure,
query it through the streaming plan API — projection and value-
predicate pushdown, cross-store federation — modify, and measure Eq. 1.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --shards 4 --policy range

Every store (single, sharded, baselines, federated) implements the
same ``MappingStore`` protocol; ``repro.build`` picks single-vs-sharded
from the cluster config and ``repro.open`` re-loads whatever was saved.
"""

import argparse
import os
import tempfile

import numpy as np

import repro
from repro.core import DeepMappingConfig, Table
from repro.core.trainer import TrainConfig


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--shards", type=int, default=1,
                    help="number of cluster shards (1 = single store)")
    ap.add_argument("--policy", default="range", choices=("range", "hash"),
                    help="cluster partition policy (with --shards > 1)")
    ap.add_argument("--telemetry-dir", default=None, metavar="DIR",
                    help="where to write metrics.prom / metrics.json / "
                         "trace.json (default: a temp dir)")
    args = ap.parse_args()

    # A small relation: order_id -> (status, priority).  Values follow a
    # periodic pattern along the key (the paper's high-correlation regime).
    n = 20_000
    keys = np.arange(n, dtype=np.int64) * 2  # sparse even keys
    table = Table(
        keys=keys,
        columns={
            "status": np.array(["F", "O", "P"])[(keys // 64) % 3],
            "priority": ((keys // 128) % 5).astype(np.int32),
        },
    )

    cfg = DeepMappingConfig(
        shared=(128, 64),
        private=(16,),
        codec="zstd",
        train=TrainConfig(epochs=40, batch_size=4096),
    )
    cluster = None
    if args.shards > 1:
        from repro.cluster import ClusterConfig

        cluster = ClusterConfig(num_shards=args.shards, policy=args.policy)
    store = repro.build(table, cfg, cluster=cluster, verbose=True)
    if args.shards > 1:
        print(f"  {store.num_shards} {args.policy} shards, "
              f"rows/shard: {[s.num_rows for s in store.shards]}")

    print("\n-- Eq.1 accounting ------------------------------")
    for k, v in store.size_breakdown().items():
        print(f"  {k:>16}: {v:,} bytes")
    print(f"  compression ratio: {store.compression_ratio():.4f}")
    print(f"  memorized by model: {store.memorized_fraction():.1%}")

    print("\n-- Point query (Algorithm 1) ---------------------")
    q = np.array([0, 2, 128, 3, 999_999], dtype=np.int64)
    res = store.query().where_keys(q).execute()
    for i, k in enumerate(q):
        if res.exists[i]:
            print(f"  key {k}: status={res.values['status'][i]} "
                  f"priority={res.values['priority'][i]}")
        else:
            print(f"  key {k}: NULL (existence bitvector)")
    print(f"  plan: {' -> '.join(res.explain.plan)}")

    print("\n-- Projection pushdown ---------------------------")
    res = store.query().select("status").where_keys(table.keys[:1000]).execute()
    print(f"  heads evaluated: {res.explain.heads_evaluated}, "
          f"skipped: {res.explain.heads_skipped}")
    print(f"  columns decoded: {res.explain.columns_decoded}, "
          f"skipped: {res.explain.columns_skipped}")

    print("\n-- Range query (§IV-E) ---------------------------")
    res = store.query().select("priority").where_range(0, 1024).execute()
    print(f"  [0, 1024) -> {res.keys.shape[0]} rows, "
          f"priorities {sorted(set(res.values['priority'].tolist()))}")

    print("\n-- Value-predicate pushdown (.where) -------------")
    # Pushed below decode: the predicate evaluates on argmax codes, so
    # non-matching rows are never decoded (see rows_decoded evidence).
    res = (
        store.query().select("priority")
        .where("status", "==", "F").where("priority", ">=", 3)
        .scan().execute()
    )
    ref = (
        store.query().select("priority")
        .where("status", "==", "F").where("priority", ">=", 3)
        .pushdown(False).scan().execute()  # post-hoc reference filter
    )
    assert res.keys.tobytes() == ref.keys.tobytes()
    print(f"  status=='F' AND priority>=3 -> {res.keys.shape[0]} rows")
    print(f"  pushdown decoded {res.explain.rows_decoded}/{res.explain.num_keys} "
          f"rows; post-hoc decoded {ref.explain.rows_decoded}")
    print("  operators: " + " -> ".join(
        f"{o.name}[{o.rows_in}->{o.rows_out}]" for o in res.explain.operators
    ))

    print("\n-- Plan cache (adaptive execution) ---------------")
    # Repeated plans reuse the materialized key stream + compiled
    # predicate code tables; mutations invalidate via the store's
    # mutation version (DESIGN.md §Adaptive execution).
    repeated = lambda: (  # noqa: E731
        store.query().where("status", "==", "F").scan().execute()
    )
    cold, warm = repeated(), repeated()
    print(f"  first run:  plan_cache={cold.explain.plan_cache!r}")
    print(f"  second run: plan_cache={warm.explain.plan_cache!r} "
          f"(key stream + code tables resident)")
    assert warm.keys.tobytes() == cold.keys.tobytes()

    print("\n-- Modifications (Algorithms 3-5) ----------------")
    store.insert(
        np.array([10**6], dtype=np.int64),
        {"status": np.array(["X"]), "priority": np.array([9], np.int32)},
    )
    v, e = store.lookup(np.array([10**6]))
    print(f"  inserted unseen category: status={v['status'][0]} (exists={e[0]})")
    store.update(
        np.array([0], dtype=np.int64),
        {"status": np.array(["P"]), "priority": np.array([4], np.int32)},
    )
    v, _ = store.lookup(np.array([0]))
    print(f"  updated key 0: status={v['status'][0]} priority={v['priority'][0]}")
    store.delete(np.array([2], dtype=np.int64))
    _, e = store.lookup(np.array([2]))
    print(f"  deleted key 2: exists={e[0]}")

    print("\n-- save / repro.open round-trip ------------------")
    path = os.path.join(tempfile.mkdtemp(), "store")
    store.save(path)
    restored = repro.open(path)
    res = restored.query().where_keys(np.array([0, 2, 10**6])).execute()
    print(f"  reopened as {type(restored).__name__}; "
          f"exists={res.exists.tolist()}")

    print("\n-- Cross-store federation ------------------------")
    # Two stores over disjoint key spaces behind one plan surface: the
    # DeepMapping store keeps its keys, a HashStore replica owns a
    # second key range starting at 10**7.
    from repro.api import FederatedStore
    from repro.baselines import HashStore

    hi_keys = np.arange(10**7, 10**7 + 5000, 2, dtype=np.int64)
    hi_table = Table(
        keys=hi_keys,
        columns={
            "status": np.array(["F", "O", "P"])[(hi_keys // 64) % 3],
            "priority": ((hi_keys // 128) % 5).astype(np.int32),
        },
    )
    fed = FederatedStore(
        [store, HashStore.build(hi_table)],
        mode="partition",
        boundaries=[10**7],
    )
    res = fed.query().where("priority", "==", 4).where_range(0, 10**8).execute()
    print(f"  {fed.num_rows:,} rows across {len(fed.members)} member stores")
    print(f"  priority==4 over both members -> {res.keys.shape[0]} rows "
          f"(min key {res.keys.min()}, max key {res.keys.max()})")
    print(f"  plan: {' -> '.join(res.explain.plan[:3])} ...")

    if args.shards > 1:
        print("\n-- Per-shard lazy retrain ------------------------")
        print(f"  dirty shards after modifications: {store.dirty_shards() or 'none'}")
        print(f"  range scatter [0, 1000): shards "
              f"{store.partitioner.shards_for_range(0, 1000).tolist()}")

    print("\n-- Observability (metrics + trace export) --------")
    # Everything above already recorded into the process-global metrics
    # registry and span tracer (always on).  Run one more multi-morsel
    # scan — small morsels force many dispatch/collect rounds, so the
    # executor's pipelining (device infer of morsel i+1 overlapping the
    # host half of morsel i) is visible in the trace — then export all
    # three sinks from this one process.
    from repro import obs

    store.query().morsel(2048).scan().execute()
    out_dir = args.telemetry_dir or tempfile.mkdtemp(prefix="deepmap_obs_")
    prom = obs.write_prometheus(os.path.join(out_dir, "metrics.prom"))
    snap = obs.write_json_snapshot(os.path.join(out_dir, "metrics.json"))
    trace = obs.write_chrome_trace(os.path.join(out_dir, "trace.json"))
    morsels = obs.registry().get("deepmap_executor_morsels_total")
    plan_lat = obs.registry().get("deepmap_executor_plan_seconds")
    print(f"  morsels executed: {int(sum(v for _, v in morsels.items()))}; "
          f"scan plan p50 {plan_lat.quantile(0.5, kind='scan')*1e3:.1f} ms")
    dispatch = obs.tracer().spans("infer_dispatch", track="device")
    collect = obs.tracer().spans("collect", track="host")
    overlaps = sum(
        1 for d in dispatch for c in collect
        if d.start < c.start and c.end < d.end
    )
    print(f"  trace: {len(dispatch)} device dispatch spans, "
          f"{len(collect)} host collect spans, "
          f"{overlaps} pipelined overlaps (dispatch i+1 covers collect i)")
    print(f"  Prometheus text:   {prom}")
    print(f"  JSON snapshot:     {snap}")
    print(f"  Chrome trace:      {trace}  (open at https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
