"""Shared scatter/gather primitives (numpy-only, store-agnostic).

One request batch fans out to several owners — shards behind a
``ShardRouter``, members behind a ``FederatedStore`` — and results come
back in request order.  Both layers used to carry private copies of
the same two nontrivial idioms; they live here once:

* :func:`group_runs` — stable group-by of positions per owner id
  (argsort + run cuts; one contiguous group per owner, ascending id);
* :func:`gather_parts` — reassemble per-owner ``(values, exists)``
  into request order via concatenate + inverse permutation, which
  sidesteps per-column dtype preallocation (owners may disagree on
  e.g. unicode widths of decode maps);
* :class:`LazyFanoutPool` — the lazily-created, double-checked-locked
  thread pool both fan-out stages (per-shard lookup visits, per-member
  federation collects) run on.

This module must stay dependency-light (numpy only): ``cluster``
imports it through ``api``, and ``api`` must never import the store
packages back.
"""

from __future__ import annotations

import os
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np


class LazyFanoutPool:
    """Lazy, long-lived thread pool for scatter/gather fan-out stages.

    Shared by the sharded store (per-shard lookup visits) and the
    federation (per-member morsel collects): owners are independent
    stores whose host halves release the GIL inside compiled inference,
    so visits genuinely overlap.  Creation is double-checked-locked —
    two first-queries racing must not each build (and leak) a pool —
    and deferred until the first parallel call, so serial workloads
    never spawn threads.
    """

    def __init__(self, max_workers: Optional[int], name: str):
        """Remember the sizing policy; no threads start until needed.

        ``max_workers=None`` defers to ``min(owners, cpu_count)`` at
        the first :meth:`map` call (``owners`` passed there).
        """
        self._max_workers = max_workers
        self._name = name
        self._pool: Optional[ThreadPoolExecutor] = None  # guarded-by: _lock
        self._lock = threading.Lock()

    def map(self, fn, items, owners: Optional[int] = None) -> List:
        """``[fn(x) for x in items]`` on the pool (created on first
        use, sized by the configured cap or ``min(owners, cpus)``)."""
        pool = self._pool
        if pool is None:
            with self._lock:
                if self._pool is None:
                    workers = self._max_workers or min(
                        owners or (os.cpu_count() or 4), os.cpu_count() or 4
                    )
                    self._pool = ThreadPoolExecutor(
                        max_workers=max(1, workers),
                        thread_name_prefix=self._name,
                    )
                pool = self._pool
        return list(pool.map(fn, items))

    def close(self) -> None:
        """Shut down the worker threads (idempotent; in-flight work
        finishes first).  Without this, pool threads live until
        interpreter exit.  A later :meth:`map` lazily re-creates the
        pool, so closing a store twice — or using it again after an
        explicit close — stays safe."""
        with self._lock:
            pool = self._pool
            self._pool = None
        if pool is not None:
            pool.shutdown(wait=True)

    def __enter__(self) -> "LazyFanoutPool":
        """Context-manager entry (no threads start here)."""
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        """Shut the pool down on scope exit."""
        self.close()


def group_runs(ids: np.ndarray) -> List[Tuple[int, np.ndarray]]:
    """Group request positions by owner id -> ``[(id, positions), ...]``
    (ascending id; owners with no positions are skipped; empty input
    -> empty list).  ``positions`` index the original request array."""
    ids = np.asarray(ids)
    if ids.size == 0:
        return []
    order = np.argsort(ids, kind="stable")
    sorted_ids = ids[order]
    cut = np.flatnonzero(np.diff(sorted_ids)) + 1
    starts = np.concatenate([[0], cut])
    ends = np.concatenate([cut, [sorted_ids.size]])
    return [
        (int(sorted_ids[s]), order[s:e]) for s, e in zip(starts, ends)
    ]


def gather_parts(
    n: int,
    parts: Iterable[Tuple[np.ndarray, Dict[str, np.ndarray], np.ndarray]],
) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    """Reassemble per-owner ``(positions, values, exists)`` parts into
    request order over ``n`` rows -> ``(values, exists)``."""
    parts = list(parts)
    exists = np.zeros(n, dtype=bool)
    if not parts:
        return {}, exists
    positions = np.concatenate([p for p, _, _ in parts])
    inv = np.empty(n, dtype=np.int64)
    inv[positions] = np.arange(positions.size)
    values: Dict[str, np.ndarray] = {}
    for name in parts[0][1]:
        values[name] = np.concatenate([v[name] for _, v, _ in parts])[inv]
    exists[positions] = np.concatenate([e for _, _, e in parts])
    return values, exists


def gather_parts_partial(
    n: int,
    parts: Iterable[Tuple[np.ndarray, Dict[str, np.ndarray], np.ndarray]],
) -> Tuple[Dict[str, np.ndarray], np.ndarray, np.ndarray]:
    """:func:`gather_parts` for a *partial* cover: some request
    positions may have no owning part (their owner failed terminally).

    Returns ``(values, exists, covered)`` where ``covered`` marks the
    positions an owner actually answered for.  Uncovered rows carry a
    placeholder value (a healthy row's bytes — never uninitialised
    memory) and ``exists=False``; callers must report them as
    *unreachable*, not *absent* (``ExplainStats.keys_unresolved``).

    Requires at least one part: with zero healthy owners there are no
    column dtypes to build placeholders from, and a fully-failed morsel
    must surface as :class:`~repro.fault.errors.OwnerFailure` upstream.
    """
    parts = list(parts)
    if not parts:
        raise ValueError(
            "gather_parts_partial needs >= 1 healthy part; a fully-failed "
            "morsel must raise OwnerFailure instead of degrading"
        )
    exists = np.zeros(n, dtype=bool)
    covered = np.zeros(n, dtype=bool)
    positions = np.concatenate([p for p, _, _ in parts])
    covered[positions] = True
    # Uncovered rows map to concatenated index 0 — a real (healthy) row
    # used purely as a typed placeholder, masked by exists=False.
    inv = np.zeros(n, dtype=np.int64)
    inv[positions] = np.arange(positions.size)
    values: Dict[str, np.ndarray] = {}
    for name in parts[0][1]:
        values[name] = np.concatenate([v[name] for _, v, _ in parts])[inv]
    exists[positions] = np.concatenate([e for _, _, e in parts])
    return values, exists, covered
