"""granite-3-2b — dense GQA [hf:ibm-granite/granite-3.0-2b-base].
40L d_model=2048 32H (kv=8, head 64) d_ff=8192 vocab=49155."""

from repro.configs.base import ArchSpec, register
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49155,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family="dense",
    num_layers=3,
    d_model=32,
    num_heads=4,
    num_kv_heads=2,
    head_dim=8,
    d_ff=64,
    vocab_size=128,
    tie_embeddings=True,
    dtype="float32",
    remat="none",
)

SPEC = register(
    ArchSpec(
        arch_id="granite-3-2b",
        config=CONFIG,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        notes="Pure full attention -> long_500k skipped.",
    )
)
