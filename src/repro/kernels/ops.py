"""Public jit'd wrappers around the Pallas kernels.

Responsibilities: MXU-alignment padding (zero-padding is exact for
dense+ReLU chains: padded inputs are zero, padded weight rows/cols are
zero, ReLU(0)=0 propagates), batch tiling, the VMEM residency budget
check, and interpret-mode selection (interpret on non-TPU backends so
the same tests run everywhere).
"""

from __future__ import annotations

import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.model import MLPSpec
from repro.kernels import bitvector as bv_kernel
from repro.kernels import fused_mlp as fm_kernel

LANE = 128          # MXU lane width
DEFAULT_TILE_N = 256
#: Conservative default residency cap — fits every supported TPU
#: generation; interpret-mode backends keep it so tier selection on CPU
#: matches the smallest real target (see :func:`vmem_budget_bytes`).
VMEM_BUDGET_BYTES = 12 * 1024 * 1024

#: Per-backend residency caps.  Real TPUs have >=128 MiB VMEM per core,
#: so the resident-weights strategy can afford a larger cap there;
#: backends that run the kernels in interpret mode (cpu/gpu, see
#: ``_auto_interpret``) stay on the conservative default so CI exercises
#: the same eligibility ladder a small TPU would take.
_BACKEND_VMEM_BUDGETS = {"tpu": 64 * 1024 * 1024}


def vmem_budget_bytes() -> int:
    """Resolved VMEM residency budget in bytes.

    Resolution order: ``REPRO_VMEM_BUDGET`` (env, always wins — also the
    hook the boundary tests use to pin exact budgets), then the
    per-backend table, then :data:`VMEM_BUDGET_BYTES`.  Re-read on every
    call: it is consulted at engine construction / eligibility time, not
    in the hot loop.
    """
    env = os.environ.get("REPRO_VMEM_BUDGET", "").strip()
    if env:
        return max(int(env), 1)
    return _BACKEND_VMEM_BUDGETS.get(jax.default_backend(), VMEM_BUDGET_BYTES)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad2(w: jnp.ndarray) -> jnp.ndarray:
    return jnp.pad(
        w,
        ((0, _round_up(w.shape[0], LANE) - w.shape[0]),
         (0, _round_up(w.shape[1], LANE) - w.shape[1])),
    )


def _pad_flat_weights(params: Dict, spec: MLPSpec) -> Tuple[Tuple[jnp.ndarray, ...], int]:
    """Flatten + pad weights in kernel plan order. Returns (flat, bytes)."""
    flat = []

    def add(layer):
        w, b = layer["w"], layer["b"]
        if w.ndim == 3:
            base_pad = _round_up(w.shape[1], LANE)
            h_pad = _round_up(w.shape[2], LANE)
            wp = jnp.pad(w, ((0, 0), (0, base_pad - w.shape[1]), (0, h_pad - w.shape[2])))
        else:
            wp = _pad2(w)
            h_pad = wp.shape[1]
        bp = jnp.pad(b, (0, h_pad - b.shape[0]))
        flat.append(wp.astype(jnp.float32))
        flat.append(bp.astype(jnp.float32))

    for layer in params["shared"]:
        add(layer)
    for t in spec.tasks:
        for layer in params["heads"][t]["hidden"]:
            add(layer)
        add(params["heads"][t]["out"])
    nbytes = sum(int(np.prod(x.shape)) * 4 for x in flat)
    return tuple(flat), nbytes


#: Public alias — the inference engine caches this call's result per
#: task subset so the hot path never re-pads (see repro.core.inference).
pad_flat_weights = _pad_flat_weights


def padded_weight_parts(spec: MLPSpec) -> Tuple[int, Dict[str, int]]:
    """Shape-only padded byte counts, split ``(trunk_bytes, {task:
    head_bytes})`` — the streaming page planner budgets the shared trunk
    once per page and packs heads greedily against the remainder."""

    def dense(in_dim: int, out_dim: int, embed: bool) -> int:
        o = _round_up(out_dim, LANE)
        if embed:  # rank-3 (width, base_pad, h_pad) + bias
            return spec.width * _round_up(spec.base, LANE) * o + o
        return _round_up(in_dim, LANE) * o + o

    trunk_total = 0
    d = None
    for h in spec.shared:
        trunk_total += dense(d or 0, h, embed=d is None)
        d = h
    trunk = d
    priv, cards = spec.private_map, spec.card_map
    heads: Dict[str, int] = {}
    for t in spec.tasks:
        d = trunk
        total = 0
        for h in priv[t]:
            total += dense(d or 0, h, embed=d is None)
            d = h
        total += dense(d or 0, cards[t], embed=d is None)
        heads[t] = total * 4  # fp32
    return trunk_total * 4, heads


def padded_weight_bytes(spec: MLPSpec) -> int:
    """Byte count :func:`pad_flat_weights` would produce, from shapes
    alone — eligibility/budget decisions must not materialize (and
    cache) a padded device copy that the chosen path never uses."""
    trunk, heads = padded_weight_parts(spec)
    return trunk + sum(heads.values())


def plan_head_pages(
    spec: MLPSpec,
    tile_n: int,
    words_bytes: int = 0,
    budget: Optional[int] = None,
) -> Optional[Tuple[Tuple[str, ...], ...]]:
    """Partition ``spec.tasks`` into consecutive head groups ("pages")
    that each fit the VMEM budget — the ``fused_streamed`` tier runs one
    :func:`fused_lookup` per page, so a model whose padded weights
    exceed the budget still takes the fused kernel instead of jit.

    Every page pays the shared trunk + activation overhead (the trunk
    is re-sent and recomputed per page); page 0 additionally reserves
    ``words_bytes`` for the resident existence words, because the
    existence test rides with the first page by contract.  Returns a
    tuple of task tuples covering ``spec.tasks`` in canonical order, or
    None when even a single head cannot fit on a fresh page — the
    caller falls back to the jit ladder.
    """
    budget = vmem_budget_bytes() if budget is None else int(budget)
    trunk_b, head_b = padded_weight_parts(spec)
    act = activation_bytes(spec, tile_n)
    pages: list = []
    cur: list = []
    used = trunk_b + act + int(words_bytes)
    for t in spec.tasks:
        hb = head_b[t]
        if cur and used + hb > budget:
            pages.append(tuple(cur))
            cur = []
            used = trunk_b + act
        if used + hb > budget:
            return None
        cur.append(t)
        used += hb
    pages.append(tuple(cur))
    return tuple(pages)


def activation_bytes(spec: MLPSpec, tile_n: int) -> int:
    """Per-tile activation VMEM footprint (with ~double buffering)."""
    widths = [spec.feature_dim, *spec.shared]
    for t, sizes in spec.private:
        widths.extend(sizes)
    return tile_n * _round_up(max(widths), LANE) * 4 * 3


def check_vmem_budget(
    params: Dict, spec: MLPSpec, tile_n: int, extra_bytes: int = 0
) -> None:
    """Raise if weights + activations (+ ``extra_bytes``, e.g. the fused
    lookup kernel's resident existence words) exceed the VMEM cap."""
    budget = vmem_budget_bytes()
    _, wbytes = _pad_flat_weights(params, spec)
    total = wbytes + activation_bytes(spec, tile_n) + extra_bytes
    if total > budget:
        raise ValueError(
            f"model too large for VMEM-resident fused kernel "
            f"({total / 2**20:.1f} MiB > "
            f"{budget / 2**20:.1f} MiB); use the streamed or jnp path"
        )


def _prep(digits: jnp.ndarray, tile_n: int) -> Tuple[jnp.ndarray, int]:
    n = digits.shape[0]
    n_pad = _round_up(max(n, tile_n), tile_n)
    dp = jnp.pad(digits.astype(jnp.int32), ((0, n_pad - n), (0, 0)))
    return dp, n


def fused_mlp_logits(
    params: Dict,
    spec: MLPSpec,
    digits: jnp.ndarray,
    tile_n: int = DEFAULT_TILE_N,
    interpret: Optional[bool] = None,
) -> Dict[str, jnp.ndarray]:
    """Per-task logits via the fused kernel. digits (n, width) int."""
    check_vmem_budget(params, spec, tile_n)
    flat, _ = _pad_flat_weights(params, spec)
    dp, n = _prep(digits, tile_n)
    cards = spec.card_map
    card_pads = tuple((t, _round_up(cards[t], LANE)) for t in spec.tasks)
    outs = fm_kernel.fused_mlp_call(
        dp, flat, spec, tile_n, _round_up(spec.base, LANE), card_pads,
        emit_codes=False, interpret=_auto_interpret(interpret),
    )
    return {t: o[:n, : cards[t]] for t, o in zip(spec.tasks, outs)}


def fused_mlp_codes(
    params: Dict,
    spec: MLPSpec,
    digits: jnp.ndarray,
    tile_n: int = DEFAULT_TILE_N,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """(n, num_tasks) int32 argmax codes — Algorithm 1's inference output.
    The argmax happens in-kernel: HBM sees one int32 per task per row."""
    check_vmem_budget(params, spec, tile_n)
    flat, _ = _pad_flat_weights(params, spec)
    dp, n = _prep(digits, tile_n)
    cards = spec.card_map
    card_pads = tuple((t, _round_up(cards[t], LANE)) for t in spec.tasks)
    outs = fm_kernel.fused_mlp_call(
        dp, flat, spec, tile_n, _round_up(spec.base, LANE), card_pads,
        emit_codes=True, interpret=_auto_interpret(interpret),
    )
    return jnp.concatenate([o[:n] for o in outs], axis=1)


def fused_lookup(
    flat_weights: Tuple[jnp.ndarray, ...],
    spec: MLPSpec,
    keys_i32: jnp.ndarray,
    pos_ops: jnp.ndarray,
    words32: Optional[jnp.ndarray],
    capacity: int,
    tile_n: int = DEFAULT_TILE_N,
    interpret: Optional[bool] = None,
    with_exists: bool = True,
    pred_tables: Tuple[jnp.ndarray, ...] = (),
    pred_tasks: Tuple[int, ...] = (),
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray], Optional[jnp.ndarray]]:
    """One-round-trip lookup kernel call: padded int32 keys in,
    ``(codes (N_pad, m) int32, exists (N_pad,) int32 | None,
    match (N_pad,) int32 | None)`` out.

    Unlike :func:`fused_mlp_codes` this takes ALREADY-padded device
    weights (the engine's per-task-subset cache), a device-resident
    ``pos_ops``/``words32``, and an already bucket-padded key batch —
    the wrapper adds no per-call host work.  Caller slices padding off.

    ``with_exists=False`` drops the words input and existence output —
    the ``fused_streamed`` tier uses it for pages past the first, whose
    VMEM budget goes entirely to head weights.  ``pred_tables`` ships
    per-predicate boolean code tables (as padded int32 vectors) into the
    kernel; ``pred_tasks[j]`` names the head (index into ``spec.tasks``)
    whose code indexes table ``j``.  Match bits are the AND of the
    existence bit and every table lookup — predicate filtering requires
    ``with_exists``.
    """
    if keys_i32.shape[0] % tile_n != 0:
        raise ValueError(
            f"padded batch size {keys_i32.shape[0]} must be a multiple of "
            f"tile_n={tile_n}"
        )
    if pred_tables and not with_exists:
        raise ValueError("in-kernel predicate filtering requires with_exists")
    return fm_kernel.fused_lookup_call(
        keys_i32, pos_ops, words32 if with_exists else None,
        tuple(flat_weights), spec, tile_n,
        _round_up(spec.base, LANE), int(capacity), _auto_interpret(interpret),
        pred_tables=tuple(pred_tables), pred_tasks=tuple(pred_tasks),
        with_exists=with_exists,
    )


def bitvector_test(
    words64: np.ndarray,
    keys: jnp.ndarray,
    tile_n: int = 1024,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Existence bits for int keys against a packed uint64 word array
    (the BitVector runtime form). Returns (n,) bool.

    The kernel works on uint32 words.  The 64->32 split happens host-side
    (``.view``) — JAX without x64 would silently TRUNCATE uint64 on
    ``jnp.asarray``, losing every odd 32-bit word.
    """
    words32 = jnp.asarray(np.asarray(words64, dtype=np.uint64).view(np.uint32))
    n = keys.shape[0]
    n_pad = _round_up(max(n, tile_n), tile_n)
    kp = jnp.pad(keys.astype(jnp.int32), (0, n_pad - n))
    bits = bv_kernel.bitvector_call(
        kp, words32, tile_n, _auto_interpret(interpret)
    )
    return bits[:n].astype(bool)
