"""Shared neural building blocks (pure JAX, pytree params).

Init functions take an ``rng`` and return param subtrees; ``stacked_init``
vmaps an init over the layer dimension so blocks can run under
``lax.scan`` (one compilation regardless of depth — essential for the
dry-run's compile-time budget at 61-layer/512-device scale).
"""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp


def _dtype(name: str):
    return jnp.dtype(name)


# -- initializers -------------------------------------------------------------


def dense_init(rng, in_dim: int, out_dim: int, dtype, bias: bool = False) -> Dict:
    scale = jnp.sqrt(1.0 / in_dim)
    p = {"w": (jax.random.normal(rng, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense(p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embedding_init(rng, vocab: int, dim: int, dtype) -> Dict:
    return {"table": (jax.random.normal(rng, (vocab, dim), jnp.float32) * 0.02).astype(dtype)}


def embed(p: Dict, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], ids, axis=0)


def rmsnorm_init(dim: int, dtype) -> Dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p: Dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def stacked_init(init_fn: Callable, rng, num: int, *args, **kwargs):
    """vmap an init over a leading layer dimension for scan."""
    rngs = jax.random.split(rng, num)
    return jax.vmap(lambda r: init_fn(r, *args, **kwargs))(rngs)


# -- rotary embeddings ----------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- gated MLP -------------------------------------------------------------------


def mlp_init(rng, d_model: int, d_ff: int, dtype) -> Dict:
    r1, r2, r3 = jax.random.split(rng, 3)
    return {
        "gate": dense_init(r1, d_model, d_ff, dtype),
        "up": dense_init(r2, d_model, d_ff, dtype),
        "down": dense_init(r3, d_ff, d_model, dtype),
    }


def mlp(p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    return dense(p["down"], jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x))


# -- misc -------------------------------------------------------------------------


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray, mask=None) -> jnp.ndarray:
    """Mean token CE in fp32; logits (..., V), labels (...) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - picked
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
