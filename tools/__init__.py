"""Repo maintenance tooling (stdlib-only; not shipped with the package)."""
