"""Mesh factories (assignment-mandated shapes).

``make_production_mesh`` is a FUNCTION (never module-level state) so
importing this module touches no jax device state.  Single-pod: 16x16
(data, model) = 256 chips.  Multi-pod: 2x16x16 (pod, data, model) = 512
chips; the ``pod`` axis composes with ``data`` for batch/FSDP sharding
and carries the hierarchical (DCN) gradient reduction.
"""

from __future__ import annotations

from typing import Tuple

import jax


def _make_mesh(shape, axes):
    """Auto-typed mesh on any jax version: older releases predate
    ``jax.sharding.AxisType`` and treat every axis as Auto already."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return _make_mesh((data, model), ("data", "model"))


def mesh_axes(mesh) -> Tuple[Tuple[str, ...], str]:
    """Returns (batch/FSDP axes, tensor axis) for a mesh from this module."""
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data"), "model"
    return ("data",), "model"
