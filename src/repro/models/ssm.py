"""Recurrent sequence mixers: RWKV6 ("Finch", data-dependent decay
linear attention) and RG-LRU (RecurrentGemma's gated linear recurrence
with temporal conv).  Both carry O(1)-per-token state — these are the
families that make the 500k-context decode cell feasible.

Training/prefill run the recurrences as ``lax.scan`` over sequence
CHUNKS with intra-chunk parallel math (chunked WKV), so sequential
depth is S/chunk, not S.  Decode is a single recurrence step against a
carried state.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


# --------------------------------------------------------------------------
# RWKV6 (arXiv:2404.05892) — time-mix with data-dependent decay + channel-mix
# --------------------------------------------------------------------------


def rwkv_init(rng, cfg) -> Dict:
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    r = jax.random.split(rng, 10)
    dt = jnp.dtype(cfg.dtype)
    return {
        # time-mix lerp coefficients (per-channel, data-independent part)
        "mu_r": jnp.full((d,), 0.5, dt),
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_v": jnp.full((d,), 0.5, dt),
        "mu_w": jnp.full((d,), 0.5, dt),
        "mu_g": jnp.full((d,), 0.5, dt),
        "wr": L.dense_init(r[0], d, d, dt),
        "wk": L.dense_init(r[1], d, d, dt),
        "wv": L.dense_init(r[2], d, d, dt),
        "wg": L.dense_init(r[3], d, d, dt),
        "ww": L.dense_init(r[4], d, d, dt),           # data-dependent decay
        "w_bias": jnp.full((d,), -6.0, dt),            # decay bias (slow default)
        "u": (0.1 * jax.random.normal(r[5], (H, dh), jnp.float32)).astype(dt),  # bonus
        "wo": L.dense_init(r[6], d, d, dt),
        "ln_x": L.rmsnorm_init(d, dt),
    }


def _rwkv_chunk_step(state, inputs, H, dh):
    """One sequence-chunk of the WKV6 recurrence, sequential inside the
    chunk (per-token state update — faithful to data-dependent decay)."""

    def token_step(s, tok):
        r, k, v, w, u = tok  # (H,dh) each except u (H,dh)
        # s: (H, dh, dh) state.  out = r · (s + u ⊙ k v^T); s' = diag(w) s + k v^T
        kv = k[:, :, None] * v[:, None, :]            # (H,dh,dh)
        out = jnp.einsum("hi,hij->hj", r, s + u[:, :, None] * kv)
        s = w[:, :, None] * s + kv
        return s, out

    return jax.lax.scan(token_step, state, inputs)


def rwkv_apply(
    p: Dict, cfg, x: jnp.ndarray, state: Optional[Dict] = None
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Time-mix block.  x (B,S,d).  state carries (wkv (B,H,dh,dh),
    x_prev (B,d)) for decode; None for train (zero init)."""
    B, S, d = x.shape
    H = cfg.num_heads
    dh = d // H

    x_prev = (
        state["x_prev"][:, None, :]
        if state is not None
        else jnp.zeros((B, 1, d), x.dtype)
    )
    xs = jnp.concatenate([x_prev, x[:, :-1, :]], axis=1)  # token shift

    def mix(mu):
        return x + (xs - x) * mu

    r = L.dense(p["wr"], mix(p["mu_r"])).reshape(B, S, H, dh)
    k = L.dense(p["wk"], mix(p["mu_k"])).reshape(B, S, H, dh)
    v = L.dense(p["wv"], mix(p["mu_v"])).reshape(B, S, H, dh)
    g = jax.nn.silu(L.dense(p["wg"], mix(p["mu_g"])))
    # data-dependent decay in (0,1): exp(-exp(...)) parameterization
    w = jnp.exp(-jnp.exp((L.dense(p["ww"], mix(p["mu_w"])) + p["w_bias"]).astype(jnp.float32)))
    w = w.reshape(B, S, H, dh).astype(jnp.float32)

    s0 = (
        state["wkv"].astype(jnp.float32)
        if state is not None
        else jnp.zeros((B, H, dh, dh), jnp.float32)
    )
    seq_first = lambda t: t.astype(jnp.float32).transpose(1, 0, 2, 3)  # (S,B,H,dh)
    inputs = (seq_first(r), seq_first(k), seq_first(v), seq_first(w),
              jnp.broadcast_to(p["u"].astype(jnp.float32), (S, B, H, dh)))

    def batch_scan(s0b, rb, kb, vb, wb, ub):
        return _rwkv_chunk_step(s0b, (rb, kb, vb, wb, ub), H, dh)

    sT, out = jax.vmap(batch_scan, in_axes=(0, 1, 1, 1, 1, 1), out_axes=(0, 1))(
        s0, *inputs
    )
    out = out.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)  # (S,B,H,dh)->(B,S,d)
    out = L.rmsnorm(p["ln_x"], out, cfg.norm_eps) * g
    out = L.dense(p["wo"], out)
    new_state = {"wkv": sT.astype(x.dtype), "x_prev": x[:, -1, :]} if state is not None else None
    return out, new_state


def rwkv_channel_init(rng, cfg) -> Dict:
    d, dff = cfg.d_model, cfg.d_ff
    r = jax.random.split(rng, 2)
    dt = jnp.dtype(cfg.dtype)
    return {
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_r": jnp.full((d,), 0.5, dt),
        "wk": L.dense_init(r[0], d, dff, dt),
        "wv": L.dense_init(r[1], dff, d, dt),
        "wr": L.dense_init(jax.random.fold_in(r[0], 1), d, d, dt),
    }


def rwkv_channel_apply(
    p: Dict, cfg, x: jnp.ndarray, x_prev: Optional[jnp.ndarray] = None
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    B, S, d = x.shape
    xp = x_prev[:, None, :] if x_prev is not None else jnp.zeros((B, 1, d), x.dtype)
    xs = jnp.concatenate([xp, x[:, :-1, :]], axis=1)
    k = L.dense(p["wk"], x + (xs - x) * p["mu_k"])
    kv = L.dense(p["wv"], jnp.square(jax.nn.relu(k)))
    rgate = jax.nn.sigmoid(L.dense(p["wr"], x + (xs - x) * p["mu_r"]))
    out = rgate * kv
    return out, (x[:, -1, :] if x_prev is not None else None)


def rwkv_init_state(cfg, batch: int, dtype=None) -> Dict:
    dt = jnp.dtype(dtype or cfg.dtype)
    H = cfg.num_heads
    dh = cfg.d_model // H
    return {
        "wkv": jnp.zeros((batch, H, dh, dh), dt),
        "x_prev": jnp.zeros((batch, cfg.d_model), dt),
        "x_prev_ffn": jnp.zeros((batch, cfg.d_model), dt),
    }


# --------------------------------------------------------------------------
# RG-LRU (RecurrentGemma, arXiv:2402.19427) — gated linear recurrence
# --------------------------------------------------------------------------

_C_RGLRU = 8.0  # paper's fixed scaling constant


def rglru_init(rng, cfg) -> Dict:
    d = cfg.d_model
    rd = cfg.rglru_dim or d
    r = jax.random.split(rng, 6)
    dt = jnp.dtype(cfg.dtype)
    return {
        "w_in_x": L.dense_init(r[0], d, rd, dt),      # recurrence branch
        "w_in_g": L.dense_init(r[1], d, rd, dt),      # gate branch (GeLU)
        "conv_w": (0.1 * jax.random.normal(r[2], (cfg.conv_width, rd), jnp.float32)).astype(dt),
        "conv_b": jnp.zeros((rd,), dt),
        "wa_gate": L.dense_init(r[3], rd, rd, dt),    # recurrence gate r_t
        "wx_gate": L.dense_init(r[4], rd, rd, dt),    # input gate i_t
        "a_param": jnp.full((rd,), -4.0, jnp.float32),  # Λ logit (slow decay)
        "w_out": L.dense_init(r[5], rd, d, dt),
    }


def rglru_apply(
    p: Dict, cfg, x: jnp.ndarray, state: Optional[Dict] = None
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Recurrent block: in-proj -> temporal conv -> RG-LRU -> gated out.

    state = {'h': (B,rd), 'conv': (B,conv_width-1,rd)} for decode."""
    B, S, d = x.shape
    rd = cfg.rglru_dim or d
    cw = cfg.conv_width

    xb = L.dense(p["w_in_x"], x)                       # (B,S,rd)
    gate_branch = jax.nn.gelu(L.dense(p["w_in_g"], x))

    # temporal conv (causal, width cw)
    if state is not None:
        ctx = jnp.concatenate([state["conv"], xb], axis=1)
    else:
        ctx = jnp.concatenate([jnp.zeros((B, cw - 1, rd), xb.dtype), xb], axis=1)
    conv = sum(ctx[:, i : i + S, :] * p["conv_w"][i] for i in range(cw)) + p["conv_b"]

    # RG-LRU gates
    r_t = jax.nn.sigmoid(L.dense(p["wa_gate"], conv))
    i_t = jax.nn.sigmoid(L.dense(p["wx_gate"], conv))
    log_a = -_C_RGLRU * jax.nn.softplus(p["a_param"]) * r_t.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated_x = (conv * i_t).astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))

    h0 = (
        state["h"].astype(jnp.float32)
        if state is not None
        else jnp.zeros((B, rd), jnp.float32)
    )

    def step(h, inp):
        a_t, bx_t = inp
        h = a_t * h + bx_t
        return h, h

    bx = (beta * gated_x).transpose(1, 0, 2)  # (S,B,rd)
    hT, hs = jax.lax.scan(step, h0, (a.transpose(1, 0, 2), bx))
    hs = hs.transpose(1, 0, 2).astype(x.dtype)  # (B,S,rd)

    out = L.dense(p["w_out"], hs * gate_branch)
    new_state = (
        {"h": hT.astype(x.dtype), "conv": ctx[:, S : S + cw - 1, :] if S >= cw - 1 else ctx[:, -(cw - 1):, :]}
        if state is not None
        else None
    )
    return out, new_state


def rglru_init_state(cfg, batch: int, dtype=None) -> Dict:
    dt = jnp.dtype(dtype or cfg.dtype)
    rd = cfg.rglru_dim or cfg.d_model
    return {
        "h": jnp.zeros((batch, rd), dt),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, rd), dt),
    }
