"""Plan executor vs. legacy direct-lookup path (ISSUE 2 tentpole
validation): does the unified query API cost anything on the hot path,
and what do its two optimizations buy?

Sections reported per dataset:

* ``point``    — legacy ``store.lookup`` vs ``query().where_keys``
                 (the plan layer should be noise);
* ``project``  — full-column lookup vs 1-of-N projection pushdown
                 (unselected private heads + decode skipped);
* ``range``    — legacy ``range_lookup`` vs ``query().where_range``;
* ``scan``     — full scan through the plan executor;
* ``sharded``  — serial shard visits vs the thread-pool fan-out stage
                 on a K-shard cluster.

    PYTHONPATH=src:benchmarks python benchmarks/bench_query.py
"""

from __future__ import annotations

import argparse
import time
from typing import List

import numpy as np

from benchmarks import common as C
from repro.cluster import ClusterConfig, ShardedDeepMappingStore
from repro.core import DeepMappingConfig
from repro.core.trainer import TrainConfig
from repro.storage import MemoryPool

SHARDED_CFG = DeepMappingConfig(
    shared=(128, 64),
    private=(16,),
    codec="zstd",
    partition_bytes=64 * 1024,
    train=TrainConfig(epochs=30, batch_size=4096),
)


def _median(fn, repeats: int) -> float:
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def run(
    datasets=("tpcds_customer_demographics",),
    batches=(1000, 10_000),
    num_shards: int = 4,
    repeats: int = 5,
) -> List[dict]:
    rows = []
    for dataset in datasets:
        table = C.DATASETS[dataset]()
        store = C.dm_store(dataset, "DM-Z", pool=MemoryPool(1 << 30))
        cols = tuple(store.columns)
        one_col = (cols[0],)

        for batch in batches:
            keys = C.query_keys(table, batch)
            # warm both paths (jit compile, pool fill) before timing
            store.lookup(keys)
            store.query().where_keys(keys).execute()

            legacy = _median(lambda: store.lookup(keys), repeats)
            plan = _median(
                lambda: store.query().where_keys(keys).execute(), repeats
            )
            C.emit(f"query.point.legacy.{dataset}.{batch}", legacy * 1e6,
                   f"{batch / legacy:.0f} keys/s")
            C.emit(f"query.point.plan.{dataset}.{batch}", plan * 1e6,
                   f"{batch / plan:.0f} keys/s; overhead "
                   f"{100 * (plan - legacy) / legacy:+.1f}%")

            if len(cols) > 1:
                store.query().select(*one_col).where_keys(keys).execute()
                proj = _median(
                    lambda: store.query().select(*one_col).where_keys(keys).execute(),
                    repeats,
                )
                res = store.query().select(*one_col).where_keys(keys).execute()
                C.emit(
                    f"query.project.{dataset}.{batch}", proj * 1e6,
                    f"1/{len(cols)} cols; heads skipped "
                    f"{len(res.explain.heads_skipped)}; "
                    f"speedup {legacy / proj:.2f}x",
                )
            rows.append({"dataset": dataset, "batch": batch,
                         "legacy_s": legacy, "plan_s": plan})

        # range + scan
        lo, hi = int(table.keys.min()), int(np.percentile(table.keys, 10))
        store.range_lookup(lo, hi)
        r_legacy = _median(lambda: store.range_lookup(lo, hi), repeats)
        r_plan = _median(
            lambda: store.query().where_range(lo, hi).execute(), repeats
        )
        n_range = store.query().where_range(lo, hi).execute().keys.shape[0]
        C.emit(f"query.range.legacy.{dataset}", r_legacy * 1e6, f"{n_range} rows")
        C.emit(f"query.range.plan.{dataset}", r_plan * 1e6,
               f"overhead {100 * (r_plan - r_legacy) / r_legacy:+.1f}%")
        s_plan = _median(lambda: store.query().scan().execute(), max(1, repeats // 2))
        C.emit(f"query.scan.plan.{dataset}", s_plan * 1e6,
               f"{table.num_rows / s_plan:.0f} rows/s")

        # sharded: serial visits vs thread-pool fan-out
        sharded = ShardedDeepMappingStore.build(
            table, SHARDED_CFG, ClusterConfig(num_shards=num_shards),
            pool=MemoryPool(1 << 30),
        )
        big = C.query_keys(table, max(batches))
        sharded.query().where_keys(big).fanout(False).execute()
        sharded.query().where_keys(big).fanout(True).execute()
        sync_s = _median(
            lambda: sharded.query().where_keys(big).fanout(False).execute(), repeats
        )
        async_s = _median(
            lambda: sharded.query().where_keys(big).fanout(True).execute(), repeats
        )
        C.emit(f"query.sharded.sync.{dataset}.k{num_shards}", sync_s * 1e6,
               f"{len(big) / sync_s:.0f} keys/s")
        C.emit(f"query.sharded.fanout.{dataset}.k{num_shards}", async_s * 1e6,
               f"{len(big) / async_s:.0f} keys/s; speedup {sync_s / async_s:.2f}x")
        rows.append({"dataset": dataset, "sync_s": sync_s, "async_s": async_s})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--datasets", nargs="*", default=["tpcds_customer_demographics"])
    ap.add_argument("--batches", nargs="*", type=int, default=[1000, 10_000])
    ap.add_argument("--shards", type=int, default=4)
    args = ap.parse_args()
    run(datasets=args.datasets, batches=tuple(args.batches),
        num_shards=args.shards)


if __name__ == "__main__":
    main()
