"""Per-architecture configs (assigned pool) + the paper's own config.

Importing this package registers every :class:`~repro.configs.base.ArchSpec`;
use ``get_arch("<id>")`` / ``list_archs()``.
"""

from repro.configs.base import SHAPES, ArchSpec, get_arch, list_archs  # noqa: F401

# side-effect registration — one module per assigned architecture
from repro.configs import deepseek_v3_671b  # noqa: F401
from repro.configs import gemma3_1b  # noqa: F401
from repro.configs import granite3_2b  # noqa: F401
from repro.configs import llama4_scout_17b  # noqa: F401
from repro.configs import phi3_vision_4_2b  # noqa: F401
from repro.configs import qwen2_7b  # noqa: F401
from repro.configs import recurrentgemma_2b  # noqa: F401
from repro.configs import rwkv6_7b  # noqa: F401
from repro.configs import seamless_m4t_medium  # noqa: F401
from repro.configs import tinyllama_1_1b  # noqa: F401
