"""Rule engine for deeplint: file model, suppressions, baseline, reporters.

Everything here is stdlib-only.  The engine parses every ``.py`` file under
the requested paths into a :class:`SourceModule`, bundles them into a
:class:`Project`, and hands the project to each rule module (see
:mod:`tools.deeplint.rules`).  Rules return :class:`Finding` objects; the
engine then drops findings that are suppressed inline
(``# deeplint: ignore[rule-id]``) or grandfathered in the baseline file.

Conventions recognised in source comments (documented in DESIGN.md):

``# deeplint: ignore[rule-a,rule-b]``
    Suppress those rules on this line (or, on a comment-only line, on the
    next line).  ``ignore`` without brackets suppresses every rule.
``# guarded-by: <lock>``
    On an attribute-initialisation line: the attribute may only be mutated
    while ``self.<lock>`` is held (rule ``lock-discipline``).
``# holds-lock: <lock>``
    On a ``def`` line: the method is only ever called with ``self.<lock>``
    already held, so its body counts as a locked region.
``# deeplint: collect-point``
    On a ``def`` line: sanctioned host/device synchronisation point for
    rule ``device-sync``.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

SUPPRESS_RE = re.compile(r"#\s*deeplint:\s*ignore(?:\[([a-zA-Z0-9_,\- ]+)\])?")
GUARDED_BY_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][A-Za-z0-9_]*)")
HOLDS_LOCK_RE = re.compile(r"#\s*holds-lock:\s*([A-Za-z_][A-Za-z0-9_]*)")
COLLECT_POINT_RE = re.compile(r"#\s*deeplint:\s*collect-point")

ALL_MARKER = "*"


@dataclasses.dataclass(frozen=True)
class Finding:
    """A single rule violation at a source location."""

    rule: str
    path: str  # repo-relative, forward slashes
    line: int
    col: int
    message: str

    def key(self) -> Tuple[str, str, str]:
        """Line-number-insensitive identity used for baseline matching."""
        return (self.rule, self.path, self.message)

    def to_json(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


class SourceModule:
    """One parsed ``.py`` file plus its comment-level annotations."""

    def __init__(self, path: Path, rel_path: str, text: str) -> None:
        self.path = path
        self.rel_path = rel_path
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=rel_path)
        self.module = derive_module_name(path)
        # line -> set of suppressed rule ids (ALL_MARKER means all rules)
        self.suppressions: Dict[int, Set[str]] = {}
        self._scan_suppressions()

    def _scan_suppressions(self) -> None:
        for idx, raw in enumerate(self.lines, start=1):
            m = SUPPRESS_RE.search(raw)
            if not m:
                continue
            ids = (
                {part.strip() for part in m.group(1).split(",") if part.strip()}
                if m.group(1)
                else {ALL_MARKER}
            )
            target = idx
            # A comment-only line suppresses the next source line.
            if raw.lstrip().startswith("#"):
                target = idx + 1
            self.suppressions.setdefault(target, set()).update(ids)

    def is_suppressed(self, rule: str, line: int) -> bool:
        ids = self.suppressions.get(line)
        if not ids:
            return False
        return ALL_MARKER in ids or rule in ids

    def line_comment(self, lineno: int) -> str:
        """Raw text of the given 1-based line ('' when out of range)."""
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def guarded_by(self, lineno: int) -> Optional[str]:
        m = GUARDED_BY_RE.search(self.line_comment(lineno))
        return m.group(1) if m else None

    def holds_lock(self, node: ast.AST) -> Optional[str]:
        """``# holds-lock:`` marker on a def line or the line above it."""
        lineno = getattr(node, "lineno", 0)
        for cand in (lineno, lineno - 1):
            m = HOLDS_LOCK_RE.search(self.line_comment(cand))
            if m:
                return m.group(1)
        return None

    def is_collect_point(self, node: ast.AST) -> bool:
        lineno = getattr(node, "lineno", 0)
        for cand in (lineno, lineno - 1):
            if COLLECT_POINT_RE.search(self.line_comment(cand)):
                return True
        return False

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        return Finding(
            rule=rule,
            path=self.rel_path,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0),
            message=message,
        )


def derive_module_name(path: Path) -> Optional[str]:
    """Map a file path to a dotted module name rooted at ``repro``.

    Works for ``src/repro/...`` layouts and for test fixtures that create a
    bare ``repro/...`` tree.  Returns ``None`` when the file is not inside a
    ``repro`` package (layering checks are skipped for such files).
    """
    parts = list(path.parts)
    for i in range(len(parts) - 1, -1, -1):
        if parts[i] == "repro":
            dotted = parts[i:]
            break
    else:
        return None
    name = ".".join(dotted)
    if name.endswith(".py"):
        name = name[: -len(".py")]
    if name.endswith(".__init__"):
        name = name[: -len(".__init__")]
    return name


@dataclasses.dataclass
class ClassInfo:
    """Project-level class record used by hierarchy-aware rules."""

    qualname: str  # "<module>.<ClassName>" (module may be "" for orphans)
    node: ast.ClassDef
    source: "SourceModule"
    base_names: List[str]  # unresolved base expressions as dotted strings


class Project:
    """All parsed modules plus shared cross-module lookup tables."""

    def __init__(self, modules: Sequence[SourceModule]) -> None:
        self.modules = list(modules)
        self.by_module: Dict[str, SourceModule] = {
            m.module: m for m in self.modules if m.module
        }
        self._classes: Optional[Dict[str, ClassInfo]] = None

    # -- class hierarchy ---------------------------------------------------
    @property
    def classes(self) -> Dict[str, ClassInfo]:
        """Qualified-name -> ClassInfo for every top-level class."""
        if self._classes is None:
            table: Dict[str, ClassInfo] = {}
            for src in self.modules:
                prefix = (src.module + ".") if src.module else src.rel_path + ":"
                for node in src.tree.body:
                    if isinstance(node, ast.ClassDef):
                        table[prefix + node.name] = ClassInfo(
                            qualname=prefix + node.name,
                            node=node,
                            source=src,
                            base_names=[_dotted(b) for b in node.bases],
                        )
            self._classes = table
        return self._classes

    def resolve_base(self, info: ClassInfo, base: str) -> Optional[str]:
        """Resolve a base-class expression to a qualified class name."""
        if not base:
            return None
        src = info.source
        head, _, rest = base.partition(".")
        imports = module_import_map(src)
        if head in imports:
            target = imports[head] + ("." + rest if rest else "")
        elif not rest:
            # Same-file base: use the same prefix the class table uses.
            prefix = (src.module + ".") if src.module else src.rel_path + ":"
            target = prefix + head
        else:
            target = base
        return target if target in self.classes else None

    def ancestors(self, qualname: str) -> Set[str]:
        """All resolved ancestor qualnames of a class (excluding itself)."""
        seen: Set[str] = set()
        frontier = [qualname]
        while frontier:
            cur = frontier.pop()
            info = self.classes.get(cur)
            if info is None:
                continue
            for base in info.base_names:
                resolved = self.resolve_base(info, base)
                if resolved and resolved not in seen:
                    seen.add(resolved)
                    frontier.append(resolved)
        return seen

    def subclasses_of(self, root_qualname: str) -> List[ClassInfo]:
        """Every class whose ancestor set contains ``root_qualname``."""
        return [
            info
            for qual, info in sorted(self.classes.items())
            if root_qualname in self.ancestors(qual)
        ]


def _dotted(node: ast.AST) -> str:
    """Render Name/Attribute chains as a dotted string ('' otherwise)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        head = _dotted(node.value)
        return head + "." + node.attr if head else ""
    return ""


def module_import_map(src: SourceModule) -> Dict[str, str]:
    """Local name -> imported dotted target for a module's imports."""
    table: Dict[str, str] = {}
    for node in ast.walk(src.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                table[alias.asname or alias.name.split(".")[0]] = (
                    alias.name if alias.asname else alias.name.split(".")[0]
                )
                if alias.asname:
                    table[alias.asname] = alias.name
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for alias in node.names:
                table[alias.asname or alias.name] = node.module + "." + alias.name
    return table


# -- collection ------------------------------------------------------------

def collect_modules(
    paths: Sequence[Path], root: Path
) -> Tuple[List[SourceModule], List[str]]:
    """Parse every .py under ``paths``; returns (modules, parse_errors)."""
    files: List[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    modules: List[SourceModule] = []
    errors: List[str] = []
    for f in files:
        try:
            rel = f.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = f.as_posix()
        try:
            text = f.read_text(encoding="utf-8")
            modules.append(SourceModule(f, rel, text))
        except (OSError, SyntaxError, ValueError) as exc:
            errors.append(f"{rel}: {exc}")
    return modules, errors


# -- baseline --------------------------------------------------------------

def load_baseline(path: Path) -> Dict[Tuple[str, str, str], int]:
    """Baseline file -> multiset of finding keys (key -> count)."""
    if not path.exists():
        return {}
    data = json.loads(path.read_text(encoding="utf-8"))
    counts: Dict[Tuple[str, str, str], int] = {}
    for entry in data.get("findings", []):
        key = (entry["rule"], entry["path"], entry["message"])
        counts[key] = counts.get(key, 0) + 1
    return counts


def write_baseline(path: Path, findings: Sequence[Finding], date: str) -> None:
    payload = {
        "version": 1,
        "updated": date,
        "policy": (
            "Grandfathered findings only. New code must be clean; entries "
            "here need a dated justification and should trend to zero."
        ),
        "findings": [
            {"rule": f.rule, "path": f.path, "message": f.message}
            for f in sorted(findings, key=lambda f: (f.rule, f.path, f.message))
        ],
    }
    path.write_text(json.dumps(payload, indent=2) + "\n", encoding="utf-8")


# -- run -------------------------------------------------------------------

def run(
    paths: Sequence[Path],
    root: Path,
    rules: Optional[Sequence[object]] = None,
) -> Tuple[List[Finding], List[Finding], List[str]]:
    """Run rules over ``paths``.

    Returns ``(findings, suppressed, parse_errors)`` where *findings* is the
    post-suppression list (baseline filtering is the caller's concern).
    """
    from tools.deeplint.rules import ALL_RULES

    modules, errors = collect_modules(paths, root)
    project = Project(modules)
    by_rel = {m.rel_path: m for m in modules}

    active = list(rules) if rules is not None else list(ALL_RULES)
    raw: List[Finding] = []
    for rule_mod in active:
        raw.extend(rule_mod.check(project))

    findings: List[Finding] = []
    suppressed: List[Finding] = []
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.rule)):
        src = by_rel.get(f.path)
        if src is not None and src.is_suppressed(f.rule, f.line):
            suppressed.append(f)
        else:
            findings.append(f)
    return findings, suppressed, errors


def apply_baseline(
    findings: Sequence[Finding], baseline: Dict[Tuple[str, str, str], int]
) -> Tuple[List[Finding], List[Finding]]:
    """Split findings into (new, baselined) using multiset matching."""
    remaining = dict(baseline)
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:
        key = f.key()
        if remaining.get(key, 0) > 0:
            remaining[key] -= 1
            old.append(f)
        else:
            new.append(f)
    return new, old


# -- reporters -------------------------------------------------------------

def render_text(
    findings: Sequence[Finding],
    baselined: Sequence[Finding],
    suppressed_count: int,
    file_count: int,
) -> str:
    lines = []
    for f in findings:
        lines.append(f"{f.path}:{f.line}:{f.col}: [{f.rule}] {f.message}")
    lines.append(
        f"deeplint: {len(findings)} finding(s) in {file_count} file(s) "
        f"({len(baselined)} baselined, {suppressed_count} suppressed)"
    )
    return "\n".join(lines)


def render_json(
    findings: Sequence[Finding],
    baselined: Sequence[Finding],
    suppressed_count: int,
    file_count: int,
    paths: Sequence[str],
) -> str:
    from tools.deeplint.rules import ALL_RULES

    payload = {
        "tool": "deeplint",
        "version": 1,
        "paths": list(paths),
        "rules": {mod.RULE_ID: mod.SUMMARY for mod in ALL_RULES},
        "findings": [f.to_json() for f in findings],
        "baselined": [f.to_json() for f in baselined],
        "summary": {
            "findings": len(findings),
            "baselined": len(baselined),
            "suppressed": suppressed_count,
            "files": file_count,
        },
    }
    return json.dumps(payload, indent=2)
