"""MHAS search space: weight bank + masked child forward (paper §IV-C1).

The space is the paper's DAG per tree node: up to ``max_layers`` shared
hidden layers and up to ``max_layers`` private hidden layers per task,
with each hidden layer's width chosen from ``layer_sizes`` (paper
searches [100, 2000]).  A sampled sub-graph =
``(shared_depth, shared_sizes[..], {task: (depth, sizes[..])})``.

Weight sharing à la ENAS: one bank of ``(max_width, max_width)``
matrices; a child with width ``s`` uses the first ``s`` columns (mask)
and — because the previous activation is zero beyond its own width —
effectively the first ``prev`` rows.  Masked evaluation is exactly
equivalent to slicing, but keeps every child the same XLA shape: the
whole search compiles ONCE.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.model import MLPSpec


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    base: int
    width: int                       # key digit positions
    tasks: Tuple[str, ...]
    out_cards: Tuple[int, ...]       # aligned with tasks
    layer_sizes: Tuple[int, ...] = (100, 200, 400, 800, 1200, 1600, 2000)
    max_layers: int = 2              # paper §V-A6: up to 2 shared + 2 private

    @property
    def feature_dim(self) -> int:
        return self.base * self.width

    @property
    def max_width(self) -> int:
        return max(self.feature_dim, max(self.layer_sizes))

    @property
    def num_size_choices(self) -> int:
        return len(self.layer_sizes)

    @property
    def num_decisions(self) -> int:
        """Controller sequence length: (depth + max_layers sizes) for the
        trunk and for each task."""
        return (1 + self.max_layers) * (1 + len(self.tasks))

    def decision_kinds(self) -> np.ndarray:
        """0 = depth decision (choices: max_layers+1), 1 = size decision."""
        block = [0] + [1] * self.max_layers
        return np.asarray(block * (1 + len(self.tasks)), dtype=np.int32)

    # ------------------------------------------------------------- bank init
    def init_bank(self, seed: int = 0, dtype=jnp.float32) -> Dict:
        mw = self.max_width
        key = jax.random.PRNGKey(seed)
        n_mats = self.max_layers * (1 + len(self.tasks)) + len(self.tasks)
        keys = iter(jax.random.split(key, n_mats))

        def mat(out_dim):
            k = next(keys)
            w = jax.random.normal(k, (mw, out_dim), dtype) * jnp.sqrt(2.0 / mw)
            return {"w": w, "b": jnp.zeros((out_dim,), dtype)}

        bank = {
            "trunk": [mat(mw) for _ in range(self.max_layers)],
            "heads": {
                t: {
                    "hidden": [mat(mw) for _ in range(self.max_layers)],
                    "out": mat(card),
                }
                for t, card in zip(self.tasks, self.out_cards)
            },
        }
        return bank

    # -------------------------------------------------------- arch encoding
    def tokens_to_arch(self, tokens: np.ndarray) -> Dict:
        """Controller token sequence -> arch dict with ACTUAL widths."""
        tokens = np.asarray(tokens)
        sizes = np.asarray(self.layer_sizes, dtype=np.int32)
        ml = self.max_layers
        arch = {
            "trunk_depth": int(tokens[0]),
            "trunk_sizes": sizes[tokens[1 : 1 + ml] % len(sizes)],
        }
        off = 1 + ml
        heads = {}
        for t in self.tasks:
            heads[t] = {
                "depth": int(tokens[off]),
                "sizes": sizes[tokens[off + 1 : off + 1 + ml] % len(sizes)],
            }
            off += 1 + ml
        arch["heads"] = heads
        return arch

    def arch_arrays(self, arch: Dict) -> Dict[str, jnp.ndarray]:
        """Arch dict -> fixed-shape device arrays for the masked forward."""
        T = len(self.tasks)
        ml = self.max_layers
        head_depth = np.zeros((T,), np.int32)
        head_sizes = np.zeros((T, ml), np.int32)
        for i, t in enumerate(self.tasks):
            head_depth[i] = arch["heads"][t]["depth"]
            head_sizes[i] = arch["heads"][t]["sizes"]
        return {
            "trunk_depth": jnp.asarray(arch["trunk_depth"], jnp.int32),
            "trunk_sizes": jnp.asarray(np.asarray(arch["trunk_sizes"], np.int32)),
            "head_depth": jnp.asarray(head_depth),
            "head_sizes": jnp.asarray(head_sizes),
        }

    # ------------------------------------------------------- masked forward
    def forward(self, bank: Dict, onehot_pad: jnp.ndarray, aa: Dict) -> Dict[str, jnp.ndarray]:
        """Masked child forward. ``onehot_pad`` is (n, max_width) — the
        one-hot key features zero-padded to bank width."""
        mw = self.max_width
        iota = jnp.arange(mw)

        def masked_layer(layer, x, active, size):
            h = jax.nn.relu(x @ layer["w"] + layer["b"])
            h = h * (iota < size)[None, :]
            return jnp.where(active, h, x)

        x = onehot_pad
        for i in range(self.max_layers):
            x = masked_layer(
                bank["trunk"][i], x, aa["trunk_depth"] > i, aa["trunk_sizes"][i]
            )
        out = {}
        for ti, t in enumerate(self.tasks):
            h = x
            head = bank["heads"][t]
            for j in range(self.max_layers):
                h = masked_layer(
                    head["hidden"][j], h, aa["head_depth"][ti] > j, aa["head_sizes"][ti, j]
                )
            out[t] = h @ head["out"]["w"] + head["out"]["b"]
        return out

    # ------------------------------------------------- child model metadata
    def child_num_params(self, arch: Dict) -> int:
        """Parameter count of the SLICED child (what Eq. 1's size(M) sees)."""
        total = 0
        d = self.feature_dim
        for i in range(arch["trunk_depth"]):
            h = int(arch["trunk_sizes"][i])
            total += d * h + h
            d = h
        trunk = d
        for t, card in zip(self.tasks, self.out_cards):
            d = trunk
            hd = arch["heads"][t]
            for j in range(hd["depth"]):
                h = int(hd["sizes"][j])
                total += d * h + h
                d = h
            total += d * card + card
        return total

    def child_spec(self, arch: Dict) -> MLPSpec:
        return MLPSpec(
            base=self.base,
            width=self.width,
            shared=tuple(int(s) for s in arch["trunk_sizes"][: arch["trunk_depth"]]),
            private={
                t: tuple(
                    int(s)
                    for s in arch["heads"][t]["sizes"][: arch["heads"][t]["depth"]]
                )
                for t in self.tasks
            },
            out_cards={t: c for t, c in zip(self.tasks, self.out_cards)},
        )

    def extract_child_params(self, bank: Dict, arch: Dict) -> Dict:
        """Slice the bank into a standalone ``repro.core.model`` param tree
        (used to warm-start the post-search fine-tune — the ENAS payoff)."""
        bank = jax.tree.map(np.asarray, bank)
        fd = self.feature_dim

        def first_from_input(w, b, out_dim):
            return {
                "w": jnp.asarray(w[:fd, :out_dim].reshape(self.width, self.base, out_dim)),
                "b": jnp.asarray(b[:out_dim]),
            }

        def dense(w, b, in_dim, out_dim):
            return {"w": jnp.asarray(w[:in_dim, :out_dim]), "b": jnp.asarray(b[:out_dim])}

        params: Dict = {"shared": [], "heads": {}}
        d = None
        for i in range(arch["trunk_depth"]):
            h = int(arch["trunk_sizes"][i])
            layer = bank["trunk"][i]
            if d is None:
                params["shared"].append(first_from_input(layer["w"], layer["b"], h))
            else:
                params["shared"].append(dense(layer["w"], layer["b"], d, h))
            d = h
        trunk_dim = d
        for t, card in zip(self.tasks, self.out_cards):
            hd = arch["heads"][t]
            head = {"hidden": [], "out": None}
            cur = trunk_dim
            for j in range(hd["depth"]):
                h = int(hd["sizes"][j])
                layer = bank["heads"][t]["hidden"][j]
                if cur is None:
                    head["hidden"].append(first_from_input(layer["w"], layer["b"], h))
                else:
                    head["hidden"].append(dense(layer["w"], layer["b"], cur, h))
                cur = h
            out_layer = bank["heads"][t]["out"]
            if cur is None:
                head["out"] = first_from_input(out_layer["w"], out_layer["b"], card)
            else:
                head["out"] = dense(out_layer["w"], out_layer["b"], cur, card)
            params["heads"][t] = head
        return params
