"""Structured failure values for the fault-tolerant query path.

A failing owner (shard, federation member, device engine, artifact
file) must surface as *data* the caller can reason about, not as a
bare traceback that kills the plan.  :class:`OwnerError` is that
value: which owner failed, at which site, after how many attempts,
and why.  ``on_error('raise')`` plans wrap the captured errors in
:class:`OwnerFailure`; ``on_error('partial')`` plans carry them as
``ExplainStats.owners_failed`` evidence instead.

:class:`IntegrityError` is the checksum-verification failure raised by
the persistence layer — a corrupt artifact must fail loudly at load
time, never serve wrong values.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


class InjectedFault(RuntimeError):
    """Deterministic failure raised by the injection harness
    (:mod:`repro.fault.injection`) at an instrumented site."""

    def __init__(self, site: str, owner: str | None = None):
        self.site = site
        self.owner = owner
        super().__init__(
            f"injected fault at site {site!r}"
            + (f" (owner {owner!r})" if owner is not None else "")
        )


class IntegrityError(ValueError):
    """A persisted artifact failed checksum verification (or is
    missing/truncated).  Raised at load time so corruption can never
    silently serve wrong values."""


@dataclasses.dataclass(frozen=True)
class OwnerError:
    """One owner's terminal failure, after retries — a value, not an
    exception, so partial-mode plans can carry it as evidence.

    ``owner`` names the failing unit (``"shard:3"``, ``"member:1"``,
    ``"store"``); ``site`` the instrumented failure site; ``attempts``
    how many tries were made (0 = the owner was already quarantined and
    never tried); ``error_type``/``message`` describe the last cause;
    ``deadline_exceeded`` marks a per-owner deadline kill rather than a
    raised error.
    """

    owner: str
    site: str
    attempts: int
    error_type: str
    message: str
    deadline_exceeded: bool = False

    def describe(self) -> str:
        """Compact one-line form for explain output and error text."""
        why = "deadline exceeded" if self.deadline_exceeded else self.error_type
        return f"{self.owner}@{self.site}: {why} after {self.attempts} attempt(s)"


class OwnerFailure(RuntimeError):
    """Raised by ``on_error('raise')`` plans when one or more owners
    failed terminally.  Carries the structured :class:`OwnerError`
    values on ``.owners`` so callers can still inspect what failed."""

    def __init__(self, owners: Tuple[OwnerError, ...]):
        self.owners = tuple(owners)
        detail = "; ".join(o.describe() for o in self.owners)
        super().__init__(
            f"{len(self.owners)} owner(s) failed: {detail} — use "
            f"Query.on_error('partial') for degraded results"
        )
