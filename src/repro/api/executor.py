"""Streaming operator-pipeline executor.

Every plan compiles to the same small operator IR regardless of store
type:

    KeySource -> (ShardScatter) -> Infer -> Exist -> AuxMerge
              -> Filter -> Decode -> Gather

and is executed **morsel-at-a-time**: the key stream is cut into
chunks — sized adaptively between morsels from per-operator timings
(:func:`next_morsel_rows`), or fixed by ``Query.morsel(n)`` — and each
chunk's device work is enqueued through the store's
``_dispatch_lookup`` hook before the previous chunk's host half
(existence fallback, aux merge, predicate filter, decode) is collected
— so model-backed stores overlap device inference of morsel *i+1* with
host work of morsel *i*.  :func:`execute_plans` extends the same
window **across plans**: while plan A's host half runs, plans B..'s
device work keeps executing, which is where multi-plan pipelines win
over running ``execute_plan`` in a loop.  Plan compilation artifacts
(key-source materializations, projection subsets, predicate code
tables) come from the store's per-store
:class:`~repro.api.cache.PlanCache`, so repeated plans skip the
existence-index scan and predicate compiles entirely.

The store-specific middle stages stay behind the two protocol hooks
(``_dispatch_lookup``/``_collect_lookup``); the sharded store
implements scatter + thread-pool fan-out inside its hook, the
federated store per-member scatter — the executor stays oblivious.

Value predicates (``Query.where``) ride the same hooks: with
``plan.pushdown`` (default) the store evaluates them below decode
(code-level on DeepMapping stores — non-matching rows are never
decoded; overlay-view on baselines) and returns a ``match`` selector;
with ``pushdown=False`` the executor runs the **post-hoc reference
path** — decode everything, filter on decoded values — kept for
byte-equality testing and as the semantics oracle.

Aggregates (``Query.group_by(...).agg(...)``) run **below decode** by
default: each morsel's collect calls the store's
``_collect_aggregate`` hook, which returns a partial aggregation state
instead of decoded rows (code-space on DeepMapping stores — a
count-only group-by decodes zero rows; fan-out merge on
sharded/federated stores; decode-then-aggregate on baselines), and the
Gather operator merges states instead of concatenating columns.  With
``pushdown=False`` the morsels flow as decoded rows and the gatherer
aggregates them post-hoc — the decode-then-aggregate reference the
differential suite compares against.  Key-equi joins (``Query.join``)
wrap the finalized morsel stream: each morsel's candidate rows probe
the right store's existence index through the same
dispatch/collect hooks (with a dispatch-ahead window, so right-store
inference overlaps left host work), non-matching rows are dropped via
the ``match`` selector, and right columns scatter into the morsel.

Plan execution defaults the sharded fan-out ON (overlapping per-shard
inference — ``Query.fanout(False)`` restores serial visits); the
legacy ``store.lookup`` shim stays serial for bit-for-bit continuity.
:func:`execute_plan_staged` keeps the pre-streaming one-shot path as a
reference implementation for the equivalence suite.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.api.cache import plan_fingerprint
from repro.api.plan import (
    DEFAULT_MORSEL,
    AggregateResult,
    ExplainStats,
    OperatorStats,
    Predicate,
    QueryPlan,
    QueryResult,
    aggregate_columns,
    aggregate_rows,
    columns_with_predicates,
    evaluate_predicates,
    finalize_agg_state,
    merge_agg_states,
)
from repro.api.protocol import _check_index_agreement
from repro.fault.errors import OwnerError, OwnerFailure

#: Morsels in flight ahead of the host half, per plan.  Matches the
#: store-level DISPATCH_WINDOW so device residency stays bounded.
MORSEL_WINDOW = 2

#: Adaptive morsel sizing bounds (rows).  Powers of two so resized
#: morsels keep hitting the inference engine's power-of-two batch
#: buckets instead of forcing fresh compiles.
ADAPT_MIN = 1 << 12
ADAPT_MAX = 1 << 20

#: Stage fields mirrored into ``deepmap_executor_stage_seconds_total``
#: and rendered as per-operator child spans under each collect span.
_STAGE_FIELDS = (
    ("exist", "exist_s"),
    ("aux_merge", "aux_s"),
    ("filter", "filter_s"),
    ("decode", "decode_s"),
    ("aggregate", "agg_s"),
)

#: Per-morsel operator-time targets (seconds).  Below the low mark the
#: fixed per-morsel overhead (dispatch bookkeeping, stats merging)
#: dominates and the window doubles; above the high mark a morsel is
#: too coarse to overlap well (and pins too much on device) and the
#: window halves.
ADAPT_LOW_S = 0.004
ADAPT_HIGH_S = 0.032


def next_morsel_rows(rows: int, operator_seconds: float) -> int:
    """Adaptive-sizing rule: the next morsel's row count given the last
    full morsel's summed per-operator time.

    Deterministic in its inputs (double under :data:`ADAPT_LOW_S`,
    halve over :data:`ADAPT_HIGH_S`, else hold) and bounded to
    ``[ADAPT_MIN, ADAPT_MAX]``; growth stays power-of-two-aligned so
    the device batch buckets stay warm.  Pure so the equivalence suite
    can test it directly.
    """
    if operator_seconds < ADAPT_LOW_S and rows < ADAPT_MAX:
        return min(rows * 2, ADAPT_MAX)
    if operator_seconds > ADAPT_HIGH_S and rows > ADAPT_MIN:
        return max(rows // 2, ADAPT_MIN)
    return rows


#: First-morsel operator-time target: the geometric midpoint of the
#: adaptive band (~11.3 ms) — a seed landing there needs no resizing.
SEED_TARGET_S = (ADAPT_LOW_S * ADAPT_HIGH_S) ** 0.5

#: Assumed effective batched-inference throughput (flop/s) for the
#: cost model below.  Calibrated so a ~300 KB model (the common
#: build in this repo's benchmarks) seeds at :data:`DEFAULT_MORSEL` —
#: the seed only moves the start for models meaningfully bigger or
#: smaller, and adaptive resizing corrects any residual error.
SEED_THROUGHPUT_FLOPS = 1e12


def seed_morsel_rows(model_bytes: int, max_rows: int = ADAPT_MAX) -> int:
    """Cost-model seed for the FIRST morsel of an adaptive plan.

    A row through an MLP of ``model_bytes`` float32 parameters costs
    about ``model_bytes / 2`` flops (two flops per weight, four bytes
    per weight); at :data:`SEED_THROUGHPUT_FLOPS` that gives an
    estimated per-row time, and the seed is the power of two whose
    morsel lands nearest :data:`SEED_TARGET_S` — so adaptive resizing
    starts inside (or next to) the target band instead of walking
    there from a fixed 2^16.  Clamped to ``[ADAPT_MIN, min(ADAPT_MAX,
    max_rows)]`` with a power-of-two floor so the device batch buckets
    stay warm.  ``model_bytes <= 0`` (baseline stores have no model)
    returns :data:`DEFAULT_MORSEL` — their seed is unchanged.  Pure so
    the seeding rule is unit-testable.
    """
    if model_bytes <= 0:
        return DEFAULT_MORSEL
    per_row_s = (model_bytes / 2) / SEED_THROUGHPUT_FLOPS
    want = int(SEED_TARGET_S / per_row_s)
    cap = min(ADAPT_MAX, max(int(max_rows), ADAPT_MIN))
    rows = ADAPT_MIN
    while rows * 2 <= min(want, cap):
        rows *= 2
    return rows


class _FailedDispatch:
    """Handle slot for a morsel whose dispatch raised under
    ``on_error='partial'`` — collect time turns it into a degraded
    morsel instead of killing the plan."""

    __slots__ = ("exc",)

    def __init__(self, exc: BaseException):
        self.exc = exc


@dataclasses.dataclass
class MorselResult:
    """One collected morsel of a streaming plan.

    ``keys``/``values``/``exists`` are aligned with the morsel's slice
    of the key stream; ``match`` is the pushed-down predicate selector
    (``None`` = no predicates — every existing row is a result row).
    For below-decode aggregate plans ``agg`` carries the morsel's
    partial aggregation state instead — ``values``/``exists`` are
    empty and the gatherer merges states rather than rows.
    """

    index: int
    start: int
    keys: np.ndarray
    values: Dict[str, np.ndarray]
    exists: np.ndarray
    match: Optional[np.ndarray]
    stats: ExplainStats
    agg: Optional[Dict[tuple, list]] = None


def _describe_failure(exc: BaseException) -> Tuple[dict, ...]:
    """Normalize an executor-level failure into ``owners_failed``
    evidence entries (multi-owner failures keep per-owner detail)."""
    if isinstance(exc, OwnerFailure):
        return tuple(o.describe() for o in exc.owners)
    return (OwnerError(
        owner="store", site=getattr(exc, "site", "dispatch"),
        attempts=1, error_type=type(exc).__name__, message=str(exc),
    ).describe(),)


def _resolve_keys(store, plan: QueryPlan) -> Tuple[np.ndarray, float]:
    """KeySource operator: materialize the plan's key stream."""
    t0 = time.perf_counter()
    if plan.kind == "point":
        keys = np.asarray(plan.keys, dtype=np.int64)
    elif plan.kind == "range":
        keys = store._range_keys(int(plan.lo), int(plan.hi))
    else:  # scan
        keys = store._all_keys()
    return keys, time.perf_counter() - t0


class PlanStream:
    """One plan's morsel state machine.

    Splits the key stream into morsels and drives the store's
    dispatch/collect hooks with an explicit in-flight window.  The
    multiplexers (:func:`stream_plan`, :func:`execute_plans`) call
    :meth:`dispatch_one` / :meth:`collect_one` in whatever order keeps
    the most device work in flight.

    Plan compilation consults the store's per-store
    :class:`~repro.api.cache.PlanCache`: a repeated range/scan plan
    reuses its materialized key stream and resolved projection instead
    of re-scanning the existence index (``cache_state`` records the
    outcome as explain evidence).  Morsel sizes are **adaptive** by
    default — resized between morsels by :func:`next_morsel_rows` from
    the collected morsel's per-operator timings — unless the plan
    forces a fixed size (``Query.morsel(n)``).
    """

    def __init__(self, store, plan: QueryPlan):
        self.store = store
        self.plan = plan
        self._t_plan0 = time.perf_counter()
        self.fixed = plan.morsel is not None
        self._morsel_rows = plan.morsel_rows()
        if not self.fixed:
            # Cost-model seed (satellite of the device-residency work):
            # start adaptive sizing from the store's model footprint
            # instead of a fixed 2^16.  Baselines (no "model" component)
            # keep the DEFAULT_MORSEL seed bit-for-bit.
            self._morsel_rows = seed_morsel_rows(
                int(store.size_breakdown().get("model", 0)),
                max_rows=getattr(
                    getattr(store, "config", None), "inference_batch",
                    ADAPT_MAX,
                ),
            )
        self.fanout = True if plan.fanout is None else plan.fanout
        self.preds: Tuple[Predicate, ...] = (
            plan.predicates if plan.pushdown else ()
        )
        #: Below-decode aggregation: with pushdown (default) every
        #: morsel collects through ``_collect_aggregate`` and returns a
        #: partial state; ``pushdown=False`` keeps rows flowing and the
        #: gatherer aggregates post-hoc (the reference path).
        self.agg_below = bool(plan.aggregates) and plan.pushdown
        #: Dispatch capability: the store will evaluate these pushdown
        #: predicates in-kernel (match bits ride the inference call), so
        #: the executor's host Filter stage is expected to be a no-op.
        self.kernel_filter = bool(self.preds) and bool(
            store.supports_kernel_filter(self.preds)
        )
        #: range/scan keys come from the existence index, so every key
        #: is known to exist — the hint baseline partition pruning needs.
        self.keys_exist = plan.kind != "point"
        fp = plan_fingerprint(plan)
        cache = store.plan_cache()
        version = store.mutation_version()
        entry = cache.get(fp, version)
        if entry is not None and plan.kind != "point" and entry.keys is None:
            # The key stream exceeded the cache's byte budget and was
            # dropped at put time — resolve it fresh.
            entry = None
        self.cache_state = "bypass" if fp is None else (
            "hit" if entry is not None else "miss"
        )
        if entry is not None:
            t0 = time.perf_counter()
            self.keys = (
                np.asarray(plan.keys, dtype=np.int64)
                if plan.kind == "point"
                else entry.keys
            )
            self.columns = entry.columns
            self.route_s = time.perf_counter() - t0
        else:
            self.keys, self.route_s = _resolve_keys(store, plan)
            # Post-hoc filtering evaluates on decoded values, so the
            # predicate columns must be decoded even when the projection
            # excludes them (_finalize_morsel drops them after filtering).
            # Aggregate plans project exactly the group-by + aggregate
            # columns (plan.columns is None by construction).
            self.columns = (
                aggregate_columns(plan.group_by, plan.aggregates)
                if plan.aggregates
                else plan.columns
            )
            if plan.predicates and not plan.pushdown:
                self.columns = columns_with_predicates(
                    self.columns, plan.predicates
                )
            cache.put(
                fp,
                version,
                None if plan.kind == "point" else self.keys,
                self.columns,
            )
        now = time.perf_counter()
        obs.tracer().add_span(
            "key_source", now - self.route_s, now, track="host",
            kind=plan.kind, cache=self.cache_state,
        )
        self.sizes: List[int] = []  # dispatched morsel sizes (evidence)
        self._cursor = 0
        self._dispatched = 0
        self._dispatched_any = False
        # (seq, start, rows, target, handle, t_dispatch) per in-flight
        # morsel — t_dispatch anchors the retroactive device-track span.
        self._inflight: List[Tuple[int, int, int, int, object, float]] = []

    # ------------------------------------------------------------- state
    @property
    def dispatch_done(self) -> bool:
        """True once the whole key stream has been dispatched (a
        zero-length stream still dispatches ONE empty morsel)."""
        return self._dispatched_any and self._cursor >= self.keys.shape[0]

    @property
    def done(self) -> bool:
        """True once every dispatched morsel has been collected."""
        return self.dispatch_done and not self._inflight

    @property
    def inflight(self) -> int:
        """Number of dispatched-but-uncollected morsels."""
        return len(self._inflight)

    # ------------------------------------------------------------- steps
    def dispatch_one(self) -> bool:
        """Enqueue the next morsel's device work; False when drained."""
        if self.dispatch_done:
            return False
        target = self._morsel_rows
        chunk = self.keys[self._cursor : self._cursor + target]
        t_dispatch = time.perf_counter()
        try:
            handle = self.store._dispatch_lookup(
                chunk,
                self.columns,
                fanout=self.fanout,
                predicates=self.preds,
                keys_exist=self.keys_exist,
                on_error=self.plan.on_error,
            )
        except Exception as exc:
            # Multi-owner stores capture dispatch failures themselves;
            # this is the single-owner (or totally-failed) case.
            if self.plan.on_error != "partial":
                raise
            handle = _FailedDispatch(exc)
        rows = int(chunk.shape[0])
        self._inflight.append(
            (self._dispatched, self._cursor, rows, target, handle, t_dispatch)
        )
        self.sizes.append(rows)
        self._cursor += rows
        self._dispatched += 1
        self._dispatched_any = True
        return True

    def collect_one(self) -> MorselResult:
        """Block on the oldest in-flight morsel's host half.

        Under adaptive sizing, a collected **full** morsel's summed
        per-operator time feeds :func:`next_morsel_rows` to resize
        subsequent dispatches (partial tail morsels carry no signal).

        Telemetry is emitted here (never in the hot per-key loops):
        a retroactive **device-track** span ``infer_dispatch`` covering
        [dispatch(seq) → collect-start(seq)] — the window in which the
        morsel's device work ran while the host drained earlier morsels
        — plus a **host-track** ``collect`` span for the blocking host
        half, per-operator child spans reconstructed from the morsel's
        stage timings, and the morsel counters/histograms.
        """
        if not self._inflight:
            raise RuntimeError("collect_one with no morsel in flight")
        seq, start, rows, target, handle, t_dispatch = self._inflight.pop(0)
        t_collect0 = time.perf_counter()
        agg: Optional[Dict[tuple, list]] = None
        if isinstance(handle, _FailedDispatch):
            values, exists, match, stats, agg = self._degraded(rows, handle.exc)
        else:
            try:
                if self.agg_below:
                    agg, stats = self.store._collect_aggregate(
                        handle, self.plan.group_by, self.plan.aggregates
                    )
                    values = {}
                    exists = np.zeros(0, dtype=bool)
                    match = None
                else:
                    values, exists, match, stats = (
                        self.store._collect_lookup(handle)
                    )
            except Exception as exc:
                if self.plan.on_error != "partial":
                    raise
                # OwnerFailure here means even partial degradation was
                # impossible inside the store (every owner failed);
                # degrade the whole morsel at this level instead.
                values, exists, match, stats, agg = self._degraded(rows, exc)
        t_collect1 = time.perf_counter()
        self._emit_morsel(seq, rows, stats, t_dispatch, t_collect0, t_collect1)
        if not self.fixed and rows == target:
            operator_s = (
                stats.infer_s + stats.exist_s + stats.aux_s
                + stats.filter_s + stats.decode_s + stats.agg_s
            )
            self._morsel_rows = next_morsel_rows(target, operator_s)
        if self.done:
            self._emit_plan(t_collect1)
        return MorselResult(
            index=seq,
            start=start,
            keys=self.keys[start : start + rows],
            values=values,
            exists=exists,
            match=match,
            stats=stats,
            agg=agg,
        )

    # ---------------------------------------------------------- degraded
    def _degraded(self, rows: int, exc: BaseException):
        """Degrade one morsel under ``on_error='partial'`` — row form
        (typed placeholder columns) or aggregate form (empty partial
        state), matching the plan's collect mode."""
        if self.agg_below:
            stats = ExplainStats(
                plan=("degraded",),
                owners_failed=_describe_failure(exc),
                keys_unresolved=rows,
            )
            obs.registry().counter(
                "deepmap_fault_degraded_morsels_total",
                "Morsels answered with every row unreachable "
                "(on_error='partial' full-owner failure).",
            ).inc(kind=self.plan.kind)
            return {}, np.zeros(0, dtype=bool), None, stats, {}
        values, exists, match, stats = self._degraded_morsel(rows, exc)
        return values, exists, match, stats, None

    def _degraded_morsel(self, rows: int, exc: BaseException):
        """Synthesize a fully-degraded morsel under ``on_error=
        'partial')``: every row unreachable (``exists=False``, typed
        placeholder values), with the failure carried as
        ``owners_failed``/``keys_unresolved`` evidence.

        Column dtypes come from a zero-length probe lookup — the
        protocol guarantees typed empty columns for empty batches
        without touching inference.  If even the probe fails there is
        nothing typed to return: the original failure propagates."""
        try:
            probe = self.store._collect_lookup(self.store._dispatch_lookup(
                np.zeros(0, dtype=np.int64), self.columns,
                fanout=False, predicates=self.preds,
            ))
        except Exception:
            raise exc
        values = {
            c: np.zeros(rows, dtype=arr.dtype) for c, arr in probe[0].items()
        }
        exists = np.zeros(rows, dtype=bool)
        match = np.zeros(rows, dtype=bool) if self.preds else None
        stats = ExplainStats(
            plan=("degraded",),
            owners_failed=_describe_failure(exc),
            keys_unresolved=rows,
        )
        obs.registry().counter(
            "deepmap_fault_degraded_morsels_total",
            "Morsels answered with every row unreachable "
            "(on_error='partial' full-owner failure).",
        ).inc(kind=self.plan.kind)
        return values, exists, match, stats

    # --------------------------------------------------------- telemetry
    def _emit_morsel(
        self, seq: int, rows: int, stats: ExplainStats,
        t_dispatch: float, t_collect0: float, t_collect1: float,
    ) -> None:
        reg = obs.registry()
        trc = obs.tracer()
        if not (reg.enabled or trc.enabled):
            return
        kind = self.plan.kind
        trc.add_span(
            "infer_dispatch", t_dispatch, t_collect0, track="device",
            morsel=seq, rows=rows, kind=kind,
        )
        trc.add_span(
            "collect", t_collect0, t_collect1, track="host",
            morsel=seq, rows=rows, kind=kind,
        )
        # Operator child spans are a *reconstruction*: the store hooks
        # report stage durations, not wall endpoints, so the children
        # are laid out sequentially from collect-start in pipeline
        # order.  Gaps under the collect span are un-attributed host
        # overhead (scatter bookkeeping, stats merging).
        t = t_collect0
        for op, field in _STAGE_FIELDS:
            d = getattr(stats, field)
            if d > 0:
                trc.add_span(f"op:{op}", t, t + d, track="host", morsel=seq)
                t += d
        reg.counter(
            "deepmap_executor_morsels_total",
            "Morsels collected, by plan kind.",
        ).inc(kind=kind)
        reg.histogram(
            "deepmap_executor_morsel_rows",
            "Rows per collected morsel.",
            buckets=obs.SIZE_BUCKETS,
        ).observe(rows, kind=kind)
        reg.histogram(
            "deepmap_executor_morsel_seconds",
            "Host collect latency per morsel.",
        ).observe(t_collect1 - t_collect0, kind=kind)
        stages = reg.counter(
            "deepmap_executor_stage_seconds_total",
            "Cumulative per-operator seconds, from store stage timings.",
        )
        if stats.infer_s > 0:
            stages.inc(stats.infer_s, stage="infer")
        for op, field in _STAGE_FIELDS:
            d = getattr(stats, field)
            if d > 0:
                stages.inc(d, stage=op)

    def _emit_plan(self, t_end: float) -> None:
        """Plan-level span + counters, once, when the last morsel of
        this stream is collected (covers both ``execute_plan`` and bare
        ``stream_plan`` consumers)."""
        reg = obs.registry()
        kind = self.plan.kind
        obs.tracer().add_span(
            "plan", self._t_plan0, t_end, track="plans",
            kind=kind, morsels=self._dispatched, cache=self.cache_state,
        )
        reg.counter(
            "deepmap_executor_plans_total", "Plans fully executed, by kind."
        ).inc(kind=kind)
        reg.histogram(
            "deepmap_executor_plan_seconds",
            "End-to-end plan latency (first dispatch to last collect).",
        ).observe(t_end - self._t_plan0, kind=kind)
        reg.counter(
            "deepmap_executor_stage_seconds_total",
            "Cumulative per-operator seconds, from store stage timings.",
        ).inc(self.route_s, stage="key_source")


# --------------------------------------------------------------- finalize
def _finalize_morsel(plan: QueryPlan, morsel: MorselResult) -> MorselResult:
    """The ONE place ``pushdown(False)`` semantics live: filter on the
    decoded values (the byte-equality oracle for pushdown) and drop
    the pred-only columns, so every consumer (``stream_plan``,
    ``execute_plan``, ``execute_plans``) sees the same match contract
    — never silently unfiltered rows.  Also enforces the range/scan
    existence-index invariant for every morsel consumer, streaming
    included — relaxed by exactly the rows a degraded morsel reports
    unreachable (``keys_unresolved``): a partial result may miss keys
    whose owner is down, but never MORE than the evidence admits."""
    if morsel.agg is not None:
        # Below-decode aggregate morsel: no rows to filter or check —
        # the partial state already reflects existence + predicates.
        return morsel
    if plan.kind != "point":
        missing = int(morsel.exists.shape[0] - morsel.exists.sum())
        if missing > int(morsel.stats.keys_unresolved):
            _check_index_agreement(f"{plan.kind} plan", morsel.exists)
    if plan.predicates and not plan.pushdown:
        morsel.match = evaluate_predicates(
            plan.predicates, morsel.values, morsel.exists, morsel.stats
        )
        if plan.columns is not None:
            morsel.values = {c: morsel.values[c] for c in plan.columns}
    return morsel


def _stream_run(run: PlanStream, window: int) -> Iterator[MorselResult]:
    while not run.done:
        while run.inflight < window and run.dispatch_one():
            pass
        yield _finalize_morsel(run.plan, run.collect_one())


# ------------------------------------------------------------------- join
def _dispatch_join(plan: QueryPlan, morsel: MorselResult):
    """JoinProbe dispatch half: enqueue the right-store lookup for one
    finalized morsel's candidate rows (existing + predicate-matched).
    The probe keys go through ``JoinSpec.key`` (vectorized left-key →
    right-key map; identity when ``None``) and scatter through the
    right store's own dispatch hook — existence index, sharding and
    fan-out included — so the probe IS a point plan on the right."""
    spec = plan.join
    sel = morsel.exists if morsel.match is None else morsel.match
    sel_idx = np.flatnonzero(sel)
    left = morsel.keys[sel_idx]
    probe = (
        left
        if spec.key is None
        else np.asarray(spec.key(left), dtype=np.int64)
    )
    try:
        handle = spec.store._dispatch_lookup(
            probe, spec.columns, fanout=True, on_error=plan.on_error
        )
    except Exception as exc:
        if plan.on_error != "partial":
            raise
        handle = _FailedDispatch(exc)
    return morsel, sel_idx, probe, handle


def _degraded_join(plan: QueryPlan, probe: np.ndarray, exc: BaseException):
    """Right-store failure under ``on_error='partial'``: every probe
    unresolved — the candidate rows drop out of the join (typed empty
    right columns via a zero-length probe, as ``_degraded_morsel``)."""
    spec = plan.join
    try:
        pvals, _, _, _ = spec.store._collect_lookup(
            spec.store._dispatch_lookup(
                np.zeros(0, dtype=np.int64), spec.columns, fanout=False
            )
        )
    except Exception:
        raise exc
    n = int(probe.shape[0])
    rvalues = {c: np.zeros(n, dtype=arr.dtype) for c, arr in pvals.items()}
    rexists = np.zeros(n, dtype=bool)
    stats = ExplainStats(
        owners_failed=_describe_failure(exc), keys_unresolved=n
    )
    obs.registry().counter(
        "deepmap_fault_degraded_morsels_total",
        "Morsels answered with every row unreachable "
        "(on_error='partial' full-owner failure).",
    ).inc(kind="join")
    return rvalues, rexists, stats


def _collect_join(plan: QueryPlan, entry) -> MorselResult:
    """JoinProbe collect half: resolve the right-store lookup, narrow
    ``match`` to rows whose probe key exists on the right, and scatter
    the right columns into the morsel (prefixed with ``JoinSpec.prefix``
    on name collision).  Right-store stage timings and decode counts
    merge into the morsel's stats; ``join_probes`` records the probes."""
    morsel, sel_idx, probe, handle = entry
    spec = plan.join
    rows = int(morsel.keys.shape[0])
    morsel.stats.join_probes += int(probe.shape[0])
    if isinstance(handle, _FailedDispatch):
        rvalues, rexists, rstats = _degraded_join(plan, probe, handle.exc)
    else:
        try:
            rvalues, rexists, _, rstats = spec.store._collect_lookup(handle)
        except Exception as exc:
            if plan.on_error != "partial":
                raise
            rvalues, rexists, rstats = _degraded_join(plan, probe, exc)
    match = (
        morsel.exists if morsel.match is None else morsel.match
    ).copy()
    match[sel_idx[~rexists]] = False
    morsel.match = match
    for c, arr in rvalues.items():
        name = spec.prefix + c if c in morsel.values else c
        full = np.zeros(rows, dtype=arr.dtype)
        full[sel_idx] = arr
        morsel.values[name] = full
    morsel.stats.merge_timings(rstats)
    return morsel


def _join_stream(
    plan: QueryPlan, stream: Iterator[MorselResult], window: int
) -> Iterator[MorselResult]:
    """Wrap a finalized morsel stream with the join operator, keeping
    up to ``window`` right-store probes in flight ahead of the collect
    — right-store device work overlaps left host halves the same way
    morsel dispatch overlaps collect within one plan."""
    pending: List[tuple] = []
    for morsel in stream:
        pending.append(_dispatch_join(plan, morsel))
        while len(pending) > window:
            yield _collect_join(plan, pending.pop(0))
    while pending:
        yield _collect_join(plan, pending.pop(0))


def _apply_join(plan: QueryPlan, morsel: MorselResult) -> MorselResult:
    """Synchronous join step (dispatch + collect back-to-back) for
    consumers that interleave several plans (:func:`execute_plans`)."""
    if plan.join is None:
        return morsel
    return _collect_join(plan, _dispatch_join(plan, morsel))


def stream_plan(
    store, plan: QueryPlan, window: int = MORSEL_WINDOW
) -> Iterator[MorselResult]:
    """Execute ``plan`` as a morsel stream (generator).

    Keeps up to ``window`` morsels' device work in flight ahead of the
    host half; yields morsels in key-stream order (post-hoc predicates
    already applied as ``match`` selectors, join probes resolved with
    their own dispatch-ahead window).  Callers that only need the
    final relation should use :func:`execute_plan`; streaming
    consumers (the serving engine, federated gathers) get bounded
    memory and early rows from this form.
    """
    stream = _stream_run(PlanStream(store, plan), window)
    if plan.join is not None:
        stream = _join_stream(plan, stream, window)
    return stream


def _concat(parts: List[np.ndarray]) -> np.ndarray:
    return parts[0] if len(parts) == 1 else np.concatenate(parts)


class _Gatherer:
    """Gather operator: accumulate finalized morsels (post-hoc filter
    already applied by :func:`_finalize_morsel`) into one QueryResult."""

    def __init__(self, plan: QueryPlan):
        self.plan = plan
        self.stats = ExplainStats(kind=plan.kind)
        self.key_parts: List[np.ndarray] = []
        self.exists_parts: List[np.ndarray] = []
        self.value_parts: Dict[str, List[np.ndarray]] = {}
        self.agg_state: Dict[tuple, list] = {}
        self.inner_plan: Tuple[str, ...] = ()
        self.t0 = time.perf_counter()

    def add(self, morsel: MorselResult) -> None:
        """Fold one finalized morsel into the accumulating result."""
        t0 = time.perf_counter()
        if self.plan.aggregates:
            if morsel.agg is not None:
                # Below-decode morsel: merge the store's partial state.
                merge_agg_states(
                    self.agg_state, morsel.agg, self.plan.aggregates
                )
            else:
                # pushdown(False) reference: aggregate the decoded rows.
                aggregate_rows(
                    self.agg_state,
                    self.plan.group_by,
                    self.plan.aggregates,
                    morsel.values,
                    morsel.exists if morsel.match is None else morsel.match,
                )
            if not self.inner_plan:
                self.inner_plan = morsel.stats.plan
            self.stats.merge_timings(morsel.stats)
            self.stats.morsels += 1
            self.stats.agg_s += time.perf_counter() - t0
            return
        if morsel.match is not None:
            sel = morsel.match
            self.key_parts.append(morsel.keys[sel])
            self.exists_parts.append(morsel.exists[sel])
            for c, arr in morsel.values.items():
                self.value_parts.setdefault(c, []).append(arr[sel])
        else:
            self.key_parts.append(morsel.keys)
            self.exists_parts.append(morsel.exists)
            for c, arr in morsel.values.items():
                self.value_parts.setdefault(c, []).append(arr)
        if not self.inner_plan:
            self.inner_plan = morsel.stats.plan
        self.stats.merge_timings(morsel.stats)
        self.stats.morsels += 1
        self.stats.gather_s += time.perf_counter() - t0

    def finish(self, run: PlanStream):
        """Concatenate the accumulated morsels and assemble the final
        :class:`~repro.api.plan.ExplainStats` (operator rows, plan
        stages, cache + morsel-size evidence).  Aggregate plans
        finalize the folded state instead — :class:`AggregateResult`."""
        if self.plan.aggregates:
            return self._finish_aggregate(run)
        t0 = time.perf_counter()
        keys = (
            _concat(self.key_parts)
            if self.key_parts
            else np.zeros(0, dtype=np.int64)
        )
        exists = (
            _concat(self.exists_parts)
            if self.exists_parts
            else np.zeros(0, dtype=bool)
        )
        values = {c: _concat(parts) for c, parts in self.value_parts.items()}
        stats = self.stats
        stats.gather_s += time.perf_counter() - t0
        stats.num_keys = int(run.keys.shape[0])
        stats.num_rows = int(exists.sum())
        stats.route_s += run.route_s
        stats.plan_cache = run.cache_state
        stats.morsel_sizes = tuple(run.sizes)
        filtered = bool(self.plan.predicates)
        # Kernel-filter evidence: the capability flag says the store
        # *promised* in-kernel evaluation; ``stats.kernel_filtered``
        # (or-merged across morsels) says at least one morsel delivered.
        kfilter = filtered and (run.kernel_filter or stats.kernel_filtered)
        stats.plan = (
            (run.plan.source_stage(),)
            + self.inner_plan
            + (
                (
                    f"filter[{'kernel:' if kfilter else ''}"
                    f"{','.join(stats.predicates)}]",
                )
                if filtered
                else ()
            )
            + (
                (
                    f"join[{type(self.plan.join.store).__name__},"
                    f"{stats.join_probes} probes]",
                )
                if self.plan.join is not None
                else ()
            )
            + (f"gather[{stats.morsels} morsels]",)
            + (
                (f"degraded[{len(stats.owners_failed)} owners]",)
                if stats.owners_failed
                else ()
            )
        )
        stats.total_s = time.perf_counter() - self.t0
        n = stats.num_keys
        ops = [OperatorStats("key_source", 0, n, stats.route_s)]
        if stats.shards_visited:
            ops.append(OperatorStats("shard_scatter", n, n, 0.0))
        ops.append(OperatorStats("infer", n, n, stats.infer_s))
        ops.append(OperatorStats("exist", n, n, stats.exist_s))
        ops.append(OperatorStats("aux_merge", n, n, stats.aux_s))
        if filtered:
            # Under the in-kernel path the host stage only patches
            # aux-overridden rows, so filter_s collapses toward zero;
            # the renamed operator row records why.
            ops.append(OperatorStats(
                "filter[kernel]" if kfilter else "filter",
                n, stats.rows_matched, stats.filter_s,
            ))
        ops.append(
            OperatorStats("decode", stats.rows_decoded, stats.rows_decoded,
                          stats.decode_s)
        )
        if self.plan.join is not None:
            ops.append(OperatorStats(
                "join", stats.join_probes, int(keys.shape[0]), 0.0
            ))
        ops.append(OperatorStats("gather", n, keys.shape[0], stats.gather_s))
        stats.operators = tuple(ops)
        return QueryResult(keys=keys, values=values, exists=exists, explain=stats)

    def _finish_aggregate(self, run: PlanStream) -> AggregateResult:
        """Finalize the folded aggregation state: deterministic group
        order, plan stages (the store-level ``aggregate[...]`` stage is
        kept when the inner plan recorded one; the post-hoc reference
        path records its own ``aggregate[host,...]``), operator rows
        with the decode evidence that proves where aggregation ran."""
        t0 = time.perf_counter()
        plan = self.plan
        stats = self.stats
        groups, aggs = finalize_agg_state(
            self.agg_state, plan.group_by, plan.aggregates
        )
        stats.gather_s += time.perf_counter() - t0
        stats.groups_emitted = len(self.agg_state)
        stats.num_keys = int(run.keys.shape[0])
        stats.num_rows = stats.groups_emitted
        stats.route_s += run.route_s
        stats.plan_cache = run.cache_state
        stats.morsel_sizes = tuple(run.sizes)
        filtered = bool(plan.predicates)
        kfilter = filtered and (run.kernel_filter or stats.kernel_filtered)
        has_agg_stage = any(
            s.startswith("aggregate[") for s in self.inner_plan
        )
        mode = "store" if run.agg_below else "host"
        stats.plan = (
            (plan.source_stage(),)
            + self.inner_plan
            + (
                (
                    f"filter[{'kernel:' if kfilter else ''}"
                    f"{','.join(stats.predicates)}]",
                )
                if filtered and not run.agg_below
                else ()
            )
            + (
                ()
                if has_agg_stage
                else (
                    f"aggregate[{mode},{len(plan.group_by)} keys,"
                    f"{len(plan.aggregates)} aggs]",
                )
            )
            + (f"gather[{stats.morsels} morsels]",)
            + (
                (f"degraded[{len(stats.owners_failed)} owners]",)
                if stats.owners_failed
                else ()
            )
        )
        stats.total_s = time.perf_counter() - self.t0
        n = stats.num_keys
        ops = [OperatorStats("key_source", 0, n, stats.route_s)]
        if stats.shards_visited:
            ops.append(OperatorStats("shard_scatter", n, n, 0.0))
        ops.append(OperatorStats("infer", n, n, stats.infer_s))
        ops.append(OperatorStats("exist", n, n, stats.exist_s))
        ops.append(OperatorStats("aux_merge", n, n, stats.aux_s))
        if filtered:
            ops.append(OperatorStats(
                "filter[kernel]" if kfilter else "filter",
                n, stats.rows_matched, stats.filter_s,
            ))
        ops.append(
            OperatorStats("decode", stats.rows_decoded, stats.rows_decoded,
                          stats.decode_s)
        )
        ops.append(OperatorStats(
            "aggregate", n, stats.groups_emitted, stats.agg_s
        ))
        ops.append(OperatorStats(
            "gather", stats.groups_emitted, stats.groups_emitted,
            stats.gather_s,
        ))
        stats.operators = tuple(ops)
        return AggregateResult(
            group_by=plan.group_by,
            groups=groups,
            aggregates=aggs,
            explain=stats,
        )


def execute_plan(store, plan: QueryPlan):
    """Run ``plan`` against ``store`` -> :class:`QueryResult` (the
    morsel stream, fully gathered), or :class:`AggregateResult` for
    ``group_by``/``agg`` plans."""
    run = PlanStream(store, plan)
    stream: Iterator[MorselResult] = _stream_run(run, MORSEL_WINDOW)
    if plan.join is not None:
        stream = _join_stream(plan, stream, MORSEL_WINDOW)
    gatherer = _Gatherer(plan)
    for morsel in stream:
        gatherer.add(morsel)
    return gatherer.finish(run)


def execute_plans(
    pairs: Sequence[Tuple[object, QueryPlan]],
    window: int = MORSEL_WINDOW,
    max_inflight: int = 16,
) -> List:
    """Run several plans — possibly against several stores — through
    ONE interleaved morsel pipeline.

    Dispatch is round-robin across plans: every live plan keeps up to
    ``window`` morsels of device work in flight, and collections
    rotate, so while one plan's host half (aux merge, filter, decode)
    runs, every other plan's device inference keeps executing.  This is
    the cross-plan overlap ``execute_plan`` in a loop cannot give: a
    serial loop drains plan *i* completely (device idle during its last
    host half) before plan *i+1* dispatches anything.

    ``window`` bounds residency per plan; ``max_inflight`` bounds the
    FLEET — the aggregate morsels in flight never exceed it, so a
    64-plan batch cannot pin 64x``window`` morsels on device (top-up is
    round-robin one morsel at a time, keeping the budget fair across
    plans).

    Results arrive in input order, each identical to what
    ``execute_plan`` would have produced alone.
    """
    max_inflight = max(1, int(max_inflight))
    runs = [PlanStream(store, plan) for store, plan in pairs]
    gatherers = [_Gatherer(plan) for _, plan in pairs]
    results: List[Optional[object]] = [None] * len(runs)
    live = list(range(len(runs)))
    rounds = 0
    while live:
        # Phase 1: top up every live plan's dispatch window — device
        # work from ALL plans is enqueued before any host half blocks —
        # round-robin one morsel per pass, under the global budget.
        # The starting plan rotates per round so a fleet larger than
        # the budget cannot starve its tail: budget freed by the head
        # plans' collections is offered to a different plan each time.
        total = sum(runs[i].inflight for i in live)
        start = rounds % len(live)
        order = live[start:] + live[:start]
        topped = True
        while topped and total < max_inflight:
            topped = False
            for i in order:
                if total >= max_inflight:
                    break
                run = runs[i]
                if run.inflight < window and run.dispatch_one():
                    total += 1
                    topped = True
        # Phase 2: collect one morsel per live plan, round-robin.
        still = []
        for i in live:
            run = runs[i]
            if run.inflight:
                gatherers[i].add(_apply_join(
                    run.plan,
                    _finalize_morsel(run.plan, run.collect_one()),
                ))
            if run.done:
                results[i] = gatherers[i].finish(run)
            else:
                still.append(i)
        live = still
        rounds += 1
    return results  # type: ignore[return-value]


def execute_plan_staged(store, plan: QueryPlan):
    """Legacy one-shot path (pre-streaming executor), kept as the
    reference implementation for the byte-equality suite: the whole
    key stream answered as a single batch through
    ``_lookup_with_stats``, predicates applied post-hoc.  Aggregates
    run post-hoc over the decoded batch (always decode-then-aggregate
    here — the staged path IS a reference) and joins resolve as one
    synchronous probe."""
    t0 = time.perf_counter()
    keys, route_s = _resolve_keys(store, plan)
    num_keys = int(keys.shape[0])
    selected = (
        aggregate_columns(plan.group_by, plan.aggregates)
        if plan.aggregates
        else plan.columns
    )
    need = columns_with_predicates(selected, plan.predicates)
    fanout = True if plan.fanout is None else plan.fanout
    values, exists, stats = store._lookup_with_stats(keys, need, fanout=fanout)
    if plan.kind != "point":
        _check_index_agreement(f"{plan.kind} plan", exists)
    match = (
        evaluate_predicates(plan.predicates, values, exists, stats)
        if plan.predicates
        else None
    )
    if plan.aggregates:
        state: Dict[tuple, list] = {}
        t_agg = time.perf_counter()
        aggregate_rows(
            state, plan.group_by, plan.aggregates, values,
            exists if match is None else match,
        )
        stats.agg_s += time.perf_counter() - t_agg
        groups, aggs = finalize_agg_state(state, plan.group_by, plan.aggregates)
        stats.kind = plan.kind
        stats.groups_emitted = len(state)
        stats.plan = (plan.source_stage(),) + stats.plan + (
            f"aggregate[host,{len(plan.group_by)} keys,"
            f"{len(plan.aggregates)} aggs]",
        )
        stats.num_keys = num_keys
        stats.num_rows = len(state)
        stats.route_s += route_s
        stats.total_s = time.perf_counter() - t0
        return AggregateResult(
            group_by=plan.group_by, groups=groups, aggregates=aggs,
            explain=stats,
        )
    if plan.join is not None:
        left_names = set(values)
        morsel = _apply_join(
            plan,
            MorselResult(0, 0, keys, values, exists, match, stats),
        )
        match, values = morsel.match, morsel.values
        keys, exists = keys[match], exists[match]
        values = {
            c: arr[match]
            for c, arr in values.items()
            if selected is None or c in selected or c not in left_names
        }
    elif match is not None:
        keys, exists = keys[match], exists[match]
        values = {
            c: arr[match]
            for c, arr in values.items()
            if selected is None or c in selected
        }
    stats.kind = plan.kind
    stats.plan = (plan.source_stage(),) + stats.plan
    stats.num_keys = num_keys
    stats.num_rows = int(exists.sum())
    stats.route_s += route_s
    stats.total_s = time.perf_counter() - t0
    return QueryResult(keys=keys, values=values, exists=exists, explain=stats)
